//! Lazy graphs and single-pass kernel fusion: record with `.lazy()`,
//! fuse + dispatch with `.eval()`, and time it against the eager chain.
//!
//! ```bash
//! cargo run --release --example fusion_demo
//! MINITENSOR_NUM_THREADS=4 cargo run --release --example fusion_demo
//! ```

use std::time::Instant;

use minitensor::prelude::*;
use minitensor::runtime::{parallel, stats};

fn main() -> Result<()> {
    // --- Record, then evaluate fused -----------------------------------
    let a = Tensor::from_vec(vec![1., -2., 3., -4., 5., -6.], &[2, 3])?;
    let b = Tensor::from_vec(vec![10., 20., 30.], &[3])?; // broadcasts

    let (la, lb) = (a.lazy(), b.lazy());
    let expr = la.mul(&lb)?.add(&la)?.relu(); // nothing has run yet
    println!("recorded: {expr:?}");

    let before = stats::snapshot();
    let y = expr.eval()?; // one fused kernel: relu(a*b + a)
    let d = stats::snapshot().delta(&before);
    println!("fused eval = {y}");
    println!(
        "…in {} exec dispatch(es), {} output allocation(s), {} ops fused",
        d.exec_dispatches, d.output_allocs, d.fused_ops
    );

    // Bitwise-equal to the eager chain (same scalar ops, same order):
    let eager = a.mul(&b)?.add(&a)?.relu();
    assert_eq!(y.to_vec(), eager.to_vec());

    // Reductions fuse as order-stable epilogues — no intermediate tensor,
    // bit-identical at any MINITENSOR_NUM_THREADS:
    let total = la.mul(&lb)?.add(&la)?.relu().sum().eval()?;
    assert_eq!(total.item()?, eager.sum().item()?);
    println!("fused sum epilogue = {}", total.item()?);

    // Re-evaluating a structurally identical expression hits the
    // compiled-program cache: no region partitioning, no tape build.
    let before = stats::snapshot();
    let _ = la.mul(&lb)?.add(&la)?.relu().eval()?;
    let d = stats::snapshot().delta(&before);
    println!(
        "program cache on re-eval: {} hit(s), {} miss(es)",
        d.program_cache_hits, d.program_cache_misses
    );

    // --- Fused forwards stay differentiable ----------------------------
    let av = Var::from_tensor(a.clone(), true);
    let bv = Var::from_tensor(Tensor::ones(&[3]), true);
    let loss = Var::fused(&[&av, &bv], |l| Ok(l[0].mul(&l[1])?.tanh().square().mean()))?;
    loss.backward()?;
    println!("d(fused loss)/da = {}", av.grad().expect("grad flows"));

    // --- Timing comparison: 6-op chain at 1e6 elements -----------------
    let mut rng = Rng::new(7);
    let n = 1_000_000;
    let x = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let z = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let reps = 20;

    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(x.mul(&z)?.add(&x)?.relu().mul(&z)?.sub(&x)?.relu());
    }
    let eager_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let t0 = Instant::now();
    for _ in 0..reps {
        let (lx, lz) = (x.lazy(), z.lazy());
        std::hint::black_box(
            lx.mul(&lz)?
                .add(&lx)?
                .relu()
                .mul(&lz)?
                .sub(&lx)?
                .relu()
                .eval()?,
        );
    }
    let fused_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    println!(
        "6-op chain, 1e6 elems, {} thread(s): eager {eager_ms:.2} ms vs fused {fused_ms:.2} ms ({:.2}x)",
        parallel::num_threads(),
        eager_ms / fused_ms
    );
    print!("{}", stats::report());
    Ok(())
}
