//! Gradient verification walkthrough (paper §5, eq 11): every layer and
//! loss in the library checked against central finite differences.
//!
//! ```bash
//! cargo run --release --example gradcheck_demo
//! ```

use minitensor::autograd::{gradcheck, Var};
use minitensor::data::Rng;
use minitensor::nn::{losses, Activation, BatchNorm1d, Dense, LayerNorm, Module, Sequential};
use minitensor::ops::conv::Conv2dSpec;
use minitensor::tensor::Tensor;

fn check(name: &str, f: impl Fn(&Var) -> minitensor::Result<Var>, input: &Tensor, tol: f32) {
    match gradcheck(f, input, 1e-3, tol) {
        Ok(r) => println!(
            "{name:<28} probes={:<3} max_abs={:<10.3e} max_rel={:<10.3e} {}",
            r.probes,
            r.max_abs_diff,
            r.max_rel_diff,
            if r.pass { "PASS" } else { "FAIL" }
        ),
        Err(e) => println!("{name:<28} ERROR: {e}"),
    }
}

fn main() -> minitensor::Result<()> {
    let mut rng = Rng::new(7);
    println!("finite-difference gradient checks (eq 11), ε=1e-3:\n");

    // Primitives.
    let x = Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng);
    check("exp·log chain", |v| v.exp().log().sum(), &x, 1e-2);
    check("tanh", |v| v.tanh().sum(), &x, 1e-2);
    check("sigmoid", |v| v.sigmoid().sum(), &x, 1e-2);
    check("gelu", |v| v.gelu().sum(), &x, 1e-2);
    check("square+sqrt", |v| v.square().add_scalar(1.0).sqrt().sum(), &x, 1e-2);
    check("softmax", |v| v.softmax()?.square().sum(), &x, 1e-2);
    check("log_softmax", |v| v.log_softmax()?.square().sum(), &x, 1e-2);

    // Matmul (eq 1/4).
    let mut rng2 = Rng::new(8);
    let w = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng2);
    let wv = Var::from_tensor(w, false);
    check(
        "matmul_nt (dense product)",
        move |v| v.matmul_nt(&wv)?.square().sum(),
        &x,
        1e-2,
    );

    // Layers.
    let dense = Dense::new(4, 6, &mut rng);
    check(
        "Dense layer",
        move |v| dense.forward(v, true)?.square().sum(),
        &x,
        1e-2,
    );
    let mlp = Sequential::new()
        .add(Dense::new(4, 8, &mut rng))
        .add(Activation::Relu)
        .add(Dense::new(8, 3, &mut rng));
    let labels = Tensor::from_vec_i32(vec![0, 2, 1], &[3]).unwrap();
    check(
        "MLP + cross-entropy (eq 8)",
        move |v| losses::cross_entropy(&mlp.forward(v, true)?, &labels),
        &x.narrow(0, 0, 3)?.contiguous(),
        1e-2,
    );

    let bn = BatchNorm1d::new(4);
    let xb = Tensor::randn(&[16, 4], 0.0, 1.0, &mut rng);
    check(
        "BatchNorm1d (eq 7)",
        move |v| bn.forward(v, true)?.square().sum(),
        &xb,
        3e-2,
    );
    let ln = LayerNorm::new(4);
    check(
        "LayerNorm",
        move |v| ln.forward(v, true)?.square().sum(),
        &x,
        3e-2,
    );

    // Convolution (eq 6).
    let xc = Tensor::randn(&[1, 2, 5, 5], 0.0, 1.0, &mut rng);
    let wc = Var::from_tensor(Tensor::randn(&[3, 2, 3, 3], 0.0, 1.0, &mut rng), false);
    // mean (not sum) keeps the loss O(1): central differences in f32 lose
    // ~1e-5 relative precision of L, which would swamp a large summed loss.
    check(
        "conv2d (eq 6)",
        move |v| {
            v.conv2d(&wc, Conv2dSpec { stride: 1, padding: 1 })?
                .square()
                .mean()
        },
        &xc,
        2e-2,
    );
    let xp = Tensor::randn(&[1, 1, 4, 4], 0.0, 1.0, &mut rng);
    check("avg_pool2d", |v| v.avg_pool2d(2)?.square().sum(), &xp, 1e-2);

    // Losses.
    let target = Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng);
    check("MSE", move |v| losses::mse(v, &target), &x, 1e-2);

    println!("\nAll checks compare reverse-mode gradients (eqs 2-4) against");
    println!("central finite differences — the paper's §5 validation.");
    Ok(())
}
