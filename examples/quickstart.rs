//! Quickstart: the PyTorch-like eager API in 60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use minitensor::prelude::*;

fn main() -> Result<()> {
    // --- Tensors and broadcasting (paper §3.1) -------------------------
    let x = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3])?;
    let b = Tensor::from_vec(vec![10., 20., 30.], &[3])?;
    let y = x.add(&b)?; // b broadcasts over the batch dimension
    println!("x + b = {y}");

    let m = Tensor::eye(3);
    println!("x @ I = {}", x.matmul(&m)?);
    println!("sum = {}  mean = {}", x.sum(), x.mean());
    println!("softmax rows = {}", x.softmax()?);

    // --- Autograd (paper §3.2): record ops, call backward() ------------
    let w = Var::from_tensor(Tensor::ones(&[3, 3]), true);
    let v = Var::from_tensor(x.clone(), false);
    let loss = v.matmul(&w)?.tanh().square().sum()?;
    loss.backward()?;
    println!("dL/dW = {}", w.grad().expect("gradient accumulated"));

    // --- Finite-difference verification (paper §5, eq 11) --------------
    let report = gradcheck(
        |v| v.sigmoid().square().sum(),
        &Tensor::from_vec(vec![0.5, -1.0, 2.0], &[3])?,
        1e-3,
        1e-2,
    )?;
    println!(
        "gradcheck: max_abs_diff={:.2e} over {} probes — {}",
        report.max_abs_diff,
        report.probes,
        if report.pass { "PASS" } else { "FAIL" }
    );

    // --- A three-line neural network (paper §3.3) ----------------------
    let mut rng = Rng::new(42);
    let model = Sequential::new()
        .add(Dense::new(3, 16, &mut rng))
        .add(Activation::Relu)
        .add(Dense::new(16, 2, &mut rng));
    let logits = model.forward(&Var::from_tensor(x, false), false)?;
    println!("model(x) = {}", logits.data());
    println!("parameters: {}", model.num_parameters());

    Ok(())
}
