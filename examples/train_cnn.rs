//! Train a small CNN (conv → relu → maxpool → dense) on synthetic-MNIST
//! images — exercises the paper's eq-6 convolution path end to end.
//!
//! ```bash
//! cargo run --release --example train_cnn
//! ```

use minitensor::autograd::Var;
use minitensor::data::{synthetic_mnist, DataLoader, Rng};
use minitensor::nn::{losses, Conv2d, Dense, Module};
use minitensor::optim::{Adam, Optimizer};

fn main() -> minitensor::Result<()> {
    let side = 12;
    let ds = synthetic_mnist(1024, side, 7);
    let (train, test) = ds.split(0.9);
    println!(
        "synthetic-MNIST: {} train / {} test, {}x{side} images, 10 classes",
        train.len(),
        test.len(),
        side
    );

    let mut rng = Rng::new(42);
    let conv1 = Conv2d::new(1, 8, 3, 1, 1, &mut rng); // [b,8,12,12]
    let conv2 = Conv2d::new(8, 16, 3, 1, 1, &mut rng); // after pool: [b,16,6,6]
    let head = Dense::new(16 * 3 * 3, 10, &mut rng);
    let mut params = conv1.parameters();
    params.extend(conv2.parameters());
    params.extend(head.parameters());
    let n_params: usize = params.iter().map(|p| p.data().numel()).sum();
    println!("model parameters: {n_params}");

    let forward = |x: &Var, train_mode: bool| -> minitensor::Result<Var> {
        let b = x.dims()[0];
        let img = x.reshape(&[b, 1, side, side])?;
        let c1 = conv1.forward(&img, train_mode)?.relu().max_pool2d(2)?; // [b,8,6,6]
        let c2 = conv2.forward(&c1, train_mode)?.relu().max_pool2d(2)?; // [b,16,3,3]
        let flat = c2.reshape(&[b, 16 * 3 * 3])?;
        head.forward(&flat, train_mode)
    };

    let mut opt = Adam::new(params, 1e-3);
    let mut loader = DataLoader::new(train.clone(), 32, true, 1).drop_last();
    let steps = 120;
    println!("\nstep, loss");
    let t0 = std::time::Instant::now();
    let mut step = 0;
    while step < steps {
        let Some(batch) = loader.next() else {
            loader.reset();
            continue;
        };
        let x = Var::from_tensor(batch.x, false);
        let logits = forward(&x, true)?;
        let loss = losses::cross_entropy(&logits, &batch.y)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("{step}, {:.5}", loss.item()?);
        }
        opt.zero_grad();
        loss.backward()?;
        opt.step()?;
        step += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // Test accuracy.
    let acc = minitensor::autograd::no_grad(|| -> minitensor::Result<f32> {
        let x = Var::from_tensor(test.x.clone(), false);
        let logits = forward(&x, false)?;
        losses::accuracy(&logits.data(), &test.y)
    })?;
    println!(
        "\ntest accuracy: {acc:.3}  ({steps} steps in {elapsed:.1}s, {:.1} steps/s)",
        steps as f64 / elapsed
    );
    assert!(acc > 0.5, "CNN should beat chance comfortably");
    Ok(())
}
