//! End-to-end driver (DESIGN.md experiment C5): train an MLP classifier
//! on synthetic-MNIST with BOTH backends and log the loss curves.
//!
//! - native: Rust autograd tape + Adam
//! - xla:    the fused AOT `mlp_train_step` HLO executable via PJRT
//!           (requires `make artifacts`)
//!
//! ```bash
//! make artifacts && cargo run --release --example train_mlp
//! ```

use minitensor::coordinator::{Backend, Config, TrainConfig, Trainer};

fn run(backend: Backend) -> minitensor::Result<()> {
    let cfg = Config::parse(
        "[train]\n\
         dataset = synthetic_mnist\n\
         n_examples = 2048\n\
         input_side = 14\n\
         hidden = 128,64\n\
         classes = 10\n\
         optimizer = sgd\n\
         momentum = 0.0\n\
         lr = 0.05\n\
         batch_size = 64\n\
         steps = 300\n\
         log_every = 20\n",
    )?;
    let mut tc = TrainConfig::from_config(&cfg)?;
    tc.backend = backend;
    // Resolve artifacts relative to the repo even if run from elsewhere.
    if !std::path::Path::new(&tc.artifacts_dir).exists() {
        tc.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    }

    println!("\n=== backend: {backend} ===");
    let trainer = Trainer::new(tc);
    match trainer.run() {
        Ok(report) => {
            println!("step, loss");
            for (s, l) in &report.losses {
                println!("{s}, {l:.5}");
            }
            println!(
                "params={}  initial={:.4}  final={:.4}  acc={}  steps/s={:.1}",
                report.num_parameters,
                report.initial_loss,
                report.final_loss,
                report
                    .accuracy
                    .map_or("n/a".into(), |a| format!("{a:.3}")),
                report.steps_per_sec
            );
            assert!(
                report.final_loss < report.initial_loss,
                "loss must descend (paper §5)"
            );
        }
        Err(e) if backend == Backend::Xla => {
            println!("xla backend unavailable ({e}); run `make artifacts` first");
        }
        Err(e) => return Err(e),
    }
    Ok(())
}

fn main() -> minitensor::Result<()> {
    run(Backend::Native)?;
    run(Backend::Xla)?;
    Ok(())
}
