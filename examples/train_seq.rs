//! Sequence classification with the extension operators: Embedding →
//! scaled-dot-product attention (as fixed mixing) → Dense head, trained
//! with AdaGrad + gradient clipping on a synthetic token task.
//!
//! Task: a sequence of 8 token ids from a 32-symbol vocabulary is
//! labelled by which of 4 "marker" tokens appears in it — solvable only
//! by aggregating information across positions, which the attention
//! mixing provides.
//!
//! ```bash
//! cargo run --release --example train_seq
//! ```

use minitensor::autograd::Var;
use minitensor::data::Rng;
use minitensor::nn::{losses, Dense, Embedding, Module};
use minitensor::optim::{clip_grad_norm, AdaGrad, Optimizer};
use minitensor::tensor::Tensor;

const VOCAB: usize = 32;
const SEQ: usize = 8;
const DIM: usize = 16;
const CLASSES: usize = 4;

/// One synthetic example: random tokens with exactly one marker token
/// (ids 0..4) placed at a random position; the label is the marker id.
fn make_batch(n: usize, rng: &mut Rng) -> (Tensor, Tensor) {
    let mut ids = Vec::with_capacity(n * SEQ);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.next_below(CLASSES as u32) as i32;
        let pos = rng.next_below(SEQ as u32) as usize;
        for s in 0..SEQ {
            if s == pos {
                ids.push(class);
            } else {
                // filler tokens never collide with markers
                ids.push(CLASSES as i32 + rng.next_below((VOCAB - CLASSES) as u32) as i32);
            }
        }
        labels.push(class);
    }
    (
        Tensor::from_vec_i32(ids, &[n * SEQ]).unwrap(),
        Tensor::from_vec_i32(labels, &[n]).unwrap(),
    )
}

fn main() -> minitensor::Result<()> {
    let mut rng = Rng::new(42);
    let emb = Embedding::new(VOCAB, DIM, &mut rng);
    let head = Dense::new(DIM, CLASSES, &mut rng);
    let mut params = emb.parameters();
    params.extend(head.parameters());
    let mut opt = AdaGrad::new(params.clone(), 0.15);

    println!(
        "sequence task: vocab={VOCAB} seq={SEQ} dim={DIM} classes={CLASSES}, {} params",
        emb.num_parameters() + head.num_parameters()
    );

    let batch = 64;
    println!("\nstep, loss, grad_norm");
    let mut final_loss = f32::NAN;
    for step in 0..250 {
        let (ids, labels) = make_batch(batch, &mut rng);
        // [b*seq, dim] → mean-pool over positions after attention mixing
        let tokens = emb.lookup(&ids)?; // [b*seq, dim]
        // attention within each sequence: process per-example (seq x dim)
        // reshaped as a batch of independent attention calls via the
        // native op on the detached value path + recorded mean-pooling.
        let x = tokens.reshape(&[batch, SEQ, DIM])?;
        // mean over positions of attention-mixed tokens: with q=k=v the
        // mixing is content-based; implemented with recorded primitives:
        let pooled = x.mean_axis(1, false)?; // [b, dim]
        let logits = head.forward(&pooled, true)?;
        let loss = losses::cross_entropy(&logits, &labels)?;
        final_loss = loss.item()?;

        opt.zero_grad();
        loss.backward()?;
        let gnorm = clip_grad_norm(&params, 5.0)?;
        opt.step()?;
        if step % 25 == 0 || step == 249 {
            println!("{step}, {final_loss:.4}, {gnorm:.3}");
        }
    }

    // Evaluation with the *native attention op* sharpening the pooled
    // representation at inference time (content-based mixing).
    let (ids, labels) = make_batch(256, &mut rng);
    let acc = minitensor::autograd::no_grad(|| -> minitensor::Result<f32> {
        let tokens = emb.lookup(&ids)?.data(); // [256*SEQ, DIM]
        let mut correct = 0usize;
        for i in 0..256 {
            let seq = tokens.narrow(0, i * SEQ, SEQ)?.contiguous(); // [SEQ, DIM]
            let mixed = seq.attention(&seq, &seq)?; // self-attention mixing
            let pooled = mixed.mean_axis(0, false)?.reshape(&[1, DIM])?;
            let logits = head.forward(&Var::from_tensor(pooled, false), false)?;
            let pred = logits.data().argmax_axis(1)?.item()? as i32;
            if pred == labels.at(&[i])? as i32 {
                correct += 1;
            }
        }
        Ok(correct as f32 / 256.0)
    })?;

    println!("\nfinal loss {final_loss:.4}, eval accuracy (with attention mixing) {acc:.3}");
    assert!(final_loss < 1.0, "loss should descend below ln(4)≈1.386");
    Ok(())
}
