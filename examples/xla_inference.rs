//! Serve batched inference through the AOT XLA forward executable —
//! the full three-layer stack on the request path: Rust coordinator →
//! PJRT executable ← (built once from JAX + Pallas kernels).
//!
//! Each server worker constructs its own [`XlaBatchModel`] (engine +
//! loaded executable) on its own thread via [`FactoryFn`], so replicas
//! never cross threads and no `unsafe impl Send` is needed.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_inference
//! ```

use std::sync::Arc;
use std::time::Instant;

use minitensor::coordinator::{BatchModel, FactoryFn, InferenceServer, ServeConfig};
use minitensor::data::Rng;
use minitensor::error::Result;
use minitensor::nn::kaiming_uniform;
use minitensor::runtime::Engine;
use minitensor::tensor::Tensor;

/// BatchModel backed by the `mlp_forward` artifact. The artifact has a
/// fixed batch dimension, so partial batches are padded and sliced.
struct XlaBatchModel {
    engine: Engine,
    params: Vec<Tensor>,
    batch: usize,
    in_features: usize,
}

impl XlaBatchModel {
    fn new(artifacts_dir: &str) -> Result<XlaBatchModel> {
        let mut engine = Engine::cpu(artifacts_dir)?;
        let art = engine.manifest().get("mlp_forward")?.clone();
        let batch = art.input_shapes[0][0];
        let in_features = art.input_shapes[0][1];
        // Deterministic seed: every worker replica materialises the
        // same weights, so replies are replica-independent.
        let mut rng = Rng::new(123);
        let params: Vec<Tensor> = art.input_shapes[1..]
            .iter()
            .map(|s| {
                if s.len() == 2 {
                    kaiming_uniform(s, s[1], &mut rng)
                } else {
                    Tensor::zeros(s)
                }
            })
            .collect();
        engine.load("mlp_forward")?; // compile up front, off the hot path
        Ok(XlaBatchModel {
            engine,
            params,
            batch,
            in_features,
        })
    }
}

impl BatchModel for XlaBatchModel {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        let b = x.dims()[0];
        // Pad to the artifact's fixed batch.
        let padded = if b == self.batch {
            x.clone()
        } else {
            let mut data = x.to_vec();
            data.resize(self.batch * self.in_features, 0.0);
            Tensor::from_vec(data, &[self.batch, self.in_features])?
        };
        let mut inputs: Vec<&Tensor> = vec![&padded];
        inputs.extend(self.params.iter());
        let out = self.engine.run("mlp_forward", &inputs)?.remove(0);
        Ok(out.narrow(0, 0, b)?.contiguous())
    }

    fn in_features(&self) -> usize {
        self.in_features
    }
}

fn main() -> Result<()> {
    // Probe the artifact once for its fixed shapes (and to fail fast if
    // it is missing); the serving replicas are built by the factory.
    let probe = match XlaBatchModel::new("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifacts not available ({e}); run `make artifacts` first");
            return Ok(());
        }
    };
    let in_features = probe.in_features;
    let max_batch = probe.batch;
    drop(probe);
    println!(
        "serving mlp_forward artifact (batch={max_batch}, features={in_features}) on PJRT"
    );

    let factory = FactoryFn::new(in_features, |_worker| {
        let m: Box<dyn BatchModel> = Box::new(XlaBatchModel::new("artifacts")?);
        Ok(m)
    });
    let cfg = ServeConfig::new()
        .max_batch(max_batch)
        .max_wait_ms(5)
        .queue_depth(512)
        .build()?;
    let server = Arc::new(InferenceServer::start(factory, cfg)?);

    // Closed-loop clients hammer the server.
    let n_clients = 4;
    let per_client = 256;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let s = server.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                for _ in 0..per_client {
                    let feats: Vec<f32> =
                        (0..in_features).map(|_| rng.next_f32()).collect();
                    let logits = s.infer(feats).expect("infer");
                    assert_eq!(logits.len(), 10);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "{} requests in {:.2}s — {:.0} req/s | {} batches, mean size {:.1} | latency p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        stats.requests,
        elapsed,
        stats.requests as f64 / elapsed,
        stats.batches,
        stats.mean_batch_size,
        stats.p50_latency_ms,
        stats.p95_latency_ms,
        stats.p99_latency_ms,
    );
    Ok(())
}
