"""Build-time compile package: JAX model (L2) + Pallas kernels (L1) + AOT
exporter. Never imported at run time — the Rust coordinator consumes only
the HLO-text artifacts this package writes."""
