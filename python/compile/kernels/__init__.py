"""Layer-1 Pallas kernels for MiniTensor's compute hot-spots.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is both the correctness
path and what gets lowered into the AOT artifacts. The BlockSpecs are
still written TPU-shaped (MXU-aligned tiles sized for VMEM) so the same
kernels compile for real TPUs unchanged — see DESIGN.md
§Hardware-Adaptation.
"""

from .attention import attention_pallas
from .matmul import matmul_pallas
from .fused_linear import fused_linear_pallas
from .softmax import log_softmax_pallas, softmax_pallas

__all__ = [
    "attention_pallas",
    "matmul_pallas",
    "fused_linear_pallas",
    "softmax_pallas",
    "log_softmax_pallas",
]
