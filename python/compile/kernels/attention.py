"""Fused scaled-dot-product attention Pallas kernel.

An extension kernel (paper §7 roadmap: "broaden operator coverage")
showing the VMEM-fusion idea at its best: for each Q row-block the
scores, the stable softmax, and the value contraction all happen in one
VMEM residency — the S = QKᵀ matrix is never written to HBM.

Tiling: the grid walks Q row-blocks; K and V stay VMEM-resident across
the grid (seq·d ≤ 1024·128 f32 ≈ 0.5 MiB each — comfortably inside the
~16 MiB budget). For longer sequences the K/V axis would be blocked too,
with running max/sum corrections (the FlashAttention recurrence); at the
sequence lengths this repo serves, whole-K residency is both simpler and
faster.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import block_dim


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...]  # [bq, d]
    k = k_ref[...]  # [n, d]
    v = v_ref[...]  # [n, d]
    # scores: [bq, n] — contract the feature axis of q with that of k.
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale
    # stable row softmax, entirely in VMEM
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, interpret: bool = True
) -> jax.Array:
    """``softmax(q kᵀ / √d) v`` over ``[seq, d]`` inputs, fused per
    Q row-block."""
    sq, d = q.shape
    sk, d2 = k.shape
    assert d == d2 and v.shape == (sk, d), (q.shape, k.shape, v.shape)
    scale = 1.0 / (d ** 0.5)
    bq = block_dim(sq)
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(sq // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),  # K resident
            pl.BlockSpec((sk, d), lambda i: (0, 0)),  # V resident
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def attention_vmem_bytes(seq: int, d: int) -> int:
    """Estimated VMEM per program: Q tile + K + V + S tile + O tile."""
    bq = block_dim(seq)
    return 4 * (bq * d + 2 * seq * d + bq * seq + bq * d)
