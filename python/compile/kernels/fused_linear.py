"""Fused dense layer kernel: ``act(x @ W^T + b)`` in one VMEM pass.

The paper's Dense layer (eq 5) followed by a nonlinearity is the MLP's
inner loop. Fusing the bias add and activation into the matmul epilogue
keeps the (bm, bn) output tile in VMEM instead of round-tripping to HBM
between three kernels — the Pallas analogue of the paper's §3.5 "inner
loops written to encourage auto-vectorization".

W is stored PyTorch-style ``[d_out, d_in]`` and read transposed by the
BlockSpec index map, so no separate transpose pass is needed (mirrors the
Rust engine's ``matmul_nt``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import block_dim

_ACTS = {
    "id": lambda v: v,
    "relu": lambda v: jnp.maximum(v, 0.0),
    "tanh": jnp.tanh,
    "gelu": lambda v: 0.5 * v * (1.0 + jnp.tanh(0.7978845608 * (v + 0.044715 * v * v * v))),
}


def _fused_linear_kernel(x_ref, wt_ref, b_ref, o_ref, *, n_k: int, act: str):
    """Grid (i, j, k): accumulate x_tile @ w_tile^T; epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # wt tile arrives as [bn, bk] (a [d_out, d_in] block); contract in-kernel.
    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        wt_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = _ACTS[act](o_ref[...] + b_ref[...])


def _fused_linear_raw(
    x: jax.Array, w: jax.Array, b: jax.Array, act: str, interpret: bool
) -> jax.Array:
    m, d_in = x.shape
    d_out, d_in2 = w.shape
    assert d_in == d_in2, f"inner dims mismatch: {d_in} vs {d_in2}"
    assert b.shape == (d_out,)
    assert act in _ACTS, f"unknown activation '{act}'"
    bm, bk, bn = block_dim(m), block_dim(d_in), block_dim(d_out)
    n_k = d_in // bk
    grid = (m // bm, d_out // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), jnp.float32),
        interpret=interpret,
    )(x, w, b)


def _act_grad(z: jax.Array, act: str) -> jax.Array:
    """dact/dz evaluated at the pre-activation z."""
    if act == "id":
        return jnp.ones_like(z)
    if act == "relu":
        return (z > 0.0).astype(z.dtype)
    if act == "tanh":
        t = jnp.tanh(z)
        return 1.0 - t * t
    if act == "gelu":
        u = 0.7978845608 * (z + 0.044715 * z**3)
        t = jnp.tanh(u)
        du = 0.7978845608 * (1.0 + 3 * 0.044715 * z * z)
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
    raise ValueError(act)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_linear_pallas(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    act: str = "relu",
    interpret: bool = True,
) -> jax.Array:
    """``act(x [m,d_in] @ w[d_out,d_in]^T + b[d_out])`` fused in one kernel.

    The custom VJP implements the paper's Dense pullbacks (eq 4 composed
    with the activation derivative): ``dz = ḡ ⊙ act'(z)``, ``x̄ = dz W``,
    ``W̄ = dzᵀ x``, ``b̄ = Σ_batch dz``, with z rematerialized by the same
    kernel (act="id") instead of stored — the §3.5 lazy-buffer idea.
    """
    return _fused_linear_raw(x, w, b, act, interpret)


def _fused_linear_fwd(x, w, b, act, interpret):
    return _fused_linear_raw(x, w, b, act, interpret), (x, w, b)


def _fused_linear_bwd(act, interpret, res, g):
    from .matmul import _matmul_raw

    x, w, b = res
    z = _fused_linear_raw(x, w, b, "id", interpret)  # rematerialize
    dz = g * _act_grad(z, act)
    dx = _matmul_raw(dz, w, interpret)  # [m,dout] @ [dout,din]
    dw = _matmul_raw(dz.T, x, interpret)  # [dout,m] @ [m,din]
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear_pallas.defvjp(_fused_linear_fwd, _fused_linear_bwd)
