"""Tiled Pallas matmul kernel (the paper's eq-1 hot spot).

TPU mapping: the grid walks (M/bm, N/bn, K/bk) tiles; each program
multiplies a VMEM-resident (bm, bk) x-tile by a (bk, bn) w-tile on the
MXU via ``jnp.dot(..., preferred_element_type=f32)`` and accumulates
into the (bm, bn) output tile, which Pallas keeps resident across the
sequential K steps. Block sizes default to 128 — the MXU systolic-array
edge — and shrink to divisors for small inputs. VMEM per program =
(bm·bk + bk·bn + bm·bn)·4 B ≈ 192 KiB at 128³, comfortably inside the
~16 MiB/core budget with double-buffering headroom.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (i, j, k) grid step: o += x_tile @ w_tile (o zeroed at k=0)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def block_dim(dim: int, target: int = 128) -> int:
    """Largest divisor of ``dim`` ≤ ``target`` (MXU-aligned when possible)."""
    if dim % target == 0:
        return target
    best = 1
    for cand in range(1, min(dim, target) + 1):
        if dim % cand == 0:
            best = cand
    return best


def _matmul_raw(x: jax.Array, w: jax.Array, interpret: bool) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bk, bn = block_dim(m), block_dim(k), block_dim(n)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul_pallas(x: jax.Array, w: jax.Array, interpret: bool = True) -> jax.Array:
    """``x [m, k] @ w [k, n]`` via the tiled Pallas kernel.

    Carries an explicit custom VJP — the paper's eq-4 pullbacks
    (``x̄ = ȳ wᵀ``, ``w̄ = xᵀ ȳ``) expressed with the same kernel — so
    reverse-mode AD never needs to trace inside the pallas_call.
    """
    return _matmul_raw(x, w, interpret)


def _matmul_fwd(x, w, interpret):
    return _matmul_raw(x, w, interpret), (x, w)


def _matmul_bwd(interpret, res, g):
    x, w = res
    dx = _matmul_raw(g, w.T, interpret)
    dw = _matmul_raw(x.T, g, interpret)
    return dx, dw


matmul_pallas.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_vmem_bytes(m: int, k: int, n: int) -> int:
    """Estimated VMEM footprint per program (DESIGN.md §Perf)."""
    bm, bk, bn = block_dim(m), block_dim(k), block_dim(n)
    return 4 * (bm * bk + bk * bn + bm * bn)
