"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
reference (paper §5: unit tests validate autograd rules and kernels
against known-good math)."""

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain jnp matmul."""
    return jnp.matmul(x, w)


def fused_linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    """``act(x @ w^T + b)`` in plain jnp."""
    y = x @ w.T + b
    if act == "id":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "tanh":
        return jnp.tanh(y)
    if act == "gelu":
        return 0.5 * y * (1.0 + jnp.tanh(0.7978845608 * (y + 0.044715 * y**3)))
    raise ValueError(f"unknown activation '{act}'")


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled-dot-product attention in plain jnp."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = (q @ k.T) * scale
    return jax.nn.softmax(s, axis=-1) @ v


def softmax_ref(x: jax.Array) -> jax.Array:
    """Row-wise stable softmax."""
    return jax.nn.softmax(x, axis=-1)


def log_softmax_ref(x: jax.Array) -> jax.Array:
    """Row-wise stable log-softmax."""
    return jax.nn.log_softmax(x, axis=-1)
