"""Numerically stable softmax / log-softmax Pallas kernels.

One program per row-tile: the full class dimension lives in a single
VMEM block (classes ≤ a few thousand fit trivially), the max-shift
reduction happens along the lane axis, and the normalized result is
written back in the same pass — no HBM round-trip between max, exp and
sum (the paper's eq-8 loss path).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import block_dim


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _log_softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    shifted = x - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    o_ref[...] = shifted - lse


def _rowwise_call(kernel, x: jax.Array, interpret: bool) -> jax.Array:
    rows, cols = x.shape
    br = block_dim(rows)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def softmax_pallas(x: jax.Array, interpret: bool = True) -> jax.Array:
    """Row-wise softmax over ``[rows, classes]``.

    Custom VJP: ``x̄ = (ḡ − Σ(ḡ ⊙ y)) ⊙ y`` — the classic simplex pullback.
    """
    return _rowwise_call(_softmax_kernel, x, interpret)


def _softmax_fwd(x, interpret):
    y = _rowwise_call(_softmax_kernel, x, interpret)
    return y, y


def _softmax_bwd(interpret, y, g):
    dot = jnp.sum(g * y, axis=-1, keepdims=True)
    return ((g - dot) * y,)


softmax_pallas.defvjp(_softmax_fwd, _softmax_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def log_softmax_pallas(x: jax.Array, interpret: bool = True) -> jax.Array:
    """Row-wise log-softmax over ``[rows, classes]``.

    Custom VJP: ``x̄ = ḡ − softmax(x) · Σḡ`` (paper §3.2 pullback; the
    softmax is recovered as ``exp(y)`` from the saved output).
    """
    return _rowwise_call(_log_softmax_kernel, x, interpret)


def _log_softmax_fwd(x, interpret):
    y = _rowwise_call(_log_softmax_kernel, x, interpret)
    return y, y


def _log_softmax_bwd(interpret, y, g):
    gsum = jnp.sum(g, axis=-1, keepdims=True)
    return (g - jnp.exp(y) * gsum,)


log_softmax_pallas.defvjp(_log_softmax_fwd, _log_softmax_bwd)
