"""Layer-2: the MLP classifier in JAX, built on the L1 Pallas kernels.

This is the paper's "end-to-end examples that train small models" (§5)
expressed as a JAX compute graph:

- ``mlp_forward``      — logits = Dense→ReLU→Dense→ReLU→Dense (eq 5)
- ``mlp_loss``         — mean softmax cross-entropy (eq 8)
- ``mlp_train_step``   — one fused SGD step: loss + grads (reverse mode,
  eqs 2–4, via ``jax.grad``) + parameter update (eq 9), returned as new
  parameters. Lowered to a single HLO module so the Rust trainer executes
  the entire step in one PJRT call.

Parameters follow the Rust engine's Dense layout: W ``[d_out, d_in]``,
b ``[d_out]`` — the same tensors can drive either backend.
"""

from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import fused_linear_pallas, log_softmax_pallas

# Default architecture baked into the AOT artifacts; must line up with
# rust TrainConfig::defaults() (input_side=14 → 196 features).
BATCH = 64
IN_FEATURES = 196
HIDDEN = (128, 64)
CLASSES = 10
LR = 0.05


def param_shapes(
    in_features: int = IN_FEATURES,
    hidden: Sequence[int] = HIDDEN,
    classes: int = CLASSES,
):
    """[(w_shape, b_shape), ...] for each Dense layer."""
    dims = [in_features, *hidden, classes]
    return [((dims[i + 1], dims[i]), (dims[i + 1],)) for i in range(len(dims) - 1)]


def init_params(key, in_features=IN_FEATURES, hidden=HIDDEN, classes=CLASSES):
    """Kaiming-uniform init matching the Rust engine."""
    params = []
    for (w_shape, b_shape) in param_shapes(in_features, hidden, classes):
        key, sub = jax.random.split(key)
        bound = (6.0 / w_shape[1]) ** 0.5
        w = jax.random.uniform(sub, w_shape, jnp.float32, -bound, bound)
        params.extend([w, jnp.zeros(b_shape, jnp.float32)])
    return params


def mlp_forward(x: jax.Array, *params: jax.Array) -> jax.Array:
    """Logits for a batch. Hidden layers use the fused linear+ReLU Pallas
    kernel; the output layer is fused linear with identity epilogue."""
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "relu" if i < n_layers - 1 else "id"
        h = fused_linear_pallas(h, w, b, act=act)
    return h


def mlp_loss(x: jax.Array, y_onehot: jax.Array, *params: jax.Array) -> jax.Array:
    """Mean cross-entropy (eq 8) using the Pallas log-softmax kernel."""
    logits = mlp_forward(x, *params)
    logp = log_softmax_pallas(logits)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def mlp_train_step(x: jax.Array, y_onehot: jax.Array, *params: jax.Array):
    """One fused SGD step (eq 9 with μ=0, λ=0): returns (loss, *new_params).

    ``jax.grad`` runs reverse-mode AD through the Pallas kernels — the same
    vector-Jacobian chain (eqs 2–4) the Rust tape implements natively.
    """
    loss, grads = jax.value_and_grad(
        lambda ps: mlp_loss(x, y_onehot, *ps)
    )(list(params))
    new_params = [p - LR * g for p, g in zip(params, grads)]
    return (loss, *new_params)


def matmul_entry(x: jax.Array, w: jax.Array) -> jax.Array:
    """Standalone matmul entry point (bench C1/C4 artifact)."""
    from .kernels import matmul_pallas

    return matmul_pallas(x, w)


def elementwise_entry(a: jax.Array, b: jax.Array):
    """Fused elementwise chain used by the C1 comparison artifact:
    relu(a * b + a). One XLA fusion — the 'optimized production backend'
    stand-in for the paper's §6 constant-factor claim."""
    return (jnp.maximum(a * b + a, 0.0),)


def reduction_entry(a: jax.Array):
    """Full-array sum and mean (C1 reductions artifact)."""
    return (jnp.sum(a), jnp.mean(a))


def attention_entry(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Fused scaled-dot-product attention (extension kernel)."""
    from .kernels import attention_pallas

    return attention_pallas(q, k, v)
