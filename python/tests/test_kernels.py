"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
swept over shapes/values with hypothesis (the CORE correctness signal of
the AOT path — paper §5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention_pallas,
    fused_linear_pallas,
    log_softmax_pallas,
    matmul_pallas,
    softmax_pallas,
)
from compile.kernels.attention import attention_vmem_bytes
from compile.kernels import ref
from compile.kernels.matmul import block_dim, matmul_vmem_bytes

dims = st.sampled_from([1, 2, 3, 5, 8, 16, 32, 64, 128, 160, 256])
small_dims = st.sampled_from([1, 2, 4, 8, 10, 16, 33])
ACTS = ["id", "relu", "tanh", "gelu"]


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestBlockDim:
    def test_mxu_aligned_when_divisible(self):
        assert block_dim(256) == 128
        assert block_dim(128) == 128
        assert block_dim(1024) == 128

    def test_divisor_fallback(self):
        assert block_dim(96) == 96
        assert block_dim(33) == 33
        assert block_dim(7) == 7
        assert block_dim(1) == 1

    @given(st.integers(1, 2048))
    @settings(max_examples=50, deadline=None)
    def test_always_divides(self, n):
        b = block_dim(n)
        assert n % b == 0
        assert 1 <= b <= 128 or b == n

    def test_vmem_budget_at_max_tiles(self):
        # 128³ tiles: 3 × 64 KiB = 192 KiB — way under the ~16 MiB VMEM.
        assert matmul_vmem_bytes(1024, 1024, 1024) == 4 * 3 * 128 * 128
        assert matmul_vmem_bytes(1024, 1024, 1024) < 16 * 2**20


class TestMatmul:
    @given(m=dims, k=dims, n=dims)
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, m, k, n):
        x = rand(m * 1000 + k, m, k)
        w = rand(n * 1000 + k + 1, k, n)
        got = matmul_pallas(x, w)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_identity(self):
        x = rand(0, 32, 32)
        np.testing.assert_allclose(matmul_pallas(x, jnp.eye(32)), x, rtol=1e-6)

    def test_zeros(self):
        x = rand(1, 16, 8)
        z = jnp.zeros((8, 4), jnp.float32)
        assert jnp.all(matmul_pallas(x, z) == 0.0)

    def test_mismatched_inner_dims_raise(self):
        with pytest.raises(AssertionError):
            matmul_pallas(rand(2, 4, 5), rand(3, 6, 4))

    def test_grad_matches_ref_grad(self):
        x = rand(4, 16, 24)
        w = rand(5, 24, 8)
        gx, gw = jax.grad(lambda a, b: jnp.sum(matmul_pallas(a, b) ** 2), (0, 1))(x, w)
        rx, rw = jax.grad(lambda a, b: jnp.sum(ref.matmul_ref(a, b) ** 2), (0, 1))(x, w)
        np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)

    def test_jit_compatible(self):
        f = jax.jit(lambda a, b: matmul_pallas(a, b))
        x, w = rand(6, 64, 64), rand(7, 64, 64)
        np.testing.assert_allclose(f(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


class TestFusedLinear:
    @given(m=small_dims, d_in=small_dims, d_out=small_dims, act=st.sampled_from(ACTS))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, m, d_in, d_out, act):
        x = rand(m + d_in, m, d_in)
        w = rand(d_out + d_in + 1, d_out, d_in)
        b = rand(d_out + 2, d_out)
        got = fused_linear_pallas(x, w, b, act)
        want = ref.fused_linear_ref(x, w, b, act)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_bias_broadcast(self):
        x = jnp.zeros((4, 8), jnp.float32)
        w = jnp.zeros((3, 8), jnp.float32)
        b = jnp.asarray([1.0, -2.0, 3.0], jnp.float32)
        out = fused_linear_pallas(x, w, b, "id")
        np.testing.assert_allclose(out, jnp.tile(b, (4, 1)), rtol=1e-6)

    def test_relu_clamps(self):
        x = rand(10, 16, 8)
        w = rand(11, 4, 8)
        b = rand(12, 4)
        out = fused_linear_pallas(x, w, b, "relu")
        assert jnp.all(out >= 0.0)

    @pytest.mark.parametrize("act", ACTS)
    def test_grads_match_ref(self, act):
        x = rand(20, 8, 12)
        w = rand(21, 6, 12)
        b = rand(22, 6)

        def loss_pallas(x, w, b):
            return jnp.sum(fused_linear_pallas(x, w, b, act) ** 2)

        def loss_ref(x, w, b):
            return jnp.sum(ref.fused_linear_ref(x, w, b, act) ** 2)

        gp = jax.grad(loss_pallas, (0, 1, 2))(x, w, b)
        gr = jax.grad(loss_ref, (0, 1, 2))(x, w, b)
        for a, e in zip(gp, gr):
            np.testing.assert_allclose(a, e, rtol=2e-3, atol=2e-3)

    def test_unknown_act_raises(self):
        with pytest.raises(AssertionError):
            fused_linear_pallas(rand(0, 4, 4), rand(1, 4, 4), rand(2, 4), "swish")


class TestSoftmax:
    @given(rows=small_dims, cols=st.sampled_from([2, 3, 10, 64, 100]))
    @settings(max_examples=20, deadline=None)
    def test_matches_ref(self, rows, cols):
        x = rand(rows * 100 + cols, rows, cols) * 3.0
        np.testing.assert_allclose(
            softmax_pallas(x), ref.softmax_ref(x), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            log_softmax_pallas(x), ref.log_softmax_ref(x), rtol=1e-4, atol=1e-5
        )

    def test_rows_sum_to_one(self):
        x = rand(30, 16, 10)
        s = jnp.sum(softmax_pallas(x), axis=-1)
        np.testing.assert_allclose(s, jnp.ones(16), rtol=1e-5)

    def test_stable_for_large_logits(self):
        x = jnp.asarray([[1000.0, 1000.0, -1000.0]], jnp.float32)
        out = softmax_pallas(x)
        assert jnp.all(jnp.isfinite(out))
        np.testing.assert_allclose(out[0, 0], 0.5, rtol=1e-5)

    def test_shift_invariance(self):
        x = rand(31, 8, 5)
        np.testing.assert_allclose(
            softmax_pallas(x), softmax_pallas(x + 100.0), rtol=1e-4, atol=1e-5
        )

    def test_grads_match_ref(self):
        x = rand(32, 8, 6)
        g = jax.grad(lambda v: jnp.sum(jnp.sin(softmax_pallas(v))))(x)
        r = jax.grad(lambda v: jnp.sum(jnp.sin(ref.softmax_ref(v))))(x)
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-5)

        gl = jax.grad(lambda v: jnp.sum(jnp.cos(log_softmax_pallas(v))))(x)
        rl = jax.grad(lambda v: jnp.sum(jnp.cos(ref.log_softmax_ref(v))))(x)
        np.testing.assert_allclose(gl, rl, rtol=1e-4, atol=1e-5)


class TestAttention:
    @given(
        seq=st.sampled_from([1, 2, 8, 16, 64, 128]),
        d=st.sampled_from([1, 4, 8, 16, 64]),
    )
    @settings(max_examples=15, deadline=None)
    def test_matches_ref(self, seq, d):
        q = rand(seq + d, seq, d)
        k = rand(seq + d + 1, seq, d)
        v = rand(seq + d + 2, seq, d)
        got = attention_pallas(q, k, v)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_uniform_keys_average_values(self):
        # identical keys ⇒ uniform attention ⇒ output = mean of values
        q = rand(40, 4, 8)
        k = jnp.ones((16, 8), jnp.float32)
        v = rand(41, 16, 8)
        out = attention_pallas(q, k, v)
        want = jnp.tile(jnp.mean(v, axis=0), (4, 1))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)

    def test_rows_attend_to_matching_key(self):
        # orthogonal one-hot q/k with large scale ⇒ near-hard attention
        eye = jnp.eye(8, dtype=jnp.float32) * 30.0
        v = rand(42, 8, 8)
        out = attention_pallas(eye, eye, v)
        np.testing.assert_allclose(out, v, rtol=1e-2, atol=1e-2)

    def test_vmem_estimate_within_budget(self):
        # the serving shape must fit VMEM comfortably
        assert attention_vmem_bytes(1024, 128) < 16 * 2**20

    def test_shape_mismatch_raises(self):
        with pytest.raises(AssertionError):
            attention_pallas(rand(0, 8, 4), rand(1, 8, 5), rand(2, 8, 5))
