"""L2 correctness: model shapes, loss semantics, train-step descent, and
agreement between the kernel-built model and a pure-jnp replica."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def make_params(seed=0):
    return model.init_params(jax.random.PRNGKey(seed))


def ref_forward(x, *params):
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "relu" if i < n_layers - 1 else "id"
        h = ref.fused_linear_ref(h, w, b, act)
    return h


class TestForward:
    def test_logits_shape(self):
        params = make_params()
        x = jnp.zeros((model.BATCH, model.IN_FEATURES), jnp.float32)
        logits = model.mlp_forward(x, *params)
        assert logits.shape == (model.BATCH, model.CLASSES)

    def test_matches_pure_jnp_replica(self):
        params = make_params(1)
        x = jax.random.normal(
            jax.random.PRNGKey(2), (model.BATCH, model.IN_FEATURES), jnp.float32
        )
        got = model.mlp_forward(x, *params)
        want = ref_forward(x, *params)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_param_shapes_match_manifest_layout(self):
        shapes = model.param_shapes()
        assert shapes[0][0] == (128, 196)
        assert shapes[-1][0] == (10, 64)
        params = make_params()
        assert len(params) == 2 * len(shapes)


class TestLoss:
    def test_uniform_logits_loss_is_log_c(self):
        # zero params of the last layer ⇒ uniform logits for any input
        params = [jnp.zeros_like(p) for p in make_params()]
        x = jnp.ones((model.BATCH, model.IN_FEATURES), jnp.float32)
        y = jax.nn.one_hot(jnp.zeros(model.BATCH, jnp.int32), model.CLASSES)
        loss = model.mlp_loss(x, y, *params)
        np.testing.assert_allclose(loss, jnp.log(model.CLASSES), rtol=1e-5)

    def test_loss_positive_and_finite(self):
        params = make_params(3)
        x = jax.random.normal(
            jax.random.PRNGKey(4), (model.BATCH, model.IN_FEATURES), jnp.float32
        )
        labels = jax.random.randint(jax.random.PRNGKey(5), (model.BATCH,), 0, model.CLASSES)
        y = jax.nn.one_hot(labels, model.CLASSES)
        loss = model.mlp_loss(x, y, *params)
        assert jnp.isfinite(loss) and loss > 0.0


class TestTrainStep:
    def test_descends_on_fixed_batch(self):
        params = make_params(6)
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (model.BATCH, model.IN_FEATURES), jnp.float32)
        labels = jax.random.randint(jax.random.PRNGKey(8), (model.BATCH,), 0, model.CLASSES)
        y = jax.nn.one_hot(labels, model.CLASSES)
        losses = []
        for _ in range(15):
            loss, *params = model.mlp_train_step(x, y, *params)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses

    def test_returns_same_shapes(self):
        params = make_params(9)
        x = jnp.zeros((model.BATCH, model.IN_FEATURES), jnp.float32)
        y = jax.nn.one_hot(jnp.zeros(model.BATCH, jnp.int32), model.CLASSES)
        out = model.mlp_train_step(x, y, *params)
        assert len(out) == 1 + len(params)
        for new, old in zip(out[1:], params):
            assert new.shape == old.shape

    def test_grad_direction_matches_ref_model(self):
        """Gradients through the Pallas model equal gradients through the
        jnp replica (eq 2-4 chain)."""
        params = make_params(10)
        x = jax.random.normal(
            jax.random.PRNGKey(11), (model.BATCH, model.IN_FEATURES), jnp.float32
        )
        labels = jax.random.randint(jax.random.PRNGKey(12), (model.BATCH,), 0, model.CLASSES)
        y = jax.nn.one_hot(labels, model.CLASSES)

        def loss_pallas(ps):
            return model.mlp_loss(x, y, *ps)

        def loss_ref(ps):
            logits = ref_forward(x, *ps)
            return -jnp.mean(jnp.sum(y * ref.log_softmax_ref(logits), axis=-1))

        gp = jax.grad(loss_pallas)(list(params))
        gr = jax.grad(loss_ref)(list(params))
        for a, e in zip(gp, gr):
            np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-4)


class TestAotEntries:
    def test_all_entries_lower_to_hlo(self):
        from compile.aot import entries, to_hlo_text

        for name, fn, in_specs in entries():
            lowered = jax.jit(fn).lower(*in_specs)
            text = to_hlo_text(lowered)
            assert "HloModule" in text, name
            assert len(text) > 100, name

    def test_manifest_shapes_agree_with_eval_shape(self):
        from compile.aot import entries, shape_str

        for name, fn, in_specs in entries():
            outs = jax.eval_shape(fn, *in_specs)
            assert len(outs) >= 1, name
            for o in outs:
                # shape_str round-trips
                s = shape_str(o.shape)
                assert isinstance(s, str) and len(s) > 0
