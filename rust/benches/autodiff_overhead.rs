//! Experiment C3 — §3.2: "reverse mode computes all parameter gradients
//! with time complexity proportional to a small constant multiple of the
//! forward cost". Measures (forward+backward)/forward across MLP sizes.

use minitensor::autograd::Var;
use minitensor::bench_util::{bench, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::nn::{losses, Activation, Dense, Module, Sequential};
use minitensor::tensor::Tensor;

fn mlp(rng: &mut Rng, dims: &[usize]) -> Sequential {
    let mut model = Sequential::new();
    for i in 0..dims.len() - 1 {
        model = model.add(Dense::new(dims[i], dims[i + 1], rng));
        if i + 2 < dims.len() {
            model = model.add(Activation::Relu);
        }
    }
    model
}

fn main() {
    let mut rng = Rng::new(4);
    let mut t = Table::new(
        "C3 — autodiff overhead ratio (paper §3.2)",
        &["model", "params", "forward", "fwd+bwd", "ratio"],
    );

    let configs: &[(&str, Vec<usize>, usize)] = &[
        ("tiny 32-32-10", vec![32, 32, 10], 64),
        ("small 196-128-64-10", vec![196, 128, 64, 10], 64),
        ("wide 512-512-10", vec![512, 512, 10], 64),
        ("deep 64x6-10", vec![64, 64, 64, 64, 64, 64, 10], 64),
    ];

    for (name, dims, batch) in configs {
        let model = mlp(&mut rng, dims);
        let x = Tensor::randn(&[*batch, dims[0]], 0.0, 1.0, &mut rng);
        let labels_vec: Vec<i32> = (0..*batch)
            .map(|i| (i % dims[dims.len() - 1]) as i32)
            .collect();
        let labels = Tensor::from_vec_i32(labels_vec, &[*batch]).unwrap();

        let fwd = bench(&format!("fwd {name}"), 80.0, 7, || {
            minitensor::autograd::no_grad(|| {
                let v = Var::from_tensor(x.clone(), false);
                let logits = model.forward(&v, true).unwrap();
                std::hint::black_box(losses::cross_entropy(&logits, &labels).unwrap());
            });
        });

        let both = bench(&format!("fwd+bwd {name}"), 80.0, 7, || {
            model.zero_grad();
            let v = Var::from_tensor(x.clone(), false);
            let logits = model.forward(&v, true).unwrap();
            let loss = losses::cross_entropy(&logits, &labels).unwrap();
            loss.backward().unwrap();
            std::hint::black_box(());
        });

        t.row(&[
            name.to_string(),
            format!("{}", model.num_parameters()),
            fmt_ns(fwd.median_ns),
            fmt_ns(both.median_ns),
            format!("{:.2}x", both.median_ns / fwd.median_ns),
        ]);
    }
    t.print();
    println!("\npaper claim (§3.2): the ratio is a small constant (classically ~2-3x");
    println!("for dense models, since the backward does ~2x the forward FLOPs).");
}
