//! Experiment C1a — §6 "competitive constant factors for many elementwise
//! operations": native engine vs the AOT-XLA executable (the production-
//! backend stand-in, `--features xla` only) vs the naive scalar baseline,
//! over sizes 1e3..1e7. Set `MINITENSOR_NUM_THREADS` to sweep the
//! execution layer's worker count (1 = the serial baseline).

use minitensor::baselines::NaiveTensor;
use minitensor::bench_util::{bench, bench_artifact, engine_threads, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(1);
    let mut t = Table::new(
        &format!(
            "C1a — elementwise relu(a*b+a), median time per op ({} thread(s))",
            engine_threads()
        ),
        &["N", "native", "xla-aot", "naive-scalar", "native GB/s", "xla/native"],
    );

    // XLA artifact is fixed at N=2^20; measure it once at that size.
    let xla_n = 1_048_576usize;

    for n in [1_000usize, 10_000, 100_000, 1_048_576, 10_000_000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);

        let native = bench(&format!("native {n}"), 60.0, 7, || {
            std::hint::black_box(a.mul(&b).unwrap().add(&a).unwrap().relu());
        });

        let xla_ns = if n == xla_n {
            bench_artifact("elementwise_1m", 60.0, &[&a, &b]).unwrap_or(f64::NAN)
        } else {
            f64::NAN
        };
        let xla_str = if n != xla_n {
            "-".to_string()
        } else if xla_ns.is_nan() {
            "n/a".to_string()
        } else {
            fmt_ns(xla_ns)
        };

        // Naive baseline only at small sizes (it is orders of magnitude
        // slower — that is the point of experiment C2).
        let naive_str = if n <= 10_000 {
            let av = a.to_vec();
            let bv = b.to_vec();
            let s = bench(&format!("naive {n}"), 40.0, 3, || {
                let na = NaiveTensor::from_vec(&av, &[n]);
                let nb = NaiveTensor::from_vec(&bv, &[n]);
                std::hint::black_box(na.mul(&nb).add(&na).relu());
            });
            fmt_ns(s.median_ns)
        } else {
            "-".into()
        };

        // 3 reads + 1 write per element, 4 bytes each ≈ 16 B/elem of traffic.
        let gbps = 16.0 * n as f64 / native.median_ns;
        let ratio = if xla_ns.is_nan() {
            "-".to_string()
        } else {
            format!("{:.2}x", xla_ns / native.median_ns)
        };
        t.row(&[
            format!("{n}"),
            fmt_ns(native.median_ns),
            xla_str,
            naive_str,
            format!("{gbps:.2}"),
            ratio,
        ]);
    }
    t.print();
    println!("\npaper claim (§6): native CPU constant factors competitive with");
    println!("production backends — xla/native ratio near or above 1.0x supports it.");
}
