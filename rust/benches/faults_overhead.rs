//! Failpoint overhead A/B — the acceptance gate for in-tree fault
//! injection.
//!
//! An **unarmed** failpoint costs one relaxed atomic load per site
//! visit (`faults::armed()`), the same discipline as the trace and
//! metrics switches. This bench pins that cost on the hottest visited
//! path: the 1e6-element eager elementwise add (whose output allocation
//! crosses the `pool.alloc` site every dispatch), measured with no site
//! armed vs with an *irrelevant* site armed at probability 0.0 — the
//! armed leg forces every visit through the slow-path site lookup
//! (process mutex + name scan, once per dispatch — not per element), so
//! the < 2% gate bounds the *worst* state an always-compiled-in
//! failpoint can be left in; the disarmed fast path costs strictly less.
//!
//! Pass `--quick` for the CI smoke mode (shorter windows, noisier — the
//! printed verdict is informational there).

use minitensor::bench_util::{bench, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::runtime::faults::{self, FaultKind};
use minitensor::tensor::Tensor;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (ms, reps) = if quick { (10.0, 3) } else { (80.0, 7) };

    let n = 1_000_000;
    let mut rng = Rng::new(11);
    let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);

    // A probability-0.0 arm on a site the add path never visits: every
    // pool.alloc visit now takes the armed slow path (mutex + name
    // scan) and injects nothing.
    let idle_site = "bench.faults.idle";
    let run = |label: &str, armed: bool| {
        if armed {
            faults::arm(idle_site, FaultKind::Error, 0.0, None);
        } else {
            faults::disarm(idle_site);
        }
        // Interleave A/B within one process run; warm once after the
        // flip so the first measured rep sees a settled pool.
        std::hint::black_box(a.add(&b).unwrap());
        let s = bench(label, ms, reps, || {
            std::hint::black_box(a.add(&b).unwrap());
        });
        faults::disarm(idle_site);
        s.median_ns
    };

    let mut table = Table::new(
        "failpoint overhead — eager add, 1e6 elems",
        &["faults", "median/op", "ns/elem"],
    );
    // off→on→off→on: neighbour pairs share thermal/cache conditions.
    let off1 = run("add 1e6 (disarmed)", false);
    let on1 = run("add 1e6 (idle site armed)", true);
    let off2 = run("add 1e6 (disarmed)", false);
    let on2 = run("add 1e6 (idle site armed)", true);
    let off = off1.min(off2);
    let on = on1.min(on2);
    for (name, v) in [("disarmed", off), ("idle-armed", on)] {
        table.row(&[
            name.to_string(),
            fmt_ns(v),
            format!("{:.4}", v / n as f64),
        ]);
    }
    table.print();

    let overhead = (on - off) / off * 100.0;
    println!("failpoint overhead (idle-armed vs disarmed): {overhead:+.2}% (gate: < 2%)");
    if !quick && overhead >= 2.0 {
        eprintln!("FAIL: failpoint sites cost {overhead:.2}% on the eager hot path");
        std::process::exit(1);
    }
}
