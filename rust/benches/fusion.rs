//! Experiment F1 — lazy-graph kernel fusion vs eager op chains.
//!
//! Eager chains are memory-bandwidth-bound: every op reads and writes a
//! full tensor. The fused path dispatches each chain as one composed
//! kernel (one pass over memory, L1-blocked intermediates), so the gap
//! should widen with chain length and size. Sweeps 3-op and 6-op chains
//! at 1e4/1e6 elements across `MINITENSOR_NUM_THREADS` ∈ {1, 2, 4},
//! verifies the fused results are bitwise-equal to eager *and*
//! bit-identical across thread counts, and writes the perf-trajectory
//! file `BENCH_fusion.json` at the repository root.
//!
//! Two further experiments ride along:
//!
//! - **F2, program cache:** cold `eval()` (cache disabled — every call
//!   re-partitions and re-compiles its tape) vs cached `eval()` (the
//!   structurally identical graph hits the compiled-plan LRU) on the
//!   3-op chain at 1e4 elements.
//! - **F3, fused softmax:** the one-dispatch softmax row kernel vs the
//!   unfused primitive chain (`x - rowmax → exp → / rowsum`) at 1e6
//!   elements, in ns/row.
//!
//! Pass `--quick` for the CI smoke mode: same sweep grid and the same
//! JSON schema, just much shorter measurement windows.

use minitensor::bench_util::{bench, fmt_ns, json_rows, Json, Table};
use minitensor::data::Rng;
use minitensor::graph;
use minitensor::runtime::{parallel, simd};
use minitensor::tensor::Tensor;

/// 3-op chain: relu(a*b + a).
fn eager3(a: &Tensor, b: &Tensor) -> Tensor {
    a.mul(b).unwrap().add(a).unwrap().relu()
}

fn fused3(a: &Tensor, b: &Tensor) -> Tensor {
    let (la, lb) = (a.lazy(), b.lazy());
    la.mul(&lb)
        .unwrap()
        .add(&la)
        .unwrap()
        .relu()
        .eval()
        .unwrap()
}

/// 6-op chain: relu(relu(a*b + a) * b - a).
fn eager6(a: &Tensor, b: &Tensor) -> Tensor {
    eager3(a, b).mul(b).unwrap().sub(a).unwrap().relu()
}

fn fused6(a: &Tensor, b: &Tensor) -> Tensor {
    let (la, lb) = (a.lazy(), b.lazy());
    la.mul(&lb)
        .unwrap()
        .add(&la)
        .unwrap()
        .relu()
        .mul(&lb)
        .unwrap()
        .sub(&la)
        .unwrap()
        .relu()
        .eval()
        .unwrap()
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.to_vec().into_iter().map(f32::to_bits).collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Quick mode shrinks the measurement window, not the sweep grid, so
    // the JSON keeps every (experiment, n, threads) row CI expects.
    let (ms, reps) = if quick { (4.0, 2) } else { (40.0, 5) };
    let before_threads = parallel::num_threads();
    // Every JSON row records the detected dispatch path so perf
    // trajectories are comparable across hosts (and against the
    // committed scalar baseline at the repo root).
    let simd_path = simd::path().name();
    println!("simd: {simd_path} ({} lanes)\n", simd::LANES);
    let mut rng = Rng::new(3);
    let mut table = Table::new(
        "F1 — eager vs fused elementwise chains",
        &[
            "chain", "N", "threads", "eager", "fused", "eager ns/el", "fused ns/el", "speedup",
            "bitwise",
        ],
    );
    let mut rows: Vec<Vec<(&str, Json)>> = Vec::new();

    for &n in &[10_000usize, 1_000_000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        type Impl = fn(&Tensor, &Tensor) -> Tensor;
        type Chain = (&'static str, usize, Impl, Impl);
        let chains: [Chain; 2] = [("3op", 3, eager3, fused3), ("6op", 6, eager6, fused6)];
        for (name, ops, eager, fused) in chains {
            let mut t1_bits: Option<Vec<u32>> = None;
            for &threads in &[1usize, 2, 4] {
                parallel::set_num_threads(threads);
                // Parity first: fused == eager bitwise at this thread
                // count, and fused identical to the 1-thread fused run.
                let fb = bits(&fused(&a, &b));
                let ok_eager = fb == bits(&eager(&a, &b));
                let ok_threads = match &t1_bits {
                    None => {
                        t1_bits = Some(fb);
                        true
                    }
                    Some(reference) => &fb == reference,
                };
                let bitwise = ok_eager && ok_threads;

                let se = bench(&format!("eager {name} {n} t{threads}"), ms, reps, || {
                    std::hint::black_box(eager(&a, &b));
                });
                let sf = bench(&format!("fused {name} {n} t{threads}"), ms, reps, || {
                    std::hint::black_box(fused(&a, &b));
                });
                let speedup = se.median_ns / sf.median_ns;
                table.row(&[
                    name.to_string(),
                    format!("{n}"),
                    format!("{threads}"),
                    fmt_ns(se.median_ns),
                    fmt_ns(sf.median_ns),
                    format!("{:.3}", se.median_ns / n as f64),
                    format!("{:.3}", sf.median_ns / n as f64),
                    format!("{speedup:.2}x"),
                    if bitwise { "ok".into() } else { "MISMATCH".into() },
                ]);
                rows.push(vec![
                    ("bench", Json::S("fusion".into())),
                    ("simd", Json::S(simd_path.into())),
                    ("chain", Json::S(name.into())),
                    ("ops", Json::N(ops as f64)),
                    ("n", Json::N(n as f64)),
                    ("threads", Json::N(threads as f64)),
                    ("eager_ns_per_elem", Json::N(se.median_ns / n as f64)),
                    ("fused_ns_per_elem", Json::N(sf.median_ns / n as f64)),
                    ("speedup", Json::N(speedup)),
                    ("bitwise_identical", Json::B(bitwise)),
                ]);
            }
        }
    }
    table.print();

    // F2 — program cache: cold compile-every-eval vs cached plans, on a
    // small 3-op chain where per-eval overhead dominates the kernel.
    let mut cache_table = Table::new(
        "F2 — cold vs cached eval() (3-op chain)",
        &["N", "threads", "cold", "cached", "speedup", "bitwise"],
    );
    {
        let n = 10_000usize;
        let before_cap = graph::program_cache_capacity();
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        for &threads in &[1usize, 2, 4] {
            parallel::set_num_threads(threads);
            let ok = bits(&fused3(&a, &b)) == bits(&eager3(&a, &b));
            // Cold: cache capacity 0 — every eval re-partitions the DAG
            // and rebuilds the instruction tape.
            graph::set_program_cache_capacity(0);
            let sc = bench(&format!("cold eval {n} t{threads}"), ms, reps, || {
                std::hint::black_box(fused3(&a, &b));
            });
            // Cached: restore the real capacity, warm with one call —
            // each timed eval walks the signature and reuses the plan.
            graph::set_program_cache_capacity(before_cap.max(1));
            std::hint::black_box(fused3(&a, &b));
            let sw = bench(&format!("cached eval {n} t{threads}"), ms, reps, || {
                std::hint::black_box(fused3(&a, &b));
            });
            let speedup = sc.median_ns / sw.median_ns;
            cache_table.row(&[
                format!("{n}"),
                format!("{threads}"),
                fmt_ns(sc.median_ns),
                fmt_ns(sw.median_ns),
                format!("{speedup:.2}x"),
                if ok { "ok".into() } else { "MISMATCH".into() },
            ]);
            graph::set_program_cache_capacity(before_cap);
            rows.push(vec![
                ("bench", Json::S("fusion_cache".into())),
                ("simd", Json::S(simd_path.into())),
                ("n", Json::N(n as f64)),
                ("threads", Json::N(threads as f64)),
                ("cold_eval_ns", Json::N(sc.median_ns)),
                ("cached_eval_ns", Json::N(sw.median_ns)),
                ("speedup", Json::N(speedup)),
                ("bitwise_identical", Json::B(ok)),
            ]);
        }
    }
    cache_table.print();

    // F3 — fused softmax (one row-kernel dispatch) vs the unfused
    // primitive chain: x - rowmax → exp → / rowsum (4 dispatches, 3
    // materialized intermediates). Not bitwise (the chain uses libm exp,
    // the row kernel fast_exp) — verified allclose instead; the fused
    // kernel itself is pinned bitwise against mul_scalar+softmax in the
    // test suite.
    let mut sm_table = Table::new(
        "F3 — eager-chain vs fused softmax (1e6 elems)",
        &[
            "rows", "k", "threads", "eager", "fused", "eager ns/row", "fused ns/row", "speedup",
            "close",
        ],
    );
    {
        let (rows_n, k) = (4096usize, 256usize);
        let t = Tensor::randn(&[rows_n, k], 0.0, 2.0, &mut rng);
        let eager_sm = |t: &Tensor| {
            let m = t.max_axis(-1, true).unwrap();
            let e = t.sub(&m).unwrap().exp();
            let s = e.sum_axis(-1, true).unwrap();
            e.div(&s).unwrap()
        };
        for &threads in &[1usize, 2, 4] {
            parallel::set_num_threads(threads);
            let close = t
                .softmax()
                .unwrap()
                .allclose(&eager_sm(&t), 1e-5, 1e-6);
            let se = bench(&format!("eager softmax t{threads}"), ms, reps, || {
                std::hint::black_box(eager_sm(&t));
            });
            let sf = bench(&format!("fused softmax t{threads}"), ms, reps, || {
                std::hint::black_box(t.softmax().unwrap());
            });
            let speedup = se.median_ns / sf.median_ns;
            sm_table.row(&[
                format!("{rows_n}"),
                format!("{k}"),
                format!("{threads}"),
                fmt_ns(se.median_ns),
                fmt_ns(sf.median_ns),
                format!("{:.1}", se.median_ns / rows_n as f64),
                format!("{:.1}", sf.median_ns / rows_n as f64),
                format!("{speedup:.2}x"),
                if close { "ok".into() } else { "MISMATCH".into() },
            ]);
            rows.push(vec![
                ("bench", Json::S("softmax_fused".into())),
                ("simd", Json::S(simd_path.into())),
                ("rows", Json::N(rows_n as f64)),
                ("k", Json::N(k as f64)),
                ("n", Json::N((rows_n * k) as f64)),
                ("threads", Json::N(threads as f64)),
                ("eager_ns_per_row", Json::N(se.median_ns / rows_n as f64)),
                ("fused_ns_per_row", Json::N(sf.median_ns / rows_n as f64)),
                ("speedup", Json::N(speedup)),
                ("allclose", Json::B(close)),
            ]);
        }
    }
    sm_table.print();

    // F4 — vector path on vs off, same kernels: the explicit SIMD layer's
    // headline claim. Results must stay bitwise-identical across the
    // toggle (scalar blocks mirror the intrinsic lane semantics exactly);
    // on an AVX2/NEON host the on-leg should clear 1.5x on the
    // transcendental-heavy rows.
    let mut simd_table = Table::new(
        "F4 — SIMD on vs off (1 thread, 1e6 elems)",
        &["kernel", "off", "on", "speedup", "bitwise"],
    );
    {
        parallel::set_num_threads(1);
        let n = 1_000_000usize;
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let sm = Tensor::randn(&[4096, 256], 0.0, 2.0, &mut rng);
        let was_vector = simd::path().is_vector();
        type Kernel<'t> = (&'static str, Box<dyn Fn() -> Tensor + 't>);
        let kernels: [Kernel; 5] = [
            ("add", Box::new(|| a.add(&b).unwrap())),
            ("exp", Box::new(|| a.exp())),
            ("gelu", Box::new(|| a.gelu())),
            ("fused 6op", Box::new(|| fused6(&a, &b))),
            ("softmax", Box::new(|| sm.softmax().unwrap())),
        ];
        for (name, f) in &kernels {
            simd::set_simd_enabled(false);
            let off_bits = bits(&f());
            let off = bench(&format!("{name} simd=off"), ms, reps, || {
                std::hint::black_box(f());
            });
            simd::set_simd_enabled(true);
            let ok = bits(&f()) == off_bits;
            let on = bench(&format!("{name} simd=on"), ms, reps, || {
                std::hint::black_box(f());
            });
            let speedup = off.median_ns / on.median_ns;
            simd_table.row(&[
                (*name).to_string(),
                fmt_ns(off.median_ns),
                fmt_ns(on.median_ns),
                format!("{speedup:.2}x"),
                if ok { "ok".into() } else { "MISMATCH".into() },
            ]);
            rows.push(vec![
                ("bench", Json::S("simd_onoff".into())),
                ("simd", Json::S(simd_path.into())),
                ("kernel", Json::S((*name).into())),
                ("n", Json::N(n as f64)),
                ("threads", Json::N(1.0)),
                ("off_ns", Json::N(off.median_ns)),
                ("on_ns", Json::N(on.median_ns)),
                ("speedup", Json::N(speedup)),
                ("bitwise_identical", Json::B(ok)),
            ]);
        }
        simd::set_simd_enabled(was_vector);
    }
    simd_table.print();
    parallel::set_num_threads(before_threads);

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fusion.json");
    std::fs::write(path, json_rows(&rows)).expect("write BENCH_fusion.json");
    println!("\nwrote {path}");
    println!("fusion claim: one pass over memory per region — the 6-op chain at 1e6");
    println!("elements should run well over 1.5x faster fused on 2+ threads; cached");
    println!("eval() must beat cold eval(), and the fused softmax row kernel must");
    println!("beat the unfused primitive chain, at every thread count.");
}
