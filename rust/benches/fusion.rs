//! Experiment F1 — lazy-graph kernel fusion vs eager op chains.
//!
//! Eager chains are memory-bandwidth-bound: every op reads and writes a
//! full tensor. The fused path dispatches each chain as one composed
//! kernel (one pass over memory, L1-blocked intermediates), so the gap
//! should widen with chain length and size. Sweeps 3-op and 6-op chains
//! at 1e4/1e6 elements across `MINITENSOR_NUM_THREADS` ∈ {1, 2, 4},
//! verifies the fused results are bitwise-equal to eager *and*
//! bit-identical across thread counts, and writes the perf-trajectory
//! file `BENCH_fusion.json` at the repository root.

use minitensor::bench_util::{bench, fmt_ns, json_rows, Json, Table};
use minitensor::data::Rng;
use minitensor::runtime::parallel;
use minitensor::tensor::Tensor;

/// 3-op chain: relu(a*b + a).
fn eager3(a: &Tensor, b: &Tensor) -> Tensor {
    a.mul(b).unwrap().add(a).unwrap().relu()
}

fn fused3(a: &Tensor, b: &Tensor) -> Tensor {
    let (la, lb) = (a.lazy(), b.lazy());
    la.mul(&lb)
        .unwrap()
        .add(&la)
        .unwrap()
        .relu()
        .eval()
        .unwrap()
}

/// 6-op chain: relu(relu(a*b + a) * b - a).
fn eager6(a: &Tensor, b: &Tensor) -> Tensor {
    eager3(a, b).mul(b).unwrap().sub(a).unwrap().relu()
}

fn fused6(a: &Tensor, b: &Tensor) -> Tensor {
    let (la, lb) = (a.lazy(), b.lazy());
    la.mul(&lb)
        .unwrap()
        .add(&la)
        .unwrap()
        .relu()
        .mul(&lb)
        .unwrap()
        .sub(&la)
        .unwrap()
        .relu()
        .eval()
        .unwrap()
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.to_vec().into_iter().map(f32::to_bits).collect()
}

fn main() {
    let before_threads = parallel::num_threads();
    let mut rng = Rng::new(3);
    let mut table = Table::new(
        "F1 — eager vs fused elementwise chains",
        &[
            "chain", "N", "threads", "eager", "fused", "eager ns/el", "fused ns/el", "speedup",
            "bitwise",
        ],
    );
    let mut rows: Vec<Vec<(&str, Json)>> = Vec::new();

    for &n in &[10_000usize, 1_000_000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        type Impl = fn(&Tensor, &Tensor) -> Tensor;
        type Chain = (&'static str, usize, Impl, Impl);
        let chains: [Chain; 2] = [("3op", 3, eager3, fused3), ("6op", 6, eager6, fused6)];
        for (name, ops, eager, fused) in chains {
            let mut t1_bits: Option<Vec<u32>> = None;
            for &threads in &[1usize, 2, 4] {
                parallel::set_num_threads(threads);
                // Parity first: fused == eager bitwise at this thread
                // count, and fused identical to the 1-thread fused run.
                let fb = bits(&fused(&a, &b));
                let ok_eager = fb == bits(&eager(&a, &b));
                let ok_threads = match &t1_bits {
                    None => {
                        t1_bits = Some(fb);
                        true
                    }
                    Some(reference) => &fb == reference,
                };
                let bitwise = ok_eager && ok_threads;

                let se = bench(&format!("eager {name} {n} t{threads}"), 40.0, 5, || {
                    std::hint::black_box(eager(&a, &b));
                });
                let sf = bench(&format!("fused {name} {n} t{threads}"), 40.0, 5, || {
                    std::hint::black_box(fused(&a, &b));
                });
                let speedup = se.median_ns / sf.median_ns;
                table.row(&[
                    name.to_string(),
                    format!("{n}"),
                    format!("{threads}"),
                    fmt_ns(se.median_ns),
                    fmt_ns(sf.median_ns),
                    format!("{:.3}", se.median_ns / n as f64),
                    format!("{:.3}", sf.median_ns / n as f64),
                    format!("{speedup:.2}x"),
                    if bitwise { "ok".into() } else { "MISMATCH".into() },
                ]);
                rows.push(vec![
                    ("bench", Json::S("fusion".into())),
                    ("chain", Json::S(name.into())),
                    ("ops", Json::N(ops as f64)),
                    ("n", Json::N(n as f64)),
                    ("threads", Json::N(threads as f64)),
                    ("eager_ns_per_elem", Json::N(se.median_ns / n as f64)),
                    ("fused_ns_per_elem", Json::N(sf.median_ns / n as f64)),
                    ("speedup", Json::N(speedup)),
                    ("bitwise_identical", Json::B(bitwise)),
                ]);
            }
        }
    }
    parallel::set_num_threads(before_threads);
    table.print();

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fusion.json");
    std::fs::write(path, json_rows(&rows)).expect("write BENCH_fusion.json");
    println!("\nwrote {path}");
    println!("fusion claim: one pass over memory per region — the 6-op chain at 1e6");
    println!("elements should run well over 1.5x faster fused on 2+ threads.");
}
