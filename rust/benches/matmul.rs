//! Experiment C4 — matmul throughput (paper eq 1, §3.5 engine claims):
//! blocked native SGEMM (panel-parallel over the worker pool) vs the
//! naive triple loop vs the XLA-AOT executable (`--features xla` only),
//! GFLOP/s across sizes. Set `MINITENSOR_NUM_THREADS` to sweep the
//! execution layer's worker count (1 = the serial baseline).

use minitensor::bench_util::{bench, bench_artifact, engine_threads, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::ops::matmul::sgemm_naive;
use minitensor::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(3);
    let mut t = Table::new(
        &format!(
            "C4 — SGEMM, median time and GFLOP/s ({} thread(s))",
            engine_threads()
        ),
        &["size", "blocked", "GFLOP/s", "naive-loop", "GFLOP/s", "xla-aot", "speedup vs naive"],
    );

    for n in [32usize, 64, 128, 256, 512] {
        let a = Tensor::randn(&[n, n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n, n], 0.0, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);

        let blocked = bench(&format!("blocked {n}"), 80.0, 7, || {
            std::hint::black_box(a.matmul(&b).unwrap());
        });

        let (av, bv) = (a.to_vec(), b.to_vec());
        let naive = bench(&format!("naive {n}"), 80.0, 5, || {
            let mut c = vec![0.0f32; n * n];
            sgemm_naive(n, n, n, &av, &bv, &mut c);
            std::hint::black_box(c);
        });

        let xla = if n == 256 {
            match bench_artifact("matmul_256", 80.0, &[&a, &b]) {
                Some(ns) => format!("{} ({:.2} GF/s)", fmt_ns(ns), flops / ns),
                None => "n/a".into(),
            }
        } else {
            "-".into()
        };

        t.row(&[
            format!("{n}x{n}"),
            fmt_ns(blocked.median_ns),
            format!("{:.2}", flops / blocked.median_ns),
            fmt_ns(naive.median_ns),
            format!("{:.2}", flops / naive.median_ns),
            xla,
            format!("{:.2}x", naive.median_ns / blocked.median_ns),
        ]);
    }
    t.print();

    // Dense-layer product (x·Wᵀ, eq 5) — the layout the MLP actually uses.
    let mut t2 = Table::new("C4' — dense product x·Wᵀ (eq 5)", &["shape", "median", "GFLOP/s"]);
    for (m, k, d) in [(64usize, 196usize, 128usize), (64, 128, 64), (256, 512, 256)] {
        let x = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        let w = Tensor::randn(&[d, k], 0.0, 1.0, &mut rng);
        let s = bench("nt", 60.0, 7, || {
            std::hint::black_box(x.matmul_nt(&w).unwrap());
        });
        t2.row(&[
            format!("[{m},{k}]x[{d},{k}]T"),
            fmt_ns(s.median_ns),
            format!("{:.2}", 2.0 * (m * k * d) as f64 / s.median_ns),
        ]);
    }
    t2.print();
}
