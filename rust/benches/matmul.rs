//! Experiment C4 — matmul throughput (paper eq 1, §3.5 engine claims):
//! blocked native SGEMM (explicit 4×16 FMA micro-kernel, panel-parallel
//! over the worker pool) vs the scalar-dispatch build of the same kernel
//! (`MINITENSOR_SIMD=off` semantics) vs the naive triple loop vs the
//! XLA-AOT executable (`--features xla` only), GFLOP/s across sizes. Set
//! `MINITENSOR_NUM_THREADS` to sweep the execution layer's worker count
//! (1 = the serial baseline). Writes `BENCH_matmul.json` at the
//! repository root, each row tagged with the detected SIMD path.

use minitensor::bench_util::{
    bench, bench_artifact, engine_threads, fmt_ns, json_rows, Json, Table,
};
use minitensor::data::Rng;
use minitensor::ops::matmul::sgemm_naive;
use minitensor::runtime::simd;
use minitensor::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(3);
    let simd_path = simd::path().name();
    let was_vector = simd::path().is_vector();
    println!("simd: {simd_path} ({} lanes)\n", simd::LANES);
    let mut rows: Vec<Vec<(&str, Json)>> = Vec::new();
    let mut t = Table::new(
        &format!(
            "C4 — SGEMM, median time and GFLOP/s ({} thread(s), simd={})",
            engine_threads(),
            simd_path
        ),
        &[
            "size", "blocked", "GFLOP/s", "scalar", "GFLOP/s", "naive-loop", "GFLOP/s", "xla-aot",
            "simd speedup",
        ],
    );

    for n in [32usize, 64, 128, 256, 512] {
        let a = Tensor::randn(&[n, n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n, n], 0.0, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);

        let blocked = bench(&format!("blocked {n}"), 80.0, 7, || {
            std::hint::black_box(a.matmul(&b).unwrap());
        });

        // Same blocked kernel with scalar dispatch forced — isolates the
        // micro-kernel's vector win from cache blocking and threading.
        // (`mul_add` scalar blocks are bit-equal to the FMA lanes, so
        // this leg is also a correctness cross-check.)
        simd::set_simd_enabled(false);
        let scalar = bench(&format!("scalar {n}"), 80.0, 5, || {
            std::hint::black_box(a.matmul(&b).unwrap());
        });
        simd::set_simd_enabled(was_vector);

        let (av, bv) = (a.to_vec(), b.to_vec());
        let naive = bench(&format!("naive {n}"), 80.0, 5, || {
            let mut c = vec![0.0f32; n * n];
            sgemm_naive(n, n, n, &av, &bv, &mut c);
            std::hint::black_box(c);
        });

        let xla = if n == 256 {
            match bench_artifact("matmul_256", 80.0, &[&a, &b]) {
                Some(ns) => format!("{} ({:.2} GF/s)", fmt_ns(ns), flops / ns),
                None => "n/a".into(),
            }
        } else {
            "-".into()
        };

        let simd_speedup = scalar.median_ns / blocked.median_ns;
        t.row(&[
            format!("{n}x{n}"),
            fmt_ns(blocked.median_ns),
            format!("{:.2}", flops / blocked.median_ns),
            fmt_ns(scalar.median_ns),
            format!("{:.2}", flops / scalar.median_ns),
            fmt_ns(naive.median_ns),
            format!("{:.2}", flops / naive.median_ns),
            xla,
            format!("{simd_speedup:.2}x"),
        ]);
        rows.push(vec![
            ("bench", Json::S("sgemm".into())),
            ("simd", Json::S(simd_path.into())),
            ("n", Json::N(n as f64)),
            ("threads", Json::N(engine_threads() as f64)),
            ("blocked_ns", Json::N(blocked.median_ns)),
            ("blocked_gflops", Json::N(flops / blocked.median_ns)),
            ("scalar_ns", Json::N(scalar.median_ns)),
            ("scalar_gflops", Json::N(flops / scalar.median_ns)),
            ("naive_ns", Json::N(naive.median_ns)),
            ("simd_speedup", Json::N(simd_speedup)),
        ]);
    }
    t.print();

    // Dense-layer product (x·Wᵀ, eq 5) — the layout the MLP actually uses.
    let mut t2 = Table::new("C4' — dense product x·Wᵀ (eq 5)", &["shape", "median", "GFLOP/s"]);
    for (m, k, d) in [(64usize, 196usize, 128usize), (64, 128, 64), (256, 512, 256)] {
        let x = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        let w = Tensor::randn(&[d, k], 0.0, 1.0, &mut rng);
        let s = bench("nt", 60.0, 7, || {
            std::hint::black_box(x.matmul_nt(&w).unwrap());
        });
        t2.row(&[
            format!("[{m},{k}]x[{d},{k}]T"),
            fmt_ns(s.median_ns),
            format!("{:.2}", 2.0 * (m * k * d) as f64 / s.median_ns),
        ]);
        rows.push(vec![
            ("bench", Json::S("dense_nt".into())),
            ("simd", Json::S(simd_path.into())),
            ("m", Json::N(m as f64)),
            ("k", Json::N(k as f64)),
            ("d", Json::N(d as f64)),
            ("threads", Json::N(engine_threads() as f64)),
            ("median_ns", Json::N(s.median_ns)),
            ("gflops", Json::N(2.0 * (m * k * d) as f64 / s.median_ns)),
        ]);
    }
    t2.print();

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_matmul.json");
    std::fs::write(path, json_rows(&rows)).expect("write BENCH_matmul.json");
    println!("\nwrote {path}");
}
