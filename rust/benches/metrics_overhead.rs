//! Registry overhead A/B — the acceptance gate for always-on metrics.
//!
//! The hot path a metric site adds is one relaxed atomic load (the
//! enable check) plus one thread-local load+store per counter. This
//! bench pins that cost: the 1e6-element eager elementwise workload
//! from `bench-quick`, measured with the registry recording
//! (`metrics::set_enabled(true)`) and frozen (`set_enabled(false)`),
//! must agree within 2%.
//!
//! The disabled leg also freezes `runtime::stats` (same shards), which
//! is exactly the pre-registry baseline being compared against. Pass
//! `--quick` for the CI smoke mode (shorter windows, noisier — the
//! printed verdict is informational there).

use minitensor::bench_util::{bench, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::runtime::metrics;
use minitensor::tensor::Tensor;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (ms, reps) = if quick { (10.0, 3) } else { (80.0, 7) };

    let n = 1_000_000;
    let mut rng = Rng::new(11);
    let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);

    let run = |label: &str, on: bool| {
        metrics::set_enabled(on);
        // Interleave A/B within one process run; warm once after the
        // flip so the first measured rep sees a settled pool.
        std::hint::black_box(a.add(&b).unwrap());
        let s = bench(label, ms, reps, || {
            std::hint::black_box(a.add(&b).unwrap());
        });
        metrics::set_enabled(true);
        s.median_ns
    };

    let mut table = Table::new(
        "metrics registry overhead — eager add, 1e6 elems",
        &["registry", "median/op", "ns/elem"],
    );
    // off→on→off→on: neighbour pairs share thermal/cache conditions.
    let off1 = run("add 1e6 (metrics off)", false);
    let on1 = run("add 1e6 (metrics on)", true);
    let off2 = run("add 1e6 (metrics off)", false);
    let on2 = run("add 1e6 (metrics on)", true);
    let off = off1.min(off2);
    let on = on1.min(on2);
    for (name, v) in [("off", off), ("on", on)] {
        table.row(&[
            name.to_string(),
            fmt_ns(v),
            format!("{:.4}", v / n as f64),
        ]);
    }
    table.print();

    let overhead = (on - off) / off * 100.0;
    println!("registry overhead: {overhead:+.2}% (gate: < 2%)");
    if !quick && overhead >= 2.0 {
        eprintln!("FAIL: always-on registry costs {overhead:.2}% on the eager hot path");
        std::process::exit(1);
    }
}
