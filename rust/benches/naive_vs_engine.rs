//! Experiment C2 — §2/§6: pure-Python-style minimal frameworks are
//! "orders of magnitude slower" than the Rust engine. The naive scalar
//! autograd interpreter (micrograd's execution model, see
//! `baselines::naive`) vs the bulk engine on the same computations,
//! including a full train step.

use minitensor::autograd::Var;
use minitensor::baselines::{NaiveScalar, NaiveTensor};
use minitensor::bench_util::{bench, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(5);
    let mut t = Table::new(
        "C2 — engine vs naive scalar interpreter (micrograd stand-in)",
        &["workload", "engine", "naive", "slowdown"],
    );

    // Elementwise chains at increasing N: the gap must GROW with N.
    for n in [100usize, 1_000, 10_000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let engine = bench(&format!("engine ew {n}"), 30.0, 5, || {
            std::hint::black_box(a.mul(&b).unwrap().add(&a).unwrap().relu());
        });
        let (av, bv) = (a.to_vec(), b.to_vec());
        let naive = bench(&format!("naive ew {n}"), 30.0, 3, || {
            let na = NaiveTensor::from_vec(&av, &[n]);
            let nb = NaiveTensor::from_vec(&bv, &[n]);
            std::hint::black_box(na.mul(&nb).add(&na).relu());
        });
        t.row(&[
            format!("elementwise chain N={n}"),
            fmt_ns(engine.median_ns),
            fmt_ns(naive.median_ns),
            format!("{:.0}x", naive.median_ns / engine.median_ns),
        ]);
    }

    // Matmul 32x32 (naive does 32³ scalar node allocations).
    let a = Tensor::randn(&[32, 32], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[32, 32], 0.0, 1.0, &mut rng);
    let engine = bench("engine mm", 30.0, 5, || {
        std::hint::black_box(a.matmul(&b).unwrap());
    });
    let (av, bv) = (a.to_vec(), b.to_vec());
    let naive = bench("naive mm", 60.0, 3, || {
        let na = NaiveTensor::from_vec(&av, &[32, 32]);
        let nb = NaiveTensor::from_vec(&bv, &[32, 32]);
        std::hint::black_box(na.matmul(&nb));
    });
    t.row(&[
        "matmul 32x32".into(),
        fmt_ns(engine.median_ns),
        fmt_ns(naive.median_ns),
        format!("{:.0}x", naive.median_ns / engine.median_ns),
    ]);

    // Forward + backward on a vector: full autograd round trip.
    let n = 4096;
    let x = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let engine_ad = bench("engine fwd+bwd", 30.0, 5, || {
        let v = Var::from_tensor(x.clone(), true);
        let loss = v.mul(&v).unwrap().relu().sum().unwrap();
        loss.backward().unwrap();
        std::hint::black_box(v.grad());
    });
    let xv = x.to_vec();
    let naive_ad = bench("naive fwd+bwd", 60.0, 3, || {
        let nx = NaiveTensor::from_vec(&xv, &[n]);
        let loss: NaiveScalar = nx.mul(&nx).relu().sum();
        loss.backward();
        std::hint::black_box(nx.grads());
    });
    t.row(&[
        format!("autograd fwd+bwd N={n}"),
        fmt_ns(engine_ad.median_ns),
        fmt_ns(naive_ad.median_ns),
        format!("{:.0}x", naive_ad.median_ns / engine_ad.median_ns),
    ]);

    t.print();
    println!("\npaper claim (§2): pure-Python-style execution is orders of magnitude");
    println!("slower; the slowdown column should show 2-4 orders of magnitude and");
    println!("grow with N (per-element dispatch + allocation vs bulk kernels).");
}
