//! Experiment C1b — §6 constant factors for reductions: sum/mean/max over
//! 1e3..1e7 elements, native vs XLA-AOT (`--features xla` only); plus
//! per-axis reductions. Set `MINITENSOR_NUM_THREADS` to sweep the
//! execution layer's worker count (1 = the serial baseline).

use minitensor::bench_util::{bench, bench_artifact, engine_threads, fmt_ns, Table};
use minitensor::data::Rng;
use minitensor::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(2);
    let mut t = Table::new(
        &format!(
            "C1b — full reductions, median time ({} thread(s))",
            engine_threads()
        ),
        &["N", "sum", "mean", "max", "sum GB/s", "xla sum+mean"],
    );

    let xla_n = 1_048_576usize;

    for n in [1_000usize, 10_000, 100_000, 1_048_576, 10_000_000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let sum = bench("sum", 50.0, 7, || {
            std::hint::black_box(a.sum());
        });
        let mean = bench("mean", 50.0, 7, || {
            std::hint::black_box(a.mean());
        });
        let max = bench("max", 50.0, 7, || {
            std::hint::black_box(a.max_all());
        });
        let xla = if n == xla_n {
            bench_artifact("reduction_1m", 50.0, &[&a])
                .map(fmt_ns)
                .unwrap_or_else(|| "n/a".into())
        } else {
            "-".into()
        };
        t.row(&[
            format!("{n}"),
            fmt_ns(sum.median_ns),
            fmt_ns(mean.median_ns),
            fmt_ns(max.median_ns),
            format!("{:.2}", 4.0 * n as f64 / sum.median_ns),
            xla,
        ]);
    }
    t.print();

    // Axis reductions on a matrix — the shapes real models use.
    let mut t2 = Table::new(
        "C1b' — axis reductions on [1024, 1024]",
        &["op", "median", "GB/s"],
    );
    let m = Tensor::randn(&[1024, 1024], 0.0, 1.0, &mut rng);
    for (name, f) in [
        ("sum_axis(0)", 0usize),
        ("sum_axis(1)", 1),
    ] {
        let ax = f as isize;
        let s = bench(name, 50.0, 7, || {
            std::hint::black_box(m.sum_axis(ax, false).unwrap());
        });
        t2.row(&[
            name.into(),
            fmt_ns(s.median_ns),
            format!("{:.2}", 4.0 * 1024.0 * 1024.0 / s.median_ns),
        ]);
    }
    let sm = bench("softmax rows", 50.0, 7, || {
        std::hint::black_box(m.softmax().unwrap());
    });
    t2.row(&[
        "softmax(lastdim)".into(),
        fmt_ns(sm.median_ns),
        format!("{:.2}", 8.0 * 1024.0 * 1024.0 / sm.median_ns),
    ]);
    t2.print();
}
