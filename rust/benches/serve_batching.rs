//! Experiment S1 — serving-stack sustained-load sweep: throughput and
//! tail latency of the continuous-batching server across worker counts
//! {1, 2, 4, 8} × `max_batch` {1, 8, 32}, under a fixed closed-loop
//! client population. Kernel-level threading is pinned to 1
//! (`parallel::set_num_threads(1)`) so the only parallelism axis being
//! measured is the worker pool — each worker owns a model replica with
//! its own warm per-thread program cache.
//!
//! A replica-equivalence check rides along (S2): a fixed probe set must
//! produce byte-identical replies from an N-worker server and the
//! 1-worker server, at every worker count — per-row math is
//! batch-composition-invariant and every replica holds the same
//! parameter snapshot.
//!
//! Writes the perf-trajectory file `BENCH_serve.json` at the repository
//! root (each row records `cores`: worker scaling beyond the machine's
//! core count measures oversubscription, not speedup). Pass `--quick`
//! for the CI smoke mode: same sweep grid and JSON schema, fewer
//! requests per client.

use std::sync::Arc;
use std::time::Instant;

use minitensor::bench_util::{json_rows, Json, Table};
use minitensor::coordinator::{InferenceServer, NativeModelFactory, ServeConfig, ServeStats};
use minitensor::data::Rng;
use minitensor::nn::{Activation, Dense, Sequential};
use minitensor::runtime::{parallel, simd};

const IN_FEATURES: usize = 196;

fn factory() -> NativeModelFactory {
    NativeModelFactory::new(IN_FEATURES, || {
        let mut rng = Rng::new(42);
        Sequential::new()
            .add(Dense::new(IN_FEATURES, 128, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(128, 64, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(64, 10, &mut rng))
    })
}

/// One sustained-load measurement: `n_clients` closed-loop clients, each
/// firing `per_client` requests back-to-back.
fn run_point(
    workers: usize,
    max_batch: usize,
    n_clients: usize,
    per_client: usize,
) -> (f64, ServeStats) {
    let cfg = ServeConfig::new()
        .workers(workers)
        .max_batch(max_batch)
        .max_wait_ms(2)
        .queue_depth(1024)
        .build()
        .expect("sweep config is valid");
    let server = Arc::new(InferenceServer::start(factory(), cfg).expect("server starts"));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let s = server.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let feats: Vec<f32> = (0..IN_FEATURES).map(|_| rng.next_f32()).collect();
                    s.infer(feats).expect("infer");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    ((n_clients * per_client) as f64 / elapsed, stats)
}

/// Byte-level reply signature of a fixed probe set served sequentially.
fn probe_bits(workers: usize) -> Vec<u32> {
    let cfg = ServeConfig::new()
        .workers(workers)
        .max_batch(8)
        .max_wait_ms(1)
        .build()
        .unwrap();
    let server = InferenceServer::start(factory(), cfg).unwrap();
    let mut rng = Rng::new(5);
    let mut bits = Vec::new();
    for _ in 0..16 {
        let feats: Vec<f32> = (0..IN_FEATURES).map(|_| rng.next_f32()).collect();
        let out = server.infer(feats).expect("probe infer");
        bits.extend(out.iter().map(|v| v.to_bits()));
    }
    server.shutdown();
    bits
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Quick mode shrinks the request volume, not the sweep grid, so the
    // JSON keeps every (workers, max_batch) row CI expects.
    let (n_clients, per_client) = if quick { (8, 30) } else { (16, 300) };
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    // Pin kernel-level threading: worker scaling is the measured axis.
    let before_threads = parallel::num_threads();
    parallel::set_num_threads(1);
    println!(
        "S1 — sustained load: {n_clients} closed-loop clients × {per_client} requests, \
         {cores} core(s), kernel threads pinned to 1\n"
    );

    let mut rows: Vec<Vec<(&str, Json)>> = Vec::new();
    let mut table = Table::new(
        "S1 — serving sweep (196-feat MLP, req/s and tail latency)",
        &[
            "workers", "max_batch", "req/s", "mean batch", "p50 ms", "p95 ms", "p99 ms",
            "rejected", "shed",
        ],
    );

    for &workers in &[1usize, 2, 4, 8] {
        for &max_batch in &[1usize, 8, 32] {
            let (req_per_s, stats) = run_point(workers, max_batch, n_clients, per_client);
            table.row(&[
                format!("{workers}"),
                format!("{max_batch}"),
                format!("{req_per_s:.0}"),
                format!("{:.1}", stats.mean_batch_size),
                format!("{:.2}", stats.p50_latency_ms),
                format!("{:.2}", stats.p95_latency_ms),
                format!("{:.2}", stats.p99_latency_ms),
                format!("{}", stats.rejected),
                format!("{}", stats.shed),
            ]);
            rows.push(vec![
                ("bench", Json::S("serve_sweep".into())),
                ("workers", Json::N(workers as f64)),
                ("max_batch", Json::N(max_batch as f64)),
                ("cores", Json::N(cores as f64)),
                ("simd", Json::S(simd::path().name().into())),
                ("threads", Json::N(parallel::num_threads() as f64)),
                ("clients", Json::N(n_clients as f64)),
                ("requests", Json::N((n_clients * per_client) as f64)),
                ("req_per_s", Json::N(req_per_s)),
                ("mean_batch", Json::N(stats.mean_batch_size)),
                ("p50_ms", Json::N(stats.p50_latency_ms)),
                ("p95_ms", Json::N(stats.p95_latency_ms)),
                ("p99_ms", Json::N(stats.p99_latency_ms)),
                ("rejected", Json::N(stats.rejected as f64)),
                ("shed", Json::N(stats.shed as f64)),
            ]);
        }
    }
    table.print();

    // S2 — replica equivalence: every worker count serves byte-identical
    // replies for the same probe set.
    let reference = probe_bits(1);
    let mut eq_table = Table::new(
        "S2 — N-worker replies vs 1-worker (byte-level)",
        &["workers", "identical"],
    );
    for &workers in &[2usize, 4, 8] {
        let identical = probe_bits(workers) == reference;
        eq_table.row(&[
            format!("{workers}"),
            if identical { "ok".into() } else { "MISMATCH".to_string() },
        ]);
        rows.push(vec![
            ("bench", Json::S("serve_equivalence".into())),
            ("workers", Json::N(workers as f64)),
            ("cores", Json::N(cores as f64)),
            ("simd", Json::S(simd::path().name().into())),
            ("threads", Json::N(parallel::num_threads() as f64)),
            ("identical_to_1worker", Json::B(identical)),
        ]);
    }
    eq_table.print();
    parallel::set_num_threads(before_threads);

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    std::fs::write(path, json_rows(&rows)).expect("write BENCH_serve.json");
    println!("\nwrote {path}");
    println!("serving claim: with kernel threads pinned, req/s at max_batch=32 should");
    println!("rise with workers until the core count caps it (the `cores` field marks");
    println!("where oversubscription starts); batching itself lifts req/s at every");
    println!("worker count, and the equivalence rows must all read identical.");
}
