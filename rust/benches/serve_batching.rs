//! Ablation — coordinator batching policy: throughput and latency of the
//! inference server as `max_batch` sweeps 1..64 (the design choice
//! DESIGN.md's coordinator section calls out). batch=1 is the no-batching
//! baseline; the crossover shows where amortizing per-call overhead wins
//! over queueing delay.

use std::sync::Arc;
use std::time::{Duration, Instant};

use minitensor::bench_util::Table;
use minitensor::coordinator::{InferenceServer, NativeBatchModel, ServeConfig};
use minitensor::data::Rng;
use minitensor::nn::{Activation, Dense, Sequential};

fn model(rng: &mut Rng) -> Sequential {
    Sequential::new()
        .add(Dense::new(196, 128, rng))
        .add(Activation::Relu)
        .add(Dense::new(128, 64, rng))
        .add(Activation::Relu)
        .add(Dense::new(64, 10, rng))
}

fn main() {
    let mut t = Table::new(
        "ablation — batching policy (4 closed-loop clients, 196-feat MLP)",
        &["max_batch", "req/s", "mean batch", "p50 ms", "p99 ms"],
    );

    for max_batch in [1usize, 4, 16, 64] {
        let mut rng = Rng::new(42);
        let m = model(&mut rng);
        let server = Arc::new(InferenceServer::start(
            Box::new(NativeBatchModel::new(m, 196)),
            ServeConfig {
                max_batch,
                max_wait: Duration::from_millis(2),
                queue_depth: 256,
            },
        ));
        let n_clients = 4;
        let per_client = 300;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let s = server.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + c as u64);
                    for _ in 0..per_client {
                        let feats: Vec<f32> = (0..196).map(|_| rng.next_f32()).collect();
                        s.infer(feats).expect("infer");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client");
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let stats = server.stats();
        t.row(&[
            format!("{max_batch}"),
            format!("{:.0}", stats.requests as f64 / elapsed),
            format!("{:.1}", stats.mean_batch_size),
            format!("{:.2}", stats.p50_latency_ms),
            format!("{:.2}", stats.p99_latency_ms),
        ]);
    }
    t.print();
    println!("\nreading: batch=1 pays one full forward per request; larger budgets");
    println!("amortize dispatch until queueing delay dominates (the p99 column).");
}
