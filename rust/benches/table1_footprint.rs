//! Experiment T1 — reproduces **Table 1** (package sizes).
//!
//! The paper compares the MiniTensor wheel (2.6 MB) against PyTorch
//! (887.9 MB) and TensorFlow (620.7 MB) wheels. Our deployable unit is
//! the stripped release binary plus the AOT artifacts; the PyTorch/TF
//! numbers are the paper's published constants (no network in this
//! environment — see DESIGN.md substitutions). The claim under test is
//! the *orders-of-magnitude ratio*, which this harness recomputes from
//! our measured sizes.

use std::path::Path;
use std::process::Command;

use minitensor::bench_util::Table;

fn dir_size(path: &Path) -> u64 {
    if path.is_file() {
        return path.metadata().map(|m| m.len()).unwrap_or(0);
    }
    let mut total = 0;
    if let Ok(entries) = std::fs::read_dir(path) {
        for e in entries.flatten() {
            total += dir_size(&e.path());
        }
    }
    total
}

fn mb(bytes: u64) -> f64 {
    bytes as f64 / 1e6
}

fn main() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));

    // Build (or reuse) the release binary and strip a copy of it.
    let bin = root.join("target/release/minitensor");
    if !bin.exists() {
        let ok = Command::new("cargo")
            .args(["build", "--release", "--bin", "minitensor"])
            .current_dir(root)
            .status()
            .map(|s| s.success())
            .unwrap_or(false);
        if !ok {
            eprintln!("warning: release build failed; sizes may be missing");
        }
    }
    let stripped = root.join("target/release/minitensor.stripped");
    let stripped_size = if bin.exists() {
        std::fs::copy(&bin, &stripped).ok();
        Command::new("strip").arg(&stripped).status().ok();
        dir_size(&stripped)
    } else {
        0
    };

    let artifacts = dir_size(&root.join("artifacts"));
    let rust_src = dir_size(&root.join("rust/src"));
    let py_src = dir_size(&root.join("python"));
    let deployable = stripped_size + artifacts;

    // Paper Table 1 constants (PyPI wheel sizes at the time of writing).
    const PAPER_MINITENSOR_MB: f64 = 2.6;
    const PAPER_TORCH_MB: f64 = 887.9;
    const PAPER_TF_MB: f64 = 620.7;

    let mut t = Table::new(
        "Table 1 — package / deployable sizes",
        &["Package and platform", "Artifact", "Size", "vs ours"],
    );
    let ours_mb = mb(deployable);
    t.row(&[
        "MiniTensor-repro (this repo)".into(),
        "stripped binary + AOT artifacts".into(),
        format!("{ours_mb:.1} MB"),
        "1.0x".into(),
    ]);
    t.row(&[
        "  · stripped binary".into(),
        "target/release/minitensor".into(),
        format!("{:.1} MB", mb(stripped_size)),
        String::new(),
    ]);
    t.row(&[
        "  · AOT artifacts (HLO text)".into(),
        "artifacts/*.hlo.txt".into(),
        format!("{:.2} MB", mb(artifacts)),
        String::new(),
    ]);
    t.row(&[
        "  · rust sources".into(),
        "rust/src".into(),
        format!("{:.2} MB", mb(rust_src)),
        String::new(),
    ]);
    t.row(&[
        "  · python compile-path sources".into(),
        "python/".into(),
        format!("{:.2} MB", mb(py_src)),
        String::new(),
    ]);
    t.row(&[
        "MiniTensor 0.1.1 (paper)".into(),
        "minitensor-0.1.1…whl".into(),
        format!("{PAPER_MINITENSOR_MB} MB"),
        format!("{:.1}x", PAPER_MINITENSOR_MB / ours_mb.max(1e-9)),
    ]);
    t.row(&[
        "PyTorch 2.8.0 (paper)".into(),
        "torch-2.8.0…whl".into(),
        format!("{PAPER_TORCH_MB} MB"),
        format!("{:.0}x", PAPER_TORCH_MB / ours_mb.max(1e-9)),
    ]);
    t.row(&[
        "TensorFlow 2.20.0 (paper)".into(),
        "tensorflow-2.20.0…whl".into(),
        format!("{PAPER_TF_MB} MB"),
        format!("{:.0}x", PAPER_TF_MB / ours_mb.max(1e-9)),
    ]);
    t.print();

    println!(
        "\npaper's claim: MiniTensor is ~{:.0}x / ~{:.0}x smaller than PyTorch / TensorFlow wheels.",
        PAPER_TORCH_MB / PAPER_MINITENSOR_MB,
        PAPER_TF_MB / PAPER_MINITENSOR_MB
    );
    println!(
        "measured here: our deployable unit is {ours_mb:.1} MB => {:.0}x / {:.0}x smaller.",
        PAPER_TORCH_MB / ours_mb.max(1e-9),
        PAPER_TF_MB / ours_mb.max(1e-9)
    );
    assert!(
        ours_mb < 50.0,
        "deployable unit must stay orders of magnitude under the mainstream wheels"
    );
}
