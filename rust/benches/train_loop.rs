//! Experiment C5 — §5 end-to-end: training throughput and the loss-descent
//! curve for the reference MLP, on both backends.

use minitensor::bench_util::Table;
use minitensor::coordinator::{Backend, Config, TrainConfig, Trainer};

fn run(backend: Backend, steps: usize) -> Option<minitensor::coordinator::TrainReport> {
    let cfg = Config::parse(&format!(
        "[train]\ndataset = synthetic_mnist\nn_examples = 1024\ninput_side = 14\nhidden = 128,64\noptimizer = sgd\nmomentum = 0.0\nlr = 0.05\nbatch_size = 64\nsteps = {steps}\nlog_every = {}\nbackend = {backend}\n",
        (steps / 10).max(1),
    ))
    .unwrap();
    let tc = TrainConfig::from_config(&cfg).unwrap();
    match Trainer::new(tc).run() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("{backend} backend skipped: {e}");
            None
        }
    }
}

fn main() {
    let steps = 100;
    let mut t = Table::new(
        "C5 — end-to-end training (synthetic-MNIST MLP 196-128-64-10)",
        &["backend", "params", "initial loss", "final loss", "acc", "steps/s"],
    );
    let mut curves = Vec::new();
    for backend in [Backend::Native, Backend::Xla] {
        if let Some(r) = run(backend, steps) {
            t.row(&[
                format!("{backend}"),
                format!("{}", r.num_parameters),
                format!("{:.4}", r.initial_loss),
                format!("{:.4}", r.final_loss),
                r.accuracy.map_or("n/a".into(), |a| format!("{a:.3}")),
                format!("{:.1}", r.steps_per_sec),
            ]);
            curves.push((backend, r.losses.clone()));
            assert!(
                r.final_loss < r.initial_loss,
                "{backend}: loss must descend (paper §5)"
            );
        }
    }
    t.print();

    println!("\nloss curves (step, loss):");
    for (backend, losses) in &curves {
        let pts: Vec<String> = losses
            .iter()
            .map(|(s, l)| format!("({s}, {l:.3})"))
            .collect();
        println!("  {backend}: {}", pts.join(" "));
    }
    println!("\npaper claim (§5): end-to-end examples confirm consistent loss descent.");
}
