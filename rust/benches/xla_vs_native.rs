//! Ablation — the three-layer design choice (DESIGN.md): per-call cost of
//! the native engine vs the AOT-XLA path for the same MLP forward/train
//! step, plus executable-compile (load) cost amortization. Requires
//! `--features xla`; without it the bench prints a notice and exits.

#[cfg(feature = "xla")]
use std::time::Instant;

#[cfg(feature = "xla")]
use minitensor::autograd::Var;
#[cfg(feature = "xla")]
use minitensor::bench_util::{bench, fmt_ns, Table};
#[cfg(feature = "xla")]
use minitensor::data::Rng;
#[cfg(feature = "xla")]
use minitensor::nn::{losses, Activation, Dense, Module, Sequential};
#[cfg(feature = "xla")]
use minitensor::runtime::Engine;
#[cfg(feature = "xla")]
use minitensor::tensor::Tensor;

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("xla_vs_native requires `--features xla` (PJRT runtime not built)");
}

#[cfg(feature = "xla")]
fn main() {
    let Ok(mut engine) = Engine::cpu(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) else {
        eprintln!("artifacts missing — run `make artifacts`");
        return;
    };

    // One-time compile cost (the AOT tax, paid once per process).
    let t0 = Instant::now();
    engine.load("mlp_forward").expect("load forward");
    let compile_fwd = t0.elapsed();
    let t0 = Instant::now();
    engine.load("mlp_train_step").expect("load train step");
    let compile_step = t0.elapsed();
    println!(
        "one-time PJRT compile: mlp_forward {:.1} ms, mlp_train_step {:.1} ms",
        compile_fwd.as_secs_f64() * 1e3,
        compile_step.as_secs_f64() * 1e3
    );

    let art = engine.manifest().get("mlp_train_step").unwrap().clone();
    let batch = art.input_shapes[0][0];
    let feats = art.input_shapes[0][1];
    let classes = art.input_shapes[1][1];

    let mut rng = Rng::new(6);
    let x = Tensor::rand(&[batch, feats], 0.0, 1.0, &mut rng);
    let labels_vec: Vec<i32> = (0..batch).map(|i| (i % classes) as i32).collect();
    let labels = Tensor::from_vec_i32(labels_vec, &[batch]).unwrap();
    let y_onehot = Tensor::one_hot(&labels, classes).unwrap();
    let params: Vec<Tensor> = art.input_shapes[2..]
        .iter()
        .map(|s| {
            if s.len() == 2 {
                minitensor::nn::kaiming_uniform(s, s[1], &mut rng)
            } else {
                Tensor::zeros(s)
            }
        })
        .collect();

    // Native model with identical weights.
    let model = Sequential::new()
        .add(Dense::from_tensors(params[0].clone(), Some(params[1].clone())))
        .add(Activation::Relu)
        .add(Dense::from_tensors(params[2].clone(), Some(params[3].clone())))
        .add(Activation::Relu)
        .add(Dense::from_tensors(params[4].clone(), Some(params[5].clone())));

    let mut t = Table::new(
        "ablation — native engine vs AOT-XLA executable (batch=64 MLP)",
        &["operation", "native", "xla-aot", "xla/native"],
    );

    // Forward.
    let native_fwd = bench("native fwd", 80.0, 7, || {
        minitensor::autograd::no_grad(|| {
            let v = Var::from_tensor(x.clone(), false);
            std::hint::black_box(model.forward(&v, false).unwrap().data());
        });
    });
    let xla_fwd = bench("xla fwd", 80.0, 7, || {
        let mut inputs: Vec<&Tensor> = vec![&x];
        inputs.extend(params.iter());
        std::hint::black_box(engine.run("mlp_forward", &inputs).unwrap());
    });
    t.row(&[
        "forward".into(),
        fmt_ns(native_fwd.median_ns),
        fmt_ns(xla_fwd.median_ns),
        format!("{:.2}x", xla_fwd.median_ns / native_fwd.median_ns),
    ]);

    // Full train step (fwd+bwd+update).
    let native_step = bench("native step", 80.0, 7, || {
        model.zero_grad();
        let v = Var::from_tensor(x.clone(), false);
        let loss = losses::cross_entropy(&model.forward(&v, true).unwrap(), &labels).unwrap();
        loss.backward().unwrap();
        // inline SGD update to mirror the fused artifact
        minitensor::autograd::no_grad(|| {
            for p in model.parameters() {
                if let Some(g) = p.grad() {
                    p.set_data(p.data().sub(&g.mul_scalar(0.05)).unwrap());
                }
            }
        });
        std::hint::black_box(());
    });
    let mut step_params = params.clone();
    let xla_step = bench("xla step", 80.0, 7, || {
        let mut inputs: Vec<&Tensor> = vec![&x, &y_onehot];
        inputs.extend(step_params.iter());
        let mut outs = engine.run("mlp_train_step", &inputs).unwrap();
        outs.remove(0);
        step_params = outs;
        std::hint::black_box(());
    });
    t.row(&[
        "train step (fwd+bwd+sgd)".into(),
        fmt_ns(native_step.median_ns),
        fmt_ns(xla_step.median_ns),
        format!("{:.2}x", xla_step.median_ns / native_step.median_ns),
    ]);
    t.print();

    let amortize = compile_step.as_secs_f64() * 1e9
        / (native_step.median_ns - xla_step.median_ns).abs().max(1.0);
    println!(
        "\ncompile amortization: the {:.0} ms train-step compile pays for itself after ~{:.0} steps",
        compile_step.as_secs_f64() * 1e3,
        amortize
    );
}
