//! Finite-difference gradient checking (paper §5, eq 11):
//!
//! ```text
//! ∂L/∂θ_i ≈ (L(θ + ε e_i) − L(θ − ε e_i)) / 2ε
//! ```
//!
//! Central differences against the autograd gradient, probe-by-probe. Used
//! by the test suite on every primitive and layer; "although finite
//! differences are slow, they provide a reference for edge cases and
//! broadcasting semantics."

use super::Var;
use crate::error::Result;
use crate::tensor::Tensor;

/// Outcome of a gradient check on one input.
#[derive(Debug, Clone)]
pub struct GradCheckReport {
    /// Maximum absolute difference between analytic and numeric gradients.
    pub max_abs_diff: f32,
    /// Maximum relative difference (scaled by gradient magnitude).
    pub max_rel_diff: f32,
    /// Number of probe coordinates compared.
    pub probes: usize,
    /// Whether the check passed the tolerance it was run with.
    pub pass: bool,
}

/// Check `f`'s gradient w.r.t. `input` at the given point.
///
/// `f` must build a scalar loss from a leaf `Var`. Every coordinate is
/// probed when `numel <= 64`; otherwise a deterministic stratified subset
/// of 64 coordinates is used to keep the check fast.
pub fn gradcheck(f: impl Fn(&Var) -> Result<Var>, input: &Tensor, eps: f32, tol: f32) -> Result<GradCheckReport> {
    gradcheck_verbose(f, input, eps, tol, false)
}

/// [`gradcheck`] that optionally prints per-probe diagnostics.
pub fn gradcheck_verbose(
    f: impl Fn(&Var) -> Result<Var>,
    input: &Tensor,
    eps: f32,
    tol: f32,
    verbose: bool,
) -> Result<GradCheckReport> {
    // Analytic gradient.
    let leaf = Var::from_tensor(input.clone(), true);
    let loss = f(&leaf)?;
    loss.backward()?;
    let analytic = leaf
        .grad()
        .ok_or_else(|| crate::Error::msg("gradcheck: no gradient reached the input"))?
        .to_vec();

    // Probe set.
    let n = input.numel();
    let probes: Vec<usize> = if n <= 64 {
        (0..n).collect()
    } else {
        // Deterministic stratified subset: 64 evenly spaced coordinates.
        (0..64).map(|i| i * n / 64).collect()
    };

    let base = input.to_vec();
    let mut max_abs = 0.0f32;
    let mut max_rel = 0.0f32;
    for &i in &probes {
        let mut plus = base.clone();
        plus[i] += eps;
        let mut minus = base.clone();
        minus[i] -= eps;
        let lp = eval_loss(&f, &plus, input)?;
        let lm = eval_loss(&f, &minus, input)?;
        let numeric = (lp - lm) / (2.0 * eps);
        let abs = (numeric - analytic[i]).abs();
        let rel = abs / analytic[i].abs().max(numeric.abs()).max(1.0);
        if verbose && abs > tol {
            eprintln!(
                "gradcheck probe {i}: analytic={} numeric={numeric} abs={abs}",
                analytic[i]
            );
        }
        max_abs = max_abs.max(abs);
        max_rel = max_rel.max(rel);
    }

    Ok(GradCheckReport {
        max_abs_diff: max_abs,
        max_rel_diff: max_rel,
        probes: probes.len(),
        pass: max_rel <= tol,
    })
}

fn eval_loss(f: &impl Fn(&Var) -> Result<Var>, data: &[f32], proto: &Tensor) -> Result<f32> {
    let t = Tensor::from_vec(data.to_vec(), proto.dims())?;
    let v = Var::from_tensor(t, false);
    // The loss value itself doesn't need a graph.
    super::no_grad(|| f(&v))?.item()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn passes_on_correct_gradient() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[3, 3], 0.0, 1.0, &mut rng);
        let report = gradcheck(
            |v| v.square().unwrap_sum(),
            &x,
            1e-3,
            1e-2,
        )
        .unwrap();
        assert!(report.pass, "{report:?}");
        assert_eq!(report.probes, 9);
    }

    #[test]
    fn catches_wrong_gradient() {
        // Deliberately wrong: use sigmoid forward but relu-style graph by
        // composing x.relu() then comparing against sigmoid — instead we
        // simply test that an intentionally mismatched loss/grad pair
        // fails: f uses x^3 but we check against the gradient of x^2 by
        // constructing a function whose autograd is inconsistent is not
        // possible through the public API, so assert a tight tolerance
        // fails for a noisy function instead.
        let x = Tensor::from_vec(vec![0.5, -0.25], &[2]).unwrap();
        // |x| has a kink; probing near 0 with large eps gives mismatch
        let x_kink = Tensor::from_vec(vec![1e-5, -1e-5], &[2]).unwrap();
        let good = gradcheck(|v| v.abs().unwrap_sum(), &x, 1e-3, 1e-2).unwrap();
        assert!(good.pass);
        let bad = gradcheck(|v| v.abs().unwrap_sum(), &x_kink, 1e-3, 1e-2).unwrap();
        assert!(!bad.pass, "kink probe should fail: {bad:?}");
    }

    #[test]
    fn large_input_subsamples() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[20, 20], 0.0, 1.0, &mut rng);
        let report = gradcheck(|v| v.mean(), &x, 1e-2, 1e-2).unwrap();
        assert!(report.pass);
        assert_eq!(report.probes, 64);
    }

    /// Helper so closures stay terse in tests.
    trait UnwrapSum {
        fn unwrap_sum(self) -> Result<Var>;
    }
    impl UnwrapSum for Var {
        fn unwrap_sum(self) -> Result<Var> {
            self.sum()
        }
    }
}
