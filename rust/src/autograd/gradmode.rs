//! Thread-local gradient-recording mode, mirroring `torch.no_grad()`.

use std::cell::Cell;

thread_local! {
    static GRAD_ENABLED: Cell<bool> = const { Cell::new(true) };
}

/// Whether ops on this thread currently record the autograd graph.
pub fn is_grad_enabled() -> bool {
    GRAD_ENABLED.with(|g| g.get())
}

/// Run `f` with graph recording disabled (inference / update steps).
pub fn no_grad<T>(f: impl FnOnce() -> T) -> T {
    let _guard = GradGuard::disable();
    f()
}

/// RAII guard that sets the grad mode and restores the previous value on
/// drop. Usable directly when a closure is inconvenient.
pub struct GradGuard {
    prev: bool,
}

impl GradGuard {
    /// Disable recording until the guard drops.
    pub fn disable() -> GradGuard {
        let prev = is_grad_enabled();
        GRAD_ENABLED.with(|g| g.set(false));
        GradGuard { prev }
    }

    /// Enable recording until the guard drops.
    pub fn enable() -> GradGuard {
        let prev = is_grad_enabled();
        GRAD_ENABLED.with(|g| g.set(true));
        GradGuard { prev }
    }
}

impl Drop for GradGuard {
    fn drop(&mut self) {
        GRAD_ENABLED.with(|g| g.set(self.prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_grad_restores_state() {
        assert!(is_grad_enabled());
        no_grad(|| {
            assert!(!is_grad_enabled());
            // nesting
            no_grad(|| assert!(!is_grad_enabled()));
            assert!(!is_grad_enabled());
        });
        assert!(is_grad_enabled());
    }

    #[test]
    fn guard_reenable_inside_no_grad() {
        no_grad(|| {
            let _g = GradGuard::enable();
            assert!(is_grad_enabled());
        });
        assert!(is_grad_enabled());
    }
}
