//! Reverse-mode automatic differentiation (paper §3.2).
//!
//! A dynamic computation graph is recorded during the forward pass whenever
//! a [`Var`] requires gradients. Each node stores references to its parents
//! and a *local pullback* mapping an output cotangent to input cotangents
//! (vector-Jacobian products, eq 2). `backward()` runs the chain rule
//! (eq 3) in reverse topological order, accumulating `∇θL` into leaf
//! gradients. Gradient buffers are allocated lazily — only when a backward
//! pass reaches them (§3.5).

mod gradmode;
pub mod gradcheck;
mod ops;
mod var;

pub use gradcheck::{gradcheck, gradcheck_verbose, GradCheckReport};
pub use gradmode::{is_grad_enabled, no_grad, GradGuard};
pub use var::{Var, VarId};
