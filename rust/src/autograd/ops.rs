//! Differentiable op wrappers: each forwards through `crate::ops` and
//! records the local pullback of paper §3.2.
//!
//! Pullback conventions (paper eqs 2-4):
//! - addition: `x̄ += z̄`, `ȳ += z̄`
//! - Hadamard: `x̄ += z̄ ⊙ y`, `ȳ += z̄ ⊙ x`
//! - matmul `Y = XW`: `X̄ += Ȳ Wᵀ`, `W̄ += Xᵀ Ȳ`
//! - dense `Y = XWᵀ`: `X̄ += Ȳ W`, `W̄ += Ȳᵀ X`  (eq 4)
//!
//! Broadcast pullbacks sum the cotangent over the expanded axes via
//! [`Tensor::reduce_grad_to`].

use super::var::{BackwardOp, Var};
use crate::error::Result;
use crate::graph::LazyTensor;
use crate::ops::attention::{attention_backward, attention_forward};
use crate::ops::conv::{
    avg_pool2d, conv2d, conv2d_backward_input, conv2d_backward_weight, max_pool2d, Conv2dSpec,
};
use crate::ops::softmax::cross_entropy_forward;
use crate::ops::unary::gelu_grad_scalar;
use crate::tensor::Tensor;

/// Build a non-recording result when no parent needs gradients.
fn constant(out: Tensor) -> Var {
    Var::from_tensor(out, false)
}

impl Var {
    // ---------------------------------------------------------------
    // Binary arithmetic (broadcasting)
    // ---------------------------------------------------------------

    /// `z = x + y`; pullbacks `x̄ += z̄`, `ȳ += z̄` (broadcast-reduced).
    pub fn add(&self, other: &Var) -> Result<Var> {
        let out = self.data().add(&other.data())?;
        if !Var::any_requires_grad(&[self, other]) {
            return Ok(constant(out));
        }
        let (xa, xb) = (self.data(), other.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), other.clone()],
                name: "add",
                pullback: Box::new(move |g| {
                    vec![
                        Some(xa.reduce_grad_to(g).unwrap()),
                        Some(xb.reduce_grad_to(g).unwrap()),
                    ]
                }),
            },
        ))
    }

    /// `z = x - y`.
    pub fn sub(&self, other: &Var) -> Result<Var> {
        let out = self.data().sub(&other.data())?;
        if !Var::any_requires_grad(&[self, other]) {
            return Ok(constant(out));
        }
        let (xa, xb) = (self.data(), other.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), other.clone()],
                name: "sub",
                pullback: Box::new(move |g| {
                    vec![
                        Some(xa.reduce_grad_to(g).unwrap()),
                        Some(xb.reduce_grad_to(&g.neg()).unwrap()),
                    ]
                }),
            },
        ))
    }

    /// Hadamard product `z = x ⊙ y`.
    pub fn mul(&self, other: &Var) -> Result<Var> {
        let out = self.data().mul(&other.data())?;
        if !Var::any_requires_grad(&[self, other]) {
            return Ok(constant(out));
        }
        let (xa, xb) = (self.data(), other.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), other.clone()],
                name: "mul",
                pullback: Box::new(move |g| {
                    let gx = g.mul(&xb).unwrap();
                    let gy = g.mul(&xa).unwrap();
                    vec![
                        Some(xa.reduce_grad_to(&gx).unwrap()),
                        Some(xb.reduce_grad_to(&gy).unwrap()),
                    ]
                }),
            },
        ))
    }

    /// `z = x / y`.
    pub fn div(&self, other: &Var) -> Result<Var> {
        let out = self.data().div(&other.data())?;
        if !Var::any_requires_grad(&[self, other]) {
            return Ok(constant(out));
        }
        let (xa, xb) = (self.data(), other.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), other.clone()],
                name: "div",
                pullback: Box::new(move |g| {
                    // x̄ = ḡ / y ; ȳ = -ḡ x / y²
                    let gx = g.div(&xb).unwrap();
                    let gy = g
                        .mul(&xa)
                        .unwrap()
                        .div(&xb.square())
                        .unwrap()
                        .neg();
                    vec![
                        Some(xa.reduce_grad_to(&gx).unwrap()),
                        Some(xb.reduce_grad_to(&gy).unwrap()),
                    ]
                }),
            },
        ))
    }

    /// Add a scalar constant (gradient passes through).
    pub fn add_scalar(&self, s: f32) -> Var {
        let out = self.data().add_scalar(s);
        if !Var::any_requires_grad(&[self]) {
            return constant(out);
        }
        Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "add_scalar",
                pullback: Box::new(move |g| vec![Some(g.clone())]),
            },
        )
    }

    /// Multiply by a scalar constant.
    pub fn mul_scalar(&self, s: f32) -> Var {
        let out = self.data().mul_scalar(s);
        if !Var::any_requires_grad(&[self]) {
            return constant(out);
        }
        Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "mul_scalar",
                pullback: Box::new(move |g| vec![Some(g.mul_scalar(s))]),
            },
        )
    }

    // ---------------------------------------------------------------
    // Unary maps
    // ---------------------------------------------------------------

    /// Generic recorded unary op: `forward` computes the value, `vjp`
    /// computes `x̄` from `(x, y, ḡ)`.
    fn unary(
        &self,
        name: &'static str,
        forward: impl Fn(&Tensor) -> Tensor,
        vjp: impl Fn(&Tensor, &Tensor, &Tensor) -> Tensor + 'static,
    ) -> Var {
        let x = self.data();
        let out = forward(&x);
        if !Var::any_requires_grad(&[self]) {
            return constant(out);
        }
        let y = out.clone();
        Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name,
                pullback: Box::new(move |g| vec![Some(vjp(&x, &y, g))]),
            },
        )
    }

    /// Elementwise negation.
    pub fn neg(&self) -> Var {
        self.unary("neg", |x| x.neg(), |_, _, g| g.neg())
    }

    /// Elementwise exp; `x̄ = ḡ ⊙ e^x` (reuses the forward output).
    pub fn exp(&self) -> Var {
        self.unary("exp", |x| x.exp(), |_, y, g| g.mul(y).unwrap())
    }

    /// Natural log; `x̄ = ḡ / x`.
    pub fn log(&self) -> Var {
        self.unary("log", |x| x.log(), |x, _, g| g.div(x).unwrap())
    }

    /// Square root; `x̄ = ḡ / (2√x)`.
    pub fn sqrt(&self) -> Var {
        self.unary(
            "sqrt",
            |x| x.sqrt(),
            |_, y, g| g.div(&y.mul_scalar(2.0)).unwrap(),
        )
    }

    /// Elementwise square; `x̄ = 2x ⊙ ḡ`.
    pub fn square(&self) -> Var {
        self.unary(
            "square",
            |x| x.square(),
            |x, _, g| g.mul(&x.mul_scalar(2.0)).unwrap(),
        )
    }

    /// Scalar power; `x̄ = s·x^{s-1} ⊙ ḡ`.
    pub fn pow_scalar(&self, s: f32) -> Var {
        self.unary(
            "pow_scalar",
            move |x| x.pow_scalar(s),
            move |x, _, g| g.mul(&x.pow_scalar(s - 1.0).mul_scalar(s)).unwrap(),
        )
    }

    /// Reciprocal; `x̄ = -ḡ / x²`.
    pub fn recip(&self) -> Var {
        self.unary(
            "recip",
            |x| x.recip(),
            |x, _, g| g.div(&x.square()).unwrap().neg(),
        )
    }

    /// Absolute value; `x̄ = sign(x) ⊙ ḡ` (0 at 0).
    pub fn abs(&self) -> Var {
        self.unary(
            "abs",
            |x| x.abs(),
            |x, _, g| {
                g.mul(&x.map(|v| {
                    if v > 0.0 {
                        1.0
                    } else if v < 0.0 {
                        -1.0
                    } else {
                        0.0
                    }
                }))
                .unwrap()
            },
        )
    }

    /// Sine.
    pub fn sin(&self) -> Var {
        self.unary("sin", |x| x.sin(), |x, _, g| g.mul(&x.cos()).unwrap())
    }

    /// Cosine.
    pub fn cos(&self) -> Var {
        self.unary(
            "cos",
            |x| x.cos(),
            |x, _, g| g.mul(&x.sin()).unwrap().neg(),
        )
    }

    /// Clamp; gradient passes only inside the open interval.
    pub fn clamp(&self, lo: f32, hi: f32) -> Var {
        self.unary(
            "clamp",
            move |x| x.clamp(lo, hi),
            move |x, _, g| {
                g.mul(&x.map(move |v| f32::from(v > lo && v < hi)))
                    .unwrap()
            },
        )
    }

    // ---------------------------------------------------------------
    // Nonlinearities (paper §3.3)
    // ---------------------------------------------------------------

    /// ReLU; `∂ReLU(x)/∂x = 1{x > 0}`.
    pub fn relu(&self) -> Var {
        self.unary(
            "relu",
            |x| x.relu(),
            |x, _, g| g.mul(&x.map(|v| f32::from(v > 0.0))).unwrap(),
        )
    }

    /// Leaky ReLU with negative slope `alpha`.
    pub fn leaky_relu(&self, alpha: f32) -> Var {
        self.unary(
            "leaky_relu",
            move |x| x.leaky_relu(alpha),
            move |x, _, g| {
                g.mul(&x.map(move |v| if v > 0.0 { 1.0 } else { alpha }))
                    .unwrap()
            },
        )
    }

    /// Sigmoid; `x̄ = ḡ ⊙ σ(x)(1-σ(x))` (reuses the output).
    pub fn sigmoid(&self) -> Var {
        self.unary(
            "sigmoid",
            |x| x.sigmoid(),
            |_, y, g| {
                let one_minus = y.map(|v| 1.0 - v);
                g.mul(y).unwrap().mul(&one_minus).unwrap()
            },
        )
    }

    /// Tanh; `x̄ = ḡ ⊙ (1 - tanh²x)`.
    pub fn tanh(&self) -> Var {
        self.unary(
            "tanh",
            |x| x.tanh(),
            |_, y, g| g.mul(&y.map(|t| 1.0 - t * t)).unwrap(),
        )
    }

    /// GELU (tanh approximation) with its exact derivative.
    pub fn gelu(&self) -> Var {
        self.unary(
            "gelu",
            |x| x.gelu(),
            |x, _, g| g.mul(&x.map(gelu_grad_scalar)).unwrap(),
        )
    }

    /// Elementwise maximum with a constant `other` tensor is rare; the
    /// useful recorded form is dropout-style masking: `z = x ⊙ mask`
    /// where `mask` is a constant. Provided via [`Var::mul_mask`].
    pub fn mul_mask(&self, mask: &Tensor) -> Result<Var> {
        let out = self.data().mul(mask)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let m = mask.clone();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "mul_mask",
                pullback: Box::new(move |g| vec![Some(g.mul(&m).unwrap())]),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Matrix products (paper eq 1 / eq 4)
    // ---------------------------------------------------------------

    /// 2-D matmul `Y = X · W`; `X̄ = Ȳ Wᵀ`, `W̄ = Xᵀ Ȳ`.
    pub fn matmul(&self, other: &Var) -> Result<Var> {
        let out = self.data().matmul(&other.data())?;
        if !Var::any_requires_grad(&[self, other]) {
            return Ok(constant(out));
        }
        let (x, w) = (self.data(), other.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), other.clone()],
                name: "matmul",
                pullback: Box::new(move |g| {
                    let gx = g.matmul(&w.t().unwrap()).unwrap();
                    let gw = x.t().unwrap().matmul(g).unwrap();
                    vec![Some(gx), Some(gw)]
                }),
            },
        ))
    }

    /// Dense product `Y = X · Wᵀ` (paper eq 1); pullbacks are eq (4):
    /// `X̄ = Ȳ W`, `W̄ = Ȳᵀ X`.
    pub fn matmul_nt(&self, w: &Var) -> Result<Var> {
        let out = self.data().matmul_nt(&w.data())?;
        if !Var::any_requires_grad(&[self, w]) {
            return Ok(constant(out));
        }
        let (x, wd) = (self.data(), w.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), w.clone()],
                name: "matmul_nt",
                pullback: Box::new(move |g| {
                    let gx = g.matmul(&wd).unwrap(); // Ȳ W
                    let gw = g.t().unwrap().matmul(&x).unwrap(); // Ȳᵀ X
                    vec![Some(gx), Some(gw)]
                }),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Reductions
    // ---------------------------------------------------------------

    /// Sum of all elements; `x̄ = ḡ · 1`.
    pub fn sum(&self) -> Result<Var> {
        let out = self.data().sum();
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let dims = self.dims();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "sum",
                pullback: Box::new(move |g| {
                    let seed = g.item().unwrap();
                    vec![Some(Tensor::full(&dims, seed))]
                }),
            },
        ))
    }

    /// Mean of all elements.
    pub fn mean(&self) -> Result<Var> {
        let n = self.data().numel() as f32;
        Ok(self.sum()?.mul_scalar(1.0 / n))
    }

    /// Sum along an axis.
    pub fn sum_axis(&self, axis: isize, keepdim: bool) -> Result<Var> {
        let out = self.data().sum_axis(axis, keepdim)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let dims = self.dims();
        let ax = self.data().shape().normalize_axis(axis)?;
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "sum_axis",
                pullback: Box::new(move |g| {
                    // restore the reduced axis, then broadcast back
                    let g2 = if keepdim {
                        g.clone()
                    } else {
                        g.unsqueeze(ax as isize).unwrap()
                    };
                    vec![Some(g2.broadcast_to(&dims).unwrap().contiguous())]
                }),
            },
        ))
    }

    /// Mean along an axis.
    pub fn mean_axis(&self, axis: isize, keepdim: bool) -> Result<Var> {
        let ax = self.data().shape().normalize_axis(axis)?;
        let n = self.dims()[ax] as f32;
        Ok(self.sum_axis(axis, keepdim)?.mul_scalar(1.0 / n))
    }

    /// Global max; the cotangent routes to the (first) argmax element.
    pub fn max_all(&self) -> Result<Var> {
        let out = self.data().max_all();
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let x = self.data();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "max_all",
                pullback: Box::new(move |g| {
                    let flat = x.to_vec();
                    let arg = crate::ops::kernels::argmax(&flat);
                    let mut grad = vec![0.0f32; flat.len()];
                    grad[arg] = g.item().unwrap();
                    vec![Some(Tensor::from_vec(grad, x.dims()).unwrap())]
                }),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Shape ops
    // ---------------------------------------------------------------

    /// Reshape; the pullback reshapes the cotangent back.
    pub fn reshape(&self, dims: &[usize]) -> Result<Var> {
        let out = self.data().reshape(dims)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let orig = self.dims();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "reshape",
                pullback: Box::new(move |g| vec![Some(g.reshape(&orig).unwrap())]),
            },
        ))
    }

    /// Transpose two axes; the pullback swaps them back.
    pub fn transpose(&self, a: isize, b: isize) -> Result<Var> {
        let out = self.data().transpose(a, b)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "transpose",
                pullback: Box::new(move |g| {
                    vec![Some(g.transpose(a, b).unwrap().contiguous())]
                }),
            },
        ))
    }

    /// Broadcast to a larger shape; the pullback sums over expanded axes.
    pub fn broadcast_to(&self, dims: &[usize]) -> Result<Var> {
        let out = self.data().broadcast_to(dims)?.contiguous();
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let x = self.data();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "broadcast_to",
                pullback: Box::new(move |g| vec![Some(x.reduce_grad_to(g).unwrap())]),
            },
        ))
    }

    /// Concatenate along an axis; the pullback splits the cotangent.
    pub fn cat(vars: &[&Var], axis: isize) -> Result<Var> {
        let datas: Vec<Tensor> = vars.iter().map(|v| v.data()).collect();
        let refs: Vec<&Tensor> = datas.iter().collect();
        let out = Tensor::cat(&refs, axis)?;
        if !super::gradmode::is_grad_enabled() || !vars.iter().any(|v| v.requires_grad()) {
            return Ok(constant(out));
        }
        let ax = out.shape().normalize_axis(axis)?;
        let sizes: Vec<usize> = datas.iter().map(|d| d.dims()[ax]).collect();
        let parents: Vec<Var> = vars.iter().map(|v| (*v).clone()).collect();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents,
                name: "cat",
                pullback: Box::new(move |g| {
                    let mut start = 0usize;
                    sizes
                        .iter()
                        .map(|&len| {
                            let piece =
                                g.narrow(ax as isize, start, len).unwrap().contiguous();
                            start += len;
                            Some(piece)
                        })
                        .collect()
                }),
            },
        ))
    }

    /// Gather rows of a `[vocab, d]` table by i32 ids; the pullback
    /// scatter-adds the cotangent back into the table (sparse gradient).
    pub fn gather_rows(&self, ids: &Tensor, n_rows: usize) -> Result<Var> {
        let out = self.data().index_select0(ids)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let ids = ids.clone();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "gather_rows",
                pullback: Box::new(move |g| {
                    vec![Some(Tensor::scatter_add0(g, &ids, n_rows).unwrap())]
                }),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Softmax family (paper eq 8)
    // ---------------------------------------------------------------

    /// Softmax along the last axis; `x̄ = (ḡ - Σ(ḡ⊙y)) ⊙ y`.
    pub fn softmax(&self) -> Result<Var> {
        let out = self.data().softmax()?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let y = out.clone();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "softmax",
                pullback: Box::new(move |g| {
                    let dot = g.mul(&y).unwrap().sum_axis(-1, true).unwrap();
                    let centered = g.sub(&dot).unwrap();
                    vec![Some(centered.mul(&y).unwrap())]
                }),
            },
        ))
    }

    /// Log-softmax; `x̄ = ḡ - softmax(x) · Σḡ`.
    pub fn log_softmax(&self) -> Result<Var> {
        let out = self.data().log_softmax()?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let probs = out.exp();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "log_softmax",
                pullback: Box::new(move |g| {
                    let gsum = g.sum_axis(-1, true).unwrap();
                    let correction = probs.mul(&gsum).unwrap();
                    vec![Some(g.sub(&correction).unwrap())]
                }),
            },
        ))
    }

    /// Fused mean cross-entropy over logits (eq 8); pullback is the classic
    /// `(softmax - onehot)/b`.
    pub fn cross_entropy(&self, labels: &Tensor) -> Result<Var> {
        let (loss, probs) = cross_entropy_forward(&self.data(), labels)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(loss));
        }
        let onehot = Tensor::one_hot(labels, probs.dims()[1])?;
        let b = probs.dims()[0] as f32;
        Ok(Var::from_op(
            loss,
            BackwardOp {
                parents: vec![self.clone()],
                name: "cross_entropy",
                pullback: Box::new(move |g| {
                    let seed = g.item().unwrap();
                    let diff = probs.sub(&onehot).unwrap();
                    vec![Some(diff.mul_scalar(seed / b))]
                }),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Attention
    // ---------------------------------------------------------------

    /// Scaled-dot-product attention `softmax(q kᵀ / √d) v` with recorded
    /// pullbacks w.r.t. q, k, and v. The forward saves the softmax
    /// probability rows so the backward reuses them instead of re-running
    /// the softmax; every gradient product dispatches through the
    /// execution layer (see `ops::attention`).
    pub fn attention(&self, key: &Var, value: &Var) -> Result<Var> {
        let (out, probs) = attention_forward(&self.data(), &key.data(), &value.data())?;
        if !Var::any_requires_grad(&[self, key, value]) {
            return Ok(constant(out));
        }
        let (q, k, v) = (self.data(), key.data(), value.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), key.clone(), value.clone()],
                name: "attention",
                pullback: Box::new(move |g| {
                    let (dq, dk, dv) = attention_backward(g, &q, &k, &v, &probs).unwrap();
                    vec![Some(dq), Some(dk), Some(dv)]
                }),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Fused lazy regions (graph subsystem)
    // ---------------------------------------------------------------

    /// Run a fused lazy region as one recorded autograd op: `build`
    /// records a [`LazyTensor`] expression over one leaf per input var,
    /// the forward evaluates it with single-pass kernel fusion
    /// (`graph::LazyTensor::eval` — one exec dispatch and one output
    /// allocation per region, bitwise-equal to the eager chain), and the
    /// pullback **replays the region's VJP** (`graph::grad::vjp`):
    /// intermediates are recomputed eagerly on backward rather than
    /// saved, so the fused forward stays allocation-free.
    ///
    /// ```
    /// # use minitensor::prelude::*;
    /// let a = Var::from_tensor(Tensor::arange(-4.0, 4.0), true);
    /// let b = Var::from_tensor(Tensor::arange(1.0, 9.0), false);
    /// let y = Var::fused(&[&a, &b], |l| Ok(l[0].mul(&l[1])?.relu().sum()))
    ///     .unwrap();
    /// y.backward().unwrap();
    /// assert!(a.grad().is_some());
    /// ```
    ///
    /// An input the expression never touches gets no gradient, and —
    /// like the eager tape skipping constant branches — inputs with
    /// `requires_grad = false` at backward time cost nothing: the VJP
    /// replay never descends their dead paths. Passing the same var
    /// twice yields two leaves whose partials both accumulate into that
    /// var, exactly like using it twice eagerly.
    pub fn fused(
        inputs: &[&Var],
        build: impl FnOnce(&[LazyTensor]) -> Result<LazyTensor>,
    ) -> Result<Var> {
        let leaves: Vec<LazyTensor> = inputs.iter().map(|v| v.data().lazy()).collect();
        let expr = build(&leaves)?;
        let out = expr.eval()?;
        if !Var::any_requires_grad(inputs) {
            return Ok(constant(out));
        }
        let leaf_ids: Vec<usize> = leaves.iter().map(LazyTensor::node_id).collect();
        let root = expr.node().clone();
        let parents: Vec<Var> = inputs.iter().map(|v| (*v).clone()).collect();
        let handles = parents.clone();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents,
                name: "fused",
                pullback: Box::new(move |g| {
                    // Liveness is read at pullback time (like the eager
                    // tape's runtime requires_grad checks), so flipping
                    // a leaf's requires_grad after recording behaves
                    // identically to the eager ops.
                    let live: std::collections::HashSet<usize> = leaf_ids
                        .iter()
                        .zip(&handles)
                        .filter(|(_, v)| v.requires_grad())
                        .map(|(id, _)| *id)
                        .collect();
                    let mut grads = crate::graph::grad::vjp_for(&root, g, Some(&live))
                        .expect("fused region VJP");
                    leaf_ids.iter().map(|id| grads.remove(id)).collect()
                }),
            },
        ))
    }

    // ---------------------------------------------------------------
    // Convolution / pooling (paper eq 6)
    // ---------------------------------------------------------------

    /// 2-D convolution with recorded pullbacks w.r.t. input and weight.
    pub fn conv2d(&self, weight: &Var, spec: Conv2dSpec) -> Result<Var> {
        let out = conv2d(&self.data(), &weight.data(), spec)?;
        if !Var::any_requires_grad(&[self, weight]) {
            return Ok(constant(out));
        }
        let (x, w) = (self.data(), weight.data());
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone(), weight.clone()],
                name: "conv2d",
                pullback: Box::new(move |g| {
                    let dx = conv2d_backward_input(g, &w, x.dims(), spec).unwrap();
                    let dw = conv2d_backward_weight(g, &x, w.dims(), spec).unwrap();
                    vec![Some(dx), Some(dw)]
                }),
            },
        ))
    }

    /// Max-pool with window/stride `k`; the cotangent scatters to argmax
    /// positions.
    pub fn max_pool2d(&self, k: usize) -> Result<Var> {
        let (out, arg) = max_pool2d(&self.data(), k)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let in_dims = self.dims();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "max_pool2d",
                pullback: Box::new(move |g| {
                    let gv = g.to_vec();
                    let mut dx = vec![0.0f32; in_dims.iter().product()];
                    for (o, &src) in arg.iter().enumerate() {
                        dx[src] += gv[o];
                    }
                    vec![Some(Tensor::from_vec(dx, &in_dims).unwrap())]
                }),
            },
        ))
    }

    /// Average-pool with window/stride `k`; the cotangent spreads evenly.
    pub fn avg_pool2d(&self, k: usize) -> Result<Var> {
        let out = avg_pool2d(&self.data(), k)?;
        if !Var::any_requires_grad(&[self]) {
            return Ok(constant(out));
        }
        let in_dims = self.dims();
        Ok(Var::from_op(
            out,
            BackwardOp {
                parents: vec![self.clone()],
                name: "avg_pool2d",
                pullback: Box::new(move |g| {
                    let (n, c, oh, ow) = (
                        g.dims()[0],
                        g.dims()[1],
                        g.dims()[2],
                        g.dims()[3],
                    );
                    let gv = g.to_vec();
                    let (h, w) = (in_dims[2], in_dims[3]);
                    let inv = 1.0 / (k * k) as f32;
                    let mut dx = vec![0.0f32; in_dims.iter().product()];
                    for img in 0..n * c {
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let gval = gv[img * oh * ow + oy * ow + ox] * inv;
                                for dy in 0..k {
                                    for dxx in 0..k {
                                        dx[img * h * w + (oy * k + dy) * w + ox * k + dxx] +=
                                            gval;
                                    }
                                }
                            }
                        }
                    }
                    vec![Some(Tensor::from_vec(dx, &in_dims).unwrap())]
                }),
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::no_grad;
    use crate::data::Rng;

    fn leaf(v: Vec<f32>, dims: &[usize]) -> Var {
        Var::from_tensor(Tensor::from_vec(v, dims).unwrap(), true)
    }

    #[test]
    fn add_pullback() {
        let x = leaf(vec![1., 2.], &[2]);
        let y = leaf(vec![3., 4.], &[2]);
        let z = x.add(&y).unwrap().sum().unwrap();
        z.backward().unwrap();
        assert_eq!(x.grad().unwrap().to_vec(), vec![1., 1.]);
        assert_eq!(y.grad().unwrap().to_vec(), vec![1., 1.]);
    }

    #[test]
    fn mul_pullback_is_hadamard() {
        let x = leaf(vec![2., 3.], &[2]);
        let y = leaf(vec![5., 7.], &[2]);
        let z = x.mul(&y).unwrap().sum().unwrap();
        z.backward().unwrap();
        assert_eq!(x.grad().unwrap().to_vec(), vec![5., 7.]); // = y
        assert_eq!(y.grad().unwrap().to_vec(), vec![2., 3.]); // = x
    }

    #[test]
    fn broadcast_add_reduces_bias_grad() {
        // paper's dense bias case: grad of b is summed over the batch
        let x = leaf(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        let b = leaf(vec![0.1, 0.2, 0.3], &[3]);
        let z = x.add(&b).unwrap().sum().unwrap();
        z.backward().unwrap();
        assert_eq!(b.grad().unwrap().dims(), &[3]);
        assert_eq!(b.grad().unwrap().to_vec(), vec![2., 2., 2.]);
    }

    #[test]
    fn matmul_pullbacks_match_eq4() {
        let mut rng = Rng::new(1);
        let x = Var::from_tensor(Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng), true);
        let w = Var::from_tensor(Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng), true);
        // Y = X Wᵀ, L = sum(Y) ⇒ Ȳ = 1; X̄ = 1·W ; W̄ = 1ᵀ·X
        let y = x.matmul_nt(&w).unwrap();
        y.sum().unwrap().backward().unwrap();
        let ones = Tensor::ones(&[3, 5]);
        let gx_expect = ones.matmul(&w.data()).unwrap();
        let gw_expect = ones.t().unwrap().matmul(&x.data()).unwrap();
        assert!(x.grad().unwrap().allclose(&gx_expect, 1e-5, 1e-6));
        assert!(w.grad().unwrap().allclose(&gw_expect, 1e-5, 1e-6));
    }

    #[test]
    fn chain_rule_composition() {
        // L = sum((x * 2 + 1)^2) ⇒ dL/dx = 2(2x+1)*2
        let x = leaf(vec![1.0, -0.5], &[2]);
        let z = x.mul_scalar(2.0).add_scalar(1.0).square().sum().unwrap();
        z.backward().unwrap();
        let expect: Vec<f32> = vec![4.0 * (2.0 + 1.0), 4.0 * (-1.0 + 1.0)];
        assert_eq!(x.grad().unwrap().to_vec(), expect);
    }

    #[test]
    fn reuse_accumulates_through_graph() {
        // z = x*x (x used twice through separate ops) ⇒ dz/dx = 2x
        let x = leaf(vec![3.0], &[1]);
        let z = x.mul(&x).unwrap().sum().unwrap();
        z.backward().unwrap();
        assert_eq!(x.grad().unwrap().to_vec(), vec![6.0]);
    }

    #[test]
    fn diamond_graph_accumulates_once_per_path() {
        // y = x+x; z = sum(y) ⇒ dz/dx = 2
        let x = leaf(vec![1.0], &[1]);
        let y = x.add(&x).unwrap();
        y.sum().unwrap().backward().unwrap();
        assert_eq!(x.grad().unwrap().to_vec(), vec![2.0]);
    }

    #[test]
    fn no_grad_suppresses_recording() {
        let x = leaf(vec![1.0], &[1]);
        let y = no_grad(|| x.mul_scalar(3.0));
        assert!(y.is_leaf());
        assert!(!y.requires_grad());
    }

    #[test]
    fn constant_branches_skip_graph() {
        let x = leaf(vec![1.0, 2.0], &[2]);
        let c = Var::from_tensor(Tensor::ones(&[2]), false);
        let z = x.mul(&c).unwrap().sum().unwrap();
        z.backward().unwrap();
        assert!(x.grad().is_some());
        assert!(c.grad().is_none());
    }

    #[test]
    fn softmax_grad_sums_to_zero() {
        // Softmax rows are on the simplex ⇒ pullback of any ḡ sums to 0/row.
        let mut rng = Rng::new(2);
        let x = Var::from_tensor(Tensor::randn(&[4, 7], 0.0, 1.0, &mut rng), true);
        let p = x.softmax().unwrap();
        // weighted sum with random weights to get a scalar
        let wts = Tensor::randn(&[4, 7], 0.0, 1.0, &mut rng);
        let loss = p.mul_mask(&wts).unwrap().sum().unwrap();
        loss.backward().unwrap();
        let g = x.grad().unwrap();
        let row_sums = g.sum_axis(1, false).unwrap();
        assert!(row_sums.allclose(&Tensor::zeros(&[4]), 1e-4, 1e-4));
    }

    #[test]
    fn cross_entropy_grad_is_probs_minus_onehot() {
        let logits = leaf(vec![2.0, 0.0, -1.0, 0.5, 1.5, 0.0], &[2, 3]);
        let labels = Tensor::from_vec_i32(vec![0, 2], &[2]).unwrap();
        let loss = logits.cross_entropy(&labels).unwrap();
        loss.backward().unwrap();
        let probs = logits.data().softmax().unwrap();
        let onehot = Tensor::one_hot(&labels, 3).unwrap();
        let expect = probs.sub(&onehot).unwrap().mul_scalar(0.5);
        assert!(logits.grad().unwrap().allclose(&expect, 1e-5, 1e-6));
    }

    #[test]
    fn reshape_transpose_roundtrip_grads() {
        let x = leaf(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        let z = x
            .reshape(&[3, 2])
            .unwrap()
            .transpose(0, 1)
            .unwrap()
            .sum()
            .unwrap();
        z.backward().unwrap();
        assert_eq!(x.grad().unwrap().dims(), &[2, 3]);
        assert_eq!(x.grad().unwrap().to_vec(), vec![1.0; 6]);
    }

    #[test]
    fn cat_splits_cotangent() {
        let a = leaf(vec![1., 2.], &[2, 1]);
        let b = leaf(vec![3., 4.], &[2, 1]);
        let c = Var::cat(&[&a, &b], 1).unwrap();
        // weight the two columns differently
        let w = Tensor::from_vec(vec![1., 10., 1., 10.], &[2, 2]).unwrap();
        c.mul_mask(&w).unwrap().sum().unwrap().backward().unwrap();
        assert_eq!(a.grad().unwrap().to_vec(), vec![1., 1.]);
        assert_eq!(b.grad().unwrap().to_vec(), vec![10., 10.]);
    }

    #[test]
    fn max_all_routes_to_argmax() {
        let x = leaf(vec![1., 5., 3.], &[3]);
        x.max_all().unwrap().backward().unwrap();
        assert_eq!(x.grad().unwrap().to_vec(), vec![0., 1., 0.]);
    }

    #[test]
    fn sum_axis_grads_broadcast_back() {
        let x = leaf(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        let s = x.sum_axis(0, false).unwrap(); // [3]
        let w = Tensor::from_vec(vec![1., 2., 3.], &[3]).unwrap();
        s.mul_mask(&w).unwrap().sum().unwrap().backward().unwrap();
        assert_eq!(
            x.grad().unwrap().to_vec(),
            vec![1., 2., 3., 1., 2., 3.]
        );
    }

    #[test]
    fn conv_and_pool_record() {
        let mut rng = Rng::new(3);
        let x = Var::from_tensor(Tensor::randn(&[1, 1, 4, 4], 0.0, 1.0, &mut rng), true);
        let w = Var::from_tensor(Tensor::randn(&[2, 1, 3, 3], 0.0, 1.0, &mut rng), true);
        let y = x
            .conv2d(&w, Conv2dSpec { stride: 1, padding: 1 })
            .unwrap();
        let p = y.max_pool2d(2).unwrap();
        p.sum().unwrap().backward().unwrap();
        assert_eq!(x.grad().unwrap().dims(), &[1, 1, 4, 4]);
        assert_eq!(w.grad().unwrap().dims(), &[2, 1, 3, 3]);
    }

    #[test]
    fn attention_records_and_matches_gradcheck() {
        use crate::autograd::gradcheck::gradcheck;
        let mut rng = Rng::new(7);
        let q0 = Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng);
        let k = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);
        let v = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);

        // All three grads flow and have the right shapes.
        let (qv, kv, vv) = (
            Var::from_tensor(q0.clone(), true),
            Var::from_tensor(k.clone(), true),
            Var::from_tensor(v.clone(), true),
        );
        let out = qv.attention(&kv, &vv).unwrap();
        assert_eq!(out.op_name(), "attention");
        out.sum().unwrap().backward().unwrap();
        assert_eq!(qv.grad().unwrap().dims(), &[3, 4]);
        assert_eq!(kv.grad().unwrap().dims(), &[5, 4]);
        assert_eq!(vv.grad().unwrap().dims(), &[5, 4]);

        // Finite-difference check w.r.t. each input through the tape.
        let kc = Var::from_tensor(k.clone(), false);
        let vc = Var::from_tensor(v.clone(), false);
        let rq = gradcheck(|x| x.attention(&kc, &vc)?.sum(), &q0, 1e-2, 1e-2).unwrap();
        assert!(rq.pass, "dq: {rq:?}");
        let qc = Var::from_tensor(q0.clone(), false);
        let rk = gradcheck(|x| qc.attention(x, &vc)?.sum(), &k, 1e-2, 1e-2).unwrap();
        assert!(rk.pass, "dk: {rk:?}");
        let rv = gradcheck(|x| qc.attention(&kc, x)?.sum(), &v, 1e-2, 1e-2).unwrap();
        assert!(rv.pass, "dv: {rv:?}");
    }

    #[test]
    fn fused_forward_matches_eager_and_backward_matches_tape() {
        // y = sum(relu(a*b + a)) — fused vs the eager Var chain: same
        // value, same gradients.
        let mut rng = Rng::new(11);
        let a0 = Tensor::randn(&[5, 3], 0.0, 1.0, &mut rng);
        let b0 = Tensor::randn(&[5, 3], 0.0, 1.0, &mut rng);

        let (ae, be) = (
            Var::from_tensor(a0.clone(), true),
            Var::from_tensor(b0.clone(), true),
        );
        let eager = ae.mul(&be).unwrap().add(&ae).unwrap().relu().sum().unwrap();
        eager.backward().unwrap();

        let (af, bf) = (
            Var::from_tensor(a0.clone(), true),
            Var::from_tensor(b0.clone(), true),
        );
        let fused = Var::fused(&[&af, &bf], |l| {
            Ok(l[0].mul(&l[1])?.add(&l[0])?.relu().sum())
        })
        .unwrap();
        assert_eq!(fused.op_name(), "fused");
        assert_eq!(
            fused.item().unwrap().to_bits(),
            eager.item().unwrap().to_bits(),
            "fused forward is bitwise-equal to the eager chain"
        );
        fused.backward().unwrap();
        assert!(af
            .grad()
            .unwrap()
            .allclose(&ae.grad().unwrap(), 1e-6, 1e-6));
        assert!(bf
            .grad()
            .unwrap()
            .allclose(&be.grad().unwrap(), 1e-6, 1e-6));
    }

    #[test]
    fn fused_gradcheck_broadcast_bias() {
        use crate::autograd::gradcheck::gradcheck;
        let mut rng = Rng::new(12);
        let x = Tensor::randn(&[4, 3], 0.0, 0.5, &mut rng);
        let bias0 = Tensor::randn(&[3], 0.0, 0.5, &mut rng);
        let xc = Var::from_tensor(x, false);
        let r = gradcheck(
            |b: &Var| Var::fused(&[&xc, b], |l| Ok(l[0].add(&l[1])?.tanh().square().mean())),
            &bias0,
            1e-3,
            2e-2,
        )
        .unwrap();
        assert!(r.pass, "{r:?}");
    }

    #[test]
    fn fused_unused_input_gets_no_grad() {
        let a = Var::from_tensor(Tensor::ones(&[2]), true);
        let b = Var::from_tensor(Tensor::ones(&[2]), true);
        let y = Var::fused(&[&a, &b], |l| Ok(l[0].sum())).unwrap();
        y.backward().unwrap();
        assert!(a.grad().is_some());
        assert!(b.grad().is_none());
    }

    #[test]
    fn fused_constant_inputs_skip_recording() {
        let a = Var::from_tensor(Tensor::ones(&[3]), false);
        let y = Var::fused(&[&a], |l| Ok(l[0].relu().sum())).unwrap();
        assert!(y.is_leaf());
        assert!(!y.requires_grad());
    }

    #[test]
    fn graph_size_counts_nodes() {
        let x = leaf(vec![1.0], &[1]);
        let z = x.mul_scalar(2.0).add_scalar(1.0).sum().unwrap();
        // nodes: x, mul_scalar, add_scalar, sum
        assert_eq!(z.graph_size(), 4);
    }
}
