//! [`Var`]: a tensor participating in the dynamic autograd graph.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::gradmode::is_grad_enabled;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Unique id for graph nodes (monotonic; also a valid topological tiebreak
/// since parents are always created before children).
pub type VarId = usize;

static NEXT_ID: AtomicUsize = AtomicUsize::new(1);

/// The recorded backward edge of a node: its parents and the local
/// pullback. The pullback receives the output cotangent and returns one
/// optional input cotangent per parent (None for parents that do not
/// require grad).
pub(crate) struct BackwardOp {
    pub parents: Vec<Var>,
    pub pullback: Box<dyn Fn(&Tensor) -> Vec<Option<Tensor>>>,
    /// Op name for debugging / graph dumps.
    pub name: &'static str,
}

pub(crate) struct VarInner {
    pub data: Tensor,
    pub grad: Option<Tensor>,
    pub requires_grad: bool,
    pub op: Option<BackwardOp>,
    pub id: VarId,
}

/// A node in the dynamic computation graph 𝒢 (paper §3.2).
///
/// `Var` is a cheap handle (`Rc`) — cloning shares the node, so a model
/// parameter can appear in many forward passes while accumulating into one
/// `.grad` buffer, exactly like a PyTorch leaf tensor.
#[derive(Clone)]
pub struct Var(pub(crate) Rc<RefCell<VarInner>>);

impl Var {
    /// Wrap a tensor as a graph leaf.
    pub fn from_tensor(data: Tensor, requires_grad: bool) -> Var {
        Var(Rc::new(RefCell::new(VarInner {
            data,
            grad: None,
            requires_grad,
            op: None,
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
        })))
    }

    /// Wrap a scalar constant.
    pub fn scalar(v: f32) -> Var {
        Var::from_tensor(Tensor::scalar(v), false)
    }

    /// Interior node produced by an op.
    pub(crate) fn from_op(data: Tensor, op: BackwardOp) -> Var {
        Var(Rc::new(RefCell::new(VarInner {
            data,
            grad: None,
            requires_grad: true,
            op: Some(op),
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
        })))
    }

    /// Node id (creation order).
    pub fn id(&self) -> VarId {
        self.0.borrow().id
    }

    /// Snapshot of the value (cheap: shares storage).
    pub fn data(&self) -> Tensor {
        self.0.borrow().data.clone()
    }

    /// Replace the value in place (used by optimizers; does not touch the
    /// graph, so call it under [`super::no_grad`] semantics).
    pub fn set_data(&self, t: Tensor) {
        self.0.borrow_mut().data = t;
    }

    /// Current gradient, if one has been accumulated.
    pub fn grad(&self) -> Option<Tensor> {
        self.0.borrow().grad.clone()
    }

    /// Zero / clear the gradient buffer (drops it — lazily reallocated by
    /// the next backward, per §3.5).
    pub fn zero_grad(&self) {
        self.0.borrow_mut().grad = None;
    }

    /// Whether this node wants gradients.
    pub fn requires_grad(&self) -> bool {
        self.0.borrow().requires_grad
    }

    /// Mark/unmark a leaf as requiring grad.
    pub fn set_requires_grad(&self, rg: bool) {
        self.0.borrow_mut().requires_grad = rg;
    }

    /// Whether this is a leaf (no recorded op).
    pub fn is_leaf(&self) -> bool {
        self.0.borrow().op.is_none()
    }

    /// Name of the op that produced this node (leaves report "leaf").
    pub fn op_name(&self) -> &'static str {
        self.0.borrow().op.as_ref().map_or("leaf", |o| o.name)
    }

    /// Shape of the value.
    pub fn dims(&self) -> Vec<usize> {
        self.0.borrow().data.dims().to_vec()
    }

    /// Detach: a new leaf sharing the value but cut from the graph.
    pub fn detach(&self) -> Var {
        Var::from_tensor(self.data(), false)
    }

    /// Convenience: extract a scalar value.
    pub fn item(&self) -> Result<f32> {
        self.0.borrow().data.item()
    }

    /// True when recording should happen for an op consuming `parents`.
    pub(crate) fn any_requires_grad(parents: &[&Var]) -> bool {
        is_grad_enabled() && parents.iter().any(|p| p.requires_grad())
    }

    /// Public wrapper over gradient accumulation (used by gradient
    /// clipping and custom training loops).
    pub fn accumulate_grad_public(&self, g: &Tensor) {
        self.accumulate_grad(g);
    }

    /// Accumulate `g` into the node's grad buffer (`x̄ += ḡ`).
    pub(crate) fn accumulate_grad(&self, g: &Tensor) {
        let mut inner = self.0.borrow_mut();
        inner.grad = Some(match inner.grad.take() {
            None => g.clone(),
            Some(existing) => existing
                .add(g)
                .expect("gradient shapes must match accumulated buffer"),
        });
    }

    /// Run reverse-mode accumulation from this (scalar) output with seed 1.
    pub fn backward(&self) -> Result<()> {
        let dims = self.dims();
        let numel: usize = dims.iter().product();
        if numel != 1 {
            return Err(Error::NonScalarBackward { shape: dims });
        }
        self.backward_with(&Tensor::ones(&self.dims()))
    }

    /// Reverse-mode accumulation with an explicit output cotangent `seed`.
    pub fn backward_with(&self, seed: &Tensor) -> Result<()> {
        if !self.requires_grad() {
            return Err(Error::NoGradRequired);
        }

        // 1. Topological order via iterative DFS over the op DAG.
        let order = self.topo_order();

        // 2. Propagate cotangents in reverse topological order.
        use std::collections::HashMap;
        let mut cotangent: HashMap<VarId, Tensor> = HashMap::new();
        cotangent.insert(self.id(), seed.clone());

        for node in order.iter().rev() {
            let Some(grad_out) = cotangent.remove(&node.id()) else {
                continue; // unreachable from the seed
            };
            let inner = node.0.borrow();
            match &inner.op {
                None => {
                    // Leaf: accumulate into .grad.
                    if inner.requires_grad {
                        drop(inner);
                        node.accumulate_grad(&grad_out);
                    }
                }
                Some(op) => {
                    let grads = (op.pullback)(&grad_out);
                    debug_assert_eq!(grads.len(), op.parents.len());
                    for (parent, g) in op.parents.iter().zip(grads) {
                        let Some(g) = g else { continue };
                        if !parent.requires_grad() {
                            continue;
                        }
                        cotangent
                            .entry(parent.id())
                            .and_modify(|acc| {
                                *acc = acc.add(&g).expect("cotangent shape mismatch")
                            })
                            .or_insert(g);
                    }
                }
            }
        }
        Ok(())
    }

    /// Iterative post-order DFS: children appear after all their parents.
    fn topo_order(&self) -> Vec<Var> {
        use std::collections::HashSet;
        let mut visited: HashSet<VarId> = HashSet::new();
        let mut order: Vec<Var> = Vec::new();
        // Stack of (node, parents_pushed?).
        let mut stack: Vec<(Var, bool)> = vec![(self.clone(), false)];
        while let Some((node, expanded)) = stack.pop() {
            let id = node.id();
            if expanded {
                order.push(node);
                continue;
            }
            if !visited.insert(id) {
                continue;
            }
            stack.push((node.clone(), true));
            let inner = node.0.borrow();
            if let Some(op) = &inner.op {
                for p in &op.parents {
                    if !visited.contains(&p.id()) {
                        stack.push((p.clone(), false));
                    }
                }
            }
        }
        order
    }

    /// Number of nodes reachable from this output (graph size; used by
    /// tests and diagnostics).
    pub fn graph_size(&self) -> usize {
        self.topo_order().len()
    }
}

impl std::fmt::Debug for Var {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.0.borrow();
        write!(
            f,
            "Var(id={}, op={}, shape={}, requires_grad={})",
            inner.id,
            inner.op.as_ref().map_or("leaf", |o| o.name),
            inner.data.shape(),
            inner.requires_grad
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_properties() {
        let v = Var::from_tensor(Tensor::ones(&[2]), true);
        assert!(v.is_leaf());
        assert!(v.requires_grad());
        assert!(v.grad().is_none());
        assert_eq!(v.op_name(), "leaf");
        let d = v.detach();
        assert!(!d.requires_grad());
    }

    #[test]
    fn backward_requires_scalar() {
        let v = Var::from_tensor(Tensor::ones(&[2]), true);
        assert!(matches!(
            v.backward(),
            Err(Error::NonScalarBackward { .. })
        ));
        let c = Var::from_tensor(Tensor::scalar(1.0), false);
        assert!(matches!(c.backward(), Err(Error::NoGradRequired)));
    }

    #[test]
    fn accumulate_adds() {
        let v = Var::from_tensor(Tensor::ones(&[2]), true);
        v.accumulate_grad(&Tensor::ones(&[2]));
        v.accumulate_grad(&Tensor::ones(&[2]));
        assert_eq!(v.grad().unwrap().to_vec(), vec![2.0, 2.0]);
        v.zero_grad();
        assert!(v.grad().is_none());
    }

    #[test]
    fn clone_shares_node() {
        let v = Var::from_tensor(Tensor::ones(&[1]), true);
        let w = v.clone();
        v.accumulate_grad(&Tensor::ones(&[1]));
        assert!(w.grad().is_some());
        assert_eq!(v.id(), w.id());
    }
}
