//! Baselines for the paper's comparative claims.
//!
//! [`naive`] is the stand-in for micrograd/tinygrad-class pure-Python
//! frameworks (§2/§6): a scalar-at-a-time, boxed, dynamically-dispatched
//! autograd interpreter. It reproduces the *mechanism* of their slowness —
//! per-element heap allocation and virtual dispatch instead of bulk
//! vectorized kernels — so the engine-vs-naive benchmark reproduces the
//! paper's "orders of magnitude" claim with the same scaling shape.

pub mod naive;

pub use naive::{NaiveScalar, NaiveTensor};
