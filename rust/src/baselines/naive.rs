//! A deliberately naive scalar autograd interpreter (micrograd-style).
//!
//! Every scalar is a heap-allocated graph node behind `Rc<RefCell<…>>`;
//! every op dynamically dispatches through a boxed closure; tensors are
//! `Vec`s of scalar nodes and all "bulk" ops are Python-style loops of
//! scalar ops. This is a faithful Rust rendition of how micrograd executes
//! — the comparison target for experiment C2 (orders-of-magnitude claim).

use std::cell::RefCell;
use std::rc::Rc;

/// One scalar node in the naive dynamic graph.
#[derive(Clone)]
pub struct NaiveScalar(Rc<RefCell<NaiveInner>>);

struct NaiveInner {
    value: f32,
    grad: f32,
    parents: Vec<NaiveScalar>,
    backward: Option<Box<dyn Fn(f32, &[NaiveScalar])>>,
}

impl NaiveScalar {
    /// Leaf scalar.
    pub fn new(value: f32) -> NaiveScalar {
        NaiveScalar(Rc::new(RefCell::new(NaiveInner {
            value,
            grad: 0.0,
            parents: Vec::new(),
            backward: None,
        })))
    }

    /// Current value.
    pub fn value(&self) -> f32 {
        self.0.borrow().value
    }

    /// Accumulated gradient.
    pub fn grad(&self) -> f32 {
        self.0.borrow().grad
    }

    fn from_op(
        value: f32,
        parents: Vec<NaiveScalar>,
        backward: Box<dyn Fn(f32, &[NaiveScalar])>,
    ) -> NaiveScalar {
        NaiveScalar(Rc::new(RefCell::new(NaiveInner {
            value,
            grad: 0.0,
            parents,
            backward: Some(backward),
        })))
    }

    /// Scalar addition.
    pub fn add(&self, other: &NaiveScalar) -> NaiveScalar {
        let v = self.value() + other.value();
        NaiveScalar::from_op(
            v,
            vec![self.clone(), other.clone()],
            Box::new(|g, ps| {
                ps[0].0.borrow_mut().grad += g;
                ps[1].0.borrow_mut().grad += g;
            }),
        )
    }

    /// Scalar multiplication.
    pub fn mul(&self, other: &NaiveScalar) -> NaiveScalar {
        let (a, b) = (self.value(), other.value());
        NaiveScalar::from_op(
            a * b,
            vec![self.clone(), other.clone()],
            Box::new(move |g, ps| {
                ps[0].0.borrow_mut().grad += g * b;
                ps[1].0.borrow_mut().grad += g * a;
            }),
        )
    }

    /// Scalar ReLU.
    pub fn relu(&self) -> NaiveScalar {
        let a = self.value();
        NaiveScalar::from_op(
            a.max(0.0),
            vec![self.clone()],
            Box::new(move |g, ps| {
                if a > 0.0 {
                    ps[0].0.borrow_mut().grad += g;
                }
            }),
        )
    }

    /// Scalar exp.
    pub fn exp(&self) -> NaiveScalar {
        let v = self.value().exp();
        NaiveScalar::from_op(
            v,
            vec![self.clone()],
            Box::new(move |g, ps| {
                ps[0].0.borrow_mut().grad += g * v;
            }),
        )
    }

    /// Reverse-mode backward from this node (seed 1).
    pub fn backward(&self) {
        // Topological order by DFS.
        let mut order: Vec<NaiveScalar> = Vec::new();
        let mut visited: Vec<*const RefCell<NaiveInner>> = Vec::new();
        fn dfs(
            node: &NaiveScalar,
            visited: &mut Vec<*const RefCell<NaiveInner>>,
            order: &mut Vec<NaiveScalar>,
        ) {
            let ptr = Rc::as_ptr(&node.0);
            if visited.contains(&ptr) {
                return;
            }
            visited.push(ptr);
            for p in node.0.borrow().parents.iter() {
                dfs(p, visited, order);
            }
            order.push(node.clone());
        }
        dfs(self, &mut visited, &mut order);

        self.0.borrow_mut().grad = 1.0;
        for node in order.iter().rev() {
            let (g, parents) = {
                let inner = node.0.borrow();
                (inner.grad, inner.parents.clone())
            };
            let inner = node.0.borrow();
            if let Some(bw) = &inner.backward {
                bw(g, &parents);
            }
        }
    }
}

/// A "tensor" in the naive framework: a flat Vec of scalar nodes.
pub struct NaiveTensor {
    pub scalars: Vec<NaiveScalar>,
    pub dims: Vec<usize>,
}

impl NaiveTensor {
    /// Build from values.
    pub fn from_vec(values: &[f32], dims: &[usize]) -> NaiveTensor {
        NaiveTensor {
            scalars: values.iter().map(|&v| NaiveScalar::new(v)).collect(),
            dims: dims.to_vec(),
        }
    }

    /// Elementwise add — a scalar-op loop, as a pure-Python framework does.
    pub fn add(&self, other: &NaiveTensor) -> NaiveTensor {
        NaiveTensor {
            scalars: self
                .scalars
                .iter()
                .zip(&other.scalars)
                .map(|(a, b)| a.add(b))
                .collect(),
            dims: self.dims.clone(),
        }
    }

    /// Elementwise multiply.
    pub fn mul(&self, other: &NaiveTensor) -> NaiveTensor {
        NaiveTensor {
            scalars: self
                .scalars
                .iter()
                .zip(&other.scalars)
                .map(|(a, b)| a.mul(b))
                .collect(),
            dims: self.dims.clone(),
        }
    }

    /// ReLU.
    pub fn relu(&self) -> NaiveTensor {
        NaiveTensor {
            scalars: self.scalars.iter().map(|s| s.relu()).collect(),
            dims: self.dims.clone(),
        }
    }

    /// Sum to one scalar node (chain of adds — exactly what a naive
    /// framework builds).
    pub fn sum(&self) -> NaiveScalar {
        let mut acc = NaiveScalar::new(0.0);
        for s in &self.scalars {
            acc = acc.add(s);
        }
        acc
    }

    /// Matrix multiply `[m,k]·[k,n]` as nested scalar loops.
    pub fn matmul(&self, other: &NaiveTensor) -> NaiveTensor {
        let (m, k) = (self.dims[0], self.dims[1]);
        let n = other.dims[1];
        let mut out = Vec::with_capacity(m * n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = NaiveScalar::new(0.0);
                for p in 0..k {
                    acc = acc.add(&self.scalars[i * k + p].mul(&other.scalars[p * n + j]));
                }
                out.push(acc);
            }
        }
        NaiveTensor {
            scalars: out,
            dims: vec![m, n],
        }
    }

    /// Values snapshot.
    pub fn values(&self) -> Vec<f32> {
        self.scalars.iter().map(|s| s.value()).collect()
    }

    /// Gradients snapshot.
    pub fn grads(&self) -> Vec<f32> {
        self.scalars.iter().map(|s| s.grad()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_autograd_matches_calculus() {
        // z = (a*b + a).relu(); a=2, b=3 ⇒ z = 8, dz/da = b+1 = 4, dz/db = a = 2
        let a = NaiveScalar::new(2.0);
        let b = NaiveScalar::new(3.0);
        let z = a.mul(&b).add(&a).relu();
        assert_eq!(z.value(), 8.0);
        z.backward();
        assert_eq!(a.grad(), 4.0);
        assert_eq!(b.grad(), 2.0);
    }

    #[test]
    fn relu_gates_gradient() {
        let a = NaiveScalar::new(-1.0);
        let z = a.relu();
        z.backward();
        assert_eq!(a.grad(), 0.0);
    }

    #[test]
    fn tensor_ops_match_engine() {
        use crate::tensor::Tensor;
        let av = vec![1.0f32, 2.0, 3.0, 4.0];
        let bv = vec![0.5f32, -1.0, 2.0, 0.0];
        let na = NaiveTensor::from_vec(&av, &[2, 2]);
        let nb = NaiveTensor::from_vec(&bv, &[2, 2]);
        let nz = na.matmul(&nb);
        let ta = Tensor::from_vec(av, &[2, 2]).unwrap();
        let tb = Tensor::from_vec(bv, &[2, 2]).unwrap();
        let tz = ta.matmul(&tb).unwrap();
        assert_eq!(nz.values(), tz.to_vec());
    }

    #[test]
    fn naive_backward_matches_engine_backward() {
        use crate::autograd::Var;
        use crate::tensor::Tensor;
        let xv = vec![1.0f32, -2.0, 0.5];
        // naive
        let nx = NaiveTensor::from_vec(&xv, &[3]);
        let nz = nx.mul(&nx).relu().sum();
        nz.backward();
        // engine
        let ex = Var::from_tensor(Tensor::from_vec(xv, &[3]).unwrap(), true);
        let ez = ex.mul(&ex).unwrap().relu().sum().unwrap();
        ez.backward().unwrap();
        assert_eq!(nx.grads(), ex.grad().unwrap().to_vec());
    }

    #[test]
    fn sum_chain() {
        let t = NaiveTensor::from_vec(&[1.0, 2.0, 3.0], &[3]);
        let s = t.sum();
        assert_eq!(s.value(), 6.0);
        s.backward();
        assert_eq!(t.grads(), vec![1.0, 1.0, 1.0]);
    }
}
