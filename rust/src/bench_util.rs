//! Minimal statistics-aware benchmark harness.
//!
//! Criterion is not available in the offline vendor set, so the bench
//! binaries use this: warmup, repeated timed runs, median/mean/p10/p90,
//! and a tabular reporter whose rows mirror the paper's tables.

use std::time::Instant;

/// Worker-thread count the execution layer will use. Benches print this
/// so reported numbers are comparable across machines and
/// `MINITENSOR_NUM_THREADS` settings.
pub fn engine_threads() -> usize {
    crate::runtime::parallel::num_threads()
}

/// Bench one AOT artifact end-to-end through the PJRT engine, returning
/// the median ns. `None` when the artifact can't run — built without the
/// `xla` feature, or `artifacts/` missing/incomplete — so bench tables
/// can print "n/a" from one shared code path.
#[cfg(feature = "xla")]
pub fn bench_artifact(
    name: &str,
    target_ms: f64,
    inputs: &[&crate::tensor::Tensor],
) -> Option<f64> {
    let mut engine =
        crate::runtime::Engine::cpu(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()?;
    engine.load(name).ok()?;
    let s = bench(name, target_ms, 7, || {
        std::hint::black_box(engine.run(name, inputs).unwrap());
    });
    Some(s.median_ns)
}

/// Without the `xla` feature there is no PJRT engine to bench.
#[cfg(not(feature = "xla"))]
pub fn bench_artifact(
    _name: &str,
    _target_ms: f64,
    _inputs: &[&crate::tensor::Tensor],
) -> Option<f64> {
    None
}

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Sample {
    /// Mean time in seconds.
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Throughput in items/second given items per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns / 1e9)
    }
}

/// Time `f`, autoscaling iteration count to `target_ms` per measurement,
/// with `reps` repeated measurements.
pub fn bench(name: &str, target_ms: f64, reps: usize, mut f: impl FnMut()) -> Sample {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_ms / 1e3 / once).ceil() as usize).clamp(1, 1_000_000);

    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    times.sort_by(f64::total_cmp);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let pct = |q: f64| times[((times.len() - 1) as f64 * q).round() as usize];
    Sample {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: pct(0.5),
        p10_ns: pct(0.1),
        p90_ns: pct(0.9),
    }
}

/// One JSON scalar for [`json_rows`]: number, string, or bool.
pub enum Json {
    N(f64),
    S(String),
    B(bool),
}

/// Render rows of key→value pairs as a JSON array of flat objects —
/// hand-rolled because serde is not in the offline vendor set. Strings
/// are escaped (quotes, backslashes, control chars); non-finite numbers
/// render as `null`. The perf-trajectory files (`BENCH_*.json`) are
/// written with this.
pub fn json_rows(rows: &[Vec<(&str, Json)>]) -> String {
    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }
    let mut out = String::from("[\n");
    for (i, row) in rows.iter().enumerate() {
        out.push_str("  {");
        for (j, (k, v)) in row.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push('"');
            out.push_str(&escape(k));
            out.push_str("\": ");
            match v {
                Json::N(x) if x.is_finite() => out.push_str(&format!("{x}")),
                Json::N(_) => out.push_str("null"),
                Json::S(s) => {
                    out.push('"');
                    out.push_str(&escape(s));
                    out.push('"');
                }
                Json::B(b) => out.push_str(if *b { "true" } else { "false" }),
            }
        }
        out.push('}');
        if i + 1 < rows.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

/// Pretty-print a nanosecond figure.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Tabular report printer: aligned columns from (label, value) rows.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout.
    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:<w$}", h, w = widths[i]))
            .collect();
        println!("{}", line.join("  "));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .take(ncol)
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_stats() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 1.0, 3, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.p90_ns);
        assert!(s.iters >= 1);
    }

    #[test]
    fn json_rows_renders_valid_flat_objects() {
        let rows = vec![
            vec![
                ("name", Json::S("a \"b\"\n".into())),
                ("x", Json::N(1.5)),
                ("ok", Json::B(true)),
            ],
            vec![("x", Json::N(f64::NAN))],
        ];
        let s = json_rows(&rows);
        assert!(s.starts_with("[\n"));
        assert!(s.trim_end().ends_with(']'));
        assert!(s.contains(r#""name": "a \"b\"\n""#), "{s}");
        assert!(s.contains(r#""x": 1.5"#));
        assert!(s.contains(r#""ok": true"#));
        assert!(s.contains(r#""x": null"#));
        assert_eq!(s.matches('{').count(), 2);
        assert_eq!(s.matches("},").count(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn table_renders_without_panic() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
