//! Configuration system: a small key=value format with `#` comments and
//! `[section]` headers (no external parser dependencies), plus typed
//! views for training runs.
//!
//! ```text
//! [train]
//! dataset = synthetic_mnist
//! hidden = 128,64
//! optimizer = adam
//! lr = 0.001
//! steps = 300
//! backend = native
//! ```

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use crate::error::{Error, Result};

/// Which execution engine runs the model math.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The native Rust kernels + autograd tape.
    Native,
    /// AOT-compiled XLA executables loaded via PJRT.
    Xla,
}

impl Backend {
    /// Parse from a config string.
    pub fn parse(s: &str) -> Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Ok(Backend::Native),
            "xla" | "pjrt" | "aot" => Ok(Backend::Xla),
            other => Err(Error::Config(format!("unknown backend '{other}'"))),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "native"),
            Backend::Xla => write!(f, "xla"),
        }
    }
}

/// Raw parsed configuration: `section.key → value`.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    /// Overlay `key=value` CLI overrides (e.g. `train.lr=0.01`).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("override '{o}' is not key=value")))?;
            self.values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(())
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// String with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed lookup with default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("cannot parse '{s}' for key '{key}'"))),
        }
    }

    /// Comma-separated usize list.
    pub fn get_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|d| {
                    d.trim()
                        .parse::<usize>()
                        .map_err(|_| Error::Config(format!("bad list entry '{d}' in '{key}'")))
                })
                .collect(),
        }
    }
}

/// Typed training configuration extracted from a [`Config`].
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub dataset: String,
    pub n_examples: usize,
    pub input_side: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub optimizer: String,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub batch_size: usize,
    pub steps: usize,
    pub seed: u64,
    pub backend: Backend,
    pub log_every: usize,
    pub artifacts_dir: String,
    /// Worker threads for the native kernels' execution layer
    /// (`runtime::parallel`). 0 = leave the process-wide setting alone
    /// (i.e. `MINITENSOR_NUM_THREADS` or all cores); 1 = exact serial.
    pub threads: usize,
}

impl TrainConfig {
    /// Defaults matching the E2E example (synthetic-MNIST MLP).
    pub fn defaults() -> TrainConfig {
        TrainConfig {
            dataset: "synthetic_mnist".into(),
            n_examples: 2048,
            input_side: 14,
            hidden: vec![128, 64],
            classes: 10,
            optimizer: "adam".into(),
            lr: 1e-3,
            momentum: 0.9,
            weight_decay: 0.0,
            batch_size: 64,
            steps: 300,
            seed: 42,
            backend: Backend::Native,
            log_every: 20,
            artifacts_dir: "artifacts".into(),
            threads: 0,
        }
    }

    /// Read the `[train]` section of a config.
    pub fn from_config(cfg: &Config) -> Result<TrainConfig> {
        let d = TrainConfig::defaults();
        Ok(TrainConfig {
            dataset: cfg.get_or("train.dataset", &d.dataset),
            n_examples: cfg.get_parse_or("train.n_examples", d.n_examples)?,
            input_side: cfg.get_parse_or("train.input_side", d.input_side)?,
            hidden: cfg.get_list_or("train.hidden", &d.hidden)?,
            classes: cfg.get_parse_or("train.classes", d.classes)?,
            optimizer: cfg.get_or("train.optimizer", &d.optimizer),
            lr: cfg.get_parse_or("train.lr", d.lr)?,
            momentum: cfg.get_parse_or("train.momentum", d.momentum)?,
            weight_decay: cfg.get_parse_or("train.weight_decay", d.weight_decay)?,
            batch_size: cfg.get_parse_or("train.batch_size", d.batch_size)?,
            steps: cfg.get_parse_or("train.steps", d.steps)?,
            seed: cfg.get_parse_or("train.seed", d.seed)?,
            backend: Backend::parse(&cfg.get_or("train.backend", "native"))?,
            log_every: cfg.get_parse_or("train.log_every", d.log_every)?,
            artifacts_dir: cfg.get_or("train.artifacts_dir", &d.artifacts_dir),
            threads: cfg.get_parse_or("train.threads", d.threads)?,
        })
    }

    /// Flattened input feature count.
    pub fn input_features(&self) -> usize {
        self.input_side * self.input_side
    }
}

/// Validated serving configuration.
///
/// Constructed through the [`ServeConfig::new`] builder — the fields are
/// private so every live `ServeConfig` has passed validation (no zero
/// worker pools, no admission queue smaller than one batch). The old
/// public-struct-literal shape (and its deprecated `from_parts` bridge)
/// is gone from the API surface.
///
/// ```
/// use minitensor::coordinator::ServeConfig;
/// let cfg = ServeConfig::new().max_batch(32).workers(4).max_wait_ms(2).build().unwrap();
/// assert_eq!(cfg.workers(), 4);
/// assert!(ServeConfig::new().workers(0).build().is_err());
/// ```
#[derive(Debug, Clone)]
pub struct ServeConfig {
    max_batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    workers: usize,
    deadline: Option<Duration>,
    metrics_port: Option<u16>,
    worker_timeout: Option<Duration>,
    restart_limit: usize,
    restart_backoff: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig::new().build().expect("default ServeConfig is valid")
    }
}

impl ServeConfig {
    /// Start a builder pre-loaded with the defaults
    /// (`max_batch=32, max_wait=2ms, queue_depth=1024, workers=1,
    /// restart_limit=5, restart_backoff=10ms, no worker timeout`).
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> ServeConfigBuilder {
        ServeConfigBuilder {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            workers: 1,
            deadline: None,
            metrics_port: None,
            worker_timeout: None,
            restart_limit: 5,
            restart_backoff: Duration::from_millis(10),
        }
    }

    /// Read the `[serve]` section of a [`Config`]: `serve.max_batch`,
    /// `serve.max_wait_ms`, `serve.queue_depth`, `serve.workers`,
    /// `serve.deadline_ms` (0 = no default deadline),
    /// `serve.metrics_port` (Prometheus endpoint; 0 picks an ephemeral
    /// port, omit the key to not serve metrics),
    /// `serve.worker_timeout_ms` (0 = no stuck-worker watchdog),
    /// `serve.restart_limit`, and `serve.restart_backoff_ms`.
    pub fn from_config(cfg: &Config) -> Result<ServeConfig> {
        let mut b = ServeConfig::new()
            .max_batch(cfg.get_parse_or("serve.max_batch", 32)?)
            .max_wait_ms(cfg.get_parse_or("serve.max_wait_ms", 2)?)
            .queue_depth(cfg.get_parse_or("serve.queue_depth", 1024)?)
            .workers(cfg.get_parse_or("serve.workers", 1)?)
            .restart_limit(cfg.get_parse_or("serve.restart_limit", 5)?)
            .restart_backoff_ms(cfg.get_parse_or("serve.restart_backoff_ms", 10)?);
        let deadline_ms: u64 = cfg.get_parse_or("serve.deadline_ms", 0)?;
        if deadline_ms > 0 {
            b = b.deadline_ms(deadline_ms);
        }
        let worker_timeout_ms: u64 = cfg.get_parse_or("serve.worker_timeout_ms", 0)?;
        if worker_timeout_ms > 0 {
            b = b.worker_timeout_ms(worker_timeout_ms);
        }
        if let Some(port) = cfg.get("serve.metrics_port") {
            let port: u16 = port.parse().map_err(|_| {
                Error::Config(format!(
                    "cannot parse '{port}' for key 'serve.metrics_port' (expected a port number)"
                ))
            })?;
            b = b.metrics_port(port);
        }
        b.build()
    }

    /// Maximum examples fused into one forward.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// How long the dispatcher waits to fill a batch before flushing.
    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }

    /// Bounded admission-queue depth (the fast-reject threshold).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Worker threads, each owning one model replica.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Default per-request deadline applied by `infer` (None = wait
    /// indefinitely); `infer_deadline` overrides per call.
    pub fn deadline(&self) -> Option<Duration> {
        self.deadline
    }

    /// Port for the Prometheus `/metrics` HTTP endpoint the server
    /// starts on 127.0.0.1 (0 = ephemeral, ask the running server via
    /// `metrics_addr()`); `None` = no endpoint.
    pub fn metrics_port(&self) -> Option<u16> {
        self.metrics_port
    }

    /// Per-batch execution deadline enforced by the stuck-worker
    /// watchdog: a worker whose forward exceeds it has its in-flight
    /// requests failed and its replica replaced. `None` = no watchdog.
    pub fn worker_timeout(&self) -> Option<Duration> {
        self.worker_timeout
    }

    /// How many consecutive replica-rebuild failures a crashed worker
    /// tolerates before giving its slot up for lost (the server degrades,
    /// and drains once every slot is lost).
    pub fn restart_limit(&self) -> usize {
        self.restart_limit
    }

    /// Base delay of the capped exponential backoff between replica
    /// rebuild attempts (`base · 2^attempt`, capped at 1 s).
    pub fn restart_backoff(&self) -> Duration {
        self.restart_backoff
    }
}

/// Builder for [`ServeConfig`]; `build()` validates the combination.
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    max_batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    workers: usize,
    deadline: Option<Duration>,
    metrics_port: Option<u16>,
    worker_timeout: Option<Duration>,
    restart_limit: usize,
    restart_backoff: Duration,
}

impl ServeConfigBuilder {
    /// Maximum examples fused into one forward (≥ 1).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// Batch-fill deadline: how long the dispatcher waits for more
    /// requests before flushing a partial batch. Zero flushes instantly.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    /// [`Self::max_wait`] in milliseconds.
    pub fn max_wait_ms(self, ms: u64) -> Self {
        self.max_wait(Duration::from_millis(ms))
    }

    /// Bounded admission-queue depth (≥ max_batch); a full queue
    /// fast-rejects with `Error::Overloaded`.
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n;
        self
    }

    /// Worker threads (≥ 1), each building and exclusively owning one
    /// model replica with its own warm program cache.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Default per-request deadline (> 0); expired requests are shed at
    /// dequeue instead of executed.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// [`Self::deadline`] in milliseconds.
    pub fn deadline_ms(self, ms: u64) -> Self {
        self.deadline(Duration::from_millis(ms))
    }

    /// Serve the process-wide metrics registry over HTTP on
    /// 127.0.0.1:`port` while the server is alive (0 = OS-assigned
    /// ephemeral port, useful for tests).
    pub fn metrics_port(mut self, port: u16) -> Self {
        self.metrics_port = Some(port);
        self
    }

    /// Arm the stuck-worker watchdog: a batch executing longer than `d`
    /// (> 0) gets its requests failed with `Error::WorkerCrashed` and its
    /// replica replaced.
    pub fn worker_timeout(mut self, d: Duration) -> Self {
        self.worker_timeout = Some(d);
        self
    }

    /// [`Self::worker_timeout`] in milliseconds.
    pub fn worker_timeout_ms(self, ms: u64) -> Self {
        self.worker_timeout(Duration::from_millis(ms))
    }

    /// Consecutive replica-rebuild failures tolerated (≥ 1) before a
    /// crashed worker's slot is abandoned.
    pub fn restart_limit(mut self, n: usize) -> Self {
        self.restart_limit = n;
        self
    }

    /// Base delay for the capped exponential rebuild backoff. Zero is
    /// allowed (retry immediately — what the fast recovery tests use).
    pub fn restart_backoff(mut self, d: Duration) -> Self {
        self.restart_backoff = d;
        self
    }

    /// [`Self::restart_backoff`] in milliseconds.
    pub fn restart_backoff_ms(self, ms: u64) -> Self {
        self.restart_backoff(Duration::from_millis(ms))
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<ServeConfig> {
        if self.max_batch == 0 {
            return Err(Error::Config("serve.max_batch must be ≥ 1".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("serve.workers must be ≥ 1".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("serve.queue_depth must be ≥ 1".into()));
        }
        if self.queue_depth < self.max_batch {
            return Err(Error::Config(format!(
                "contradictory: serve.queue_depth ({}) < serve.max_batch ({}) — a full batch could never be admitted",
                self.queue_depth, self.max_batch
            )));
        }
        if self.deadline == Some(Duration::ZERO) {
            return Err(Error::Config(
                "serve.deadline_ms must be > 0 (omit it for no deadline)".into(),
            ));
        }
        if self.worker_timeout == Some(Duration::ZERO) {
            return Err(Error::Config(
                "serve.worker_timeout_ms must be > 0 (omit it for no watchdog)".into(),
            ));
        }
        if self.restart_limit == 0 {
            return Err(Error::Config("serve.restart_limit must be ≥ 1".into()));
        }
        Ok(ServeConfig {
            max_batch: self.max_batch,
            max_wait: self.max_wait,
            queue_depth: self.queue_depth,
            workers: self.workers,
            deadline: self.deadline,
            metrics_port: self.metrics_port,
            worker_timeout: self.worker_timeout,
            restart_limit: self.restart_limit,
            restart_backoff: self.restart_backoff,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_comments_defaults() {
        let cfg = Config::parse(
            "# top comment\n[train]\nlr = 0.01 # inline\nhidden = 32, 16\n\n[serve]\nport = 8080\n",
        )
        .unwrap();
        assert_eq!(cfg.get("train.lr"), Some("0.01"));
        assert_eq!(cfg.get("serve.port"), Some("8080"));
        assert_eq!(cfg.get_parse_or("train.lr", 0.0f32).unwrap(), 0.01);
        assert_eq!(
            cfg.get_list_or("train.hidden", &[]).unwrap(),
            vec![32, 16]
        );
        assert_eq!(cfg.get_parse_or("train.missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn overrides_win() {
        let mut cfg = Config::parse("[train]\nlr = 0.1\n").unwrap();
        cfg.apply_overrides(&["train.lr=0.5".to_string()]).unwrap();
        assert_eq!(cfg.get("train.lr"), Some("0.5"));
        assert!(cfg.apply_overrides(&["nonsense".to_string()]).is_err());
    }

    #[test]
    fn malformed_errors() {
        assert!(Config::parse("key value no equals").is_err());
        let cfg = Config::parse("[t]\nx = abc\n").unwrap();
        assert!(cfg.get_parse_or("t.x", 1usize).is_err());
    }

    #[test]
    fn train_config_roundtrip() {
        let cfg = Config::parse(
            "[train]\ndataset = blobs\nhidden = 8\nbackend = xla\nsteps = 10\nthreads = 4\n",
        )
        .unwrap();
        let tc = TrainConfig::from_config(&cfg).unwrap();
        assert_eq!(tc.dataset, "blobs");
        assert_eq!(tc.hidden, vec![8]);
        assert_eq!(tc.backend, Backend::Xla);
        assert_eq!(tc.steps, 10);
        assert_eq!(tc.lr, 1e-3); // default preserved
        assert_eq!(tc.threads, 4);
        let d = TrainConfig::defaults();
        assert_eq!(d.threads, 0); // 0 = inherit process-wide setting
    }

    #[test]
    fn serve_builder_validates() {
        let cfg = ServeConfig::new()
            .max_batch(16)
            .workers(4)
            .max_wait_ms(3)
            .queue_depth(64)
            .deadline_ms(50)
            .build()
            .unwrap();
        assert_eq!(cfg.max_batch(), 16);
        assert_eq!(cfg.workers(), 4);
        assert_eq!(cfg.max_wait(), Duration::from_millis(3));
        assert_eq!(cfg.queue_depth(), 64);
        assert_eq!(cfg.deadline(), Some(Duration::from_millis(50)));

        assert!(ServeConfig::new().max_batch(0).build().is_err());
        assert!(ServeConfig::new().workers(0).build().is_err());
        assert!(ServeConfig::new().queue_depth(0).build().is_err());
        // contradictory: queue shallower than one batch
        assert!(ServeConfig::new().max_batch(32).queue_depth(8).build().is_err());
        assert!(ServeConfig::new().deadline(Duration::ZERO).build().is_err());

        let d = ServeConfig::default();
        assert_eq!(d.max_batch(), 32);
        assert_eq!(d.workers(), 1);
        assert_eq!(d.deadline(), None);
        assert_eq!(d.metrics_port(), None);
        let m = ServeConfig::new().metrics_port(0).build().unwrap();
        assert_eq!(m.metrics_port(), Some(0));
    }

    #[test]
    fn serve_from_config_reads_section() {
        let cfg = Config::parse(
            "[serve]\nmax_batch = 8\nworkers = 2\nmax_wait_ms = 5\nqueue_depth = 32\ndeadline_ms = 20\n",
        )
        .unwrap();
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.max_batch(), 8);
        assert_eq!(sc.workers(), 2);
        assert_eq!(sc.max_wait(), Duration::from_millis(5));
        assert_eq!(sc.queue_depth(), 32);
        assert_eq!(sc.deadline(), Some(Duration::from_millis(20)));
        // deadline_ms = 0 (the default) means "no deadline"
        let sc = ServeConfig::from_config(&Config::default()).unwrap();
        assert_eq!(sc.deadline(), None);
        assert_eq!(sc.metrics_port(), None); // absent key = no endpoint
        let with_port = Config::parse("[serve]\nmetrics_port = 9100\n").unwrap();
        let sc = ServeConfig::from_config(&with_port).unwrap();
        assert_eq!(sc.metrics_port(), Some(9100));
        let bad_port = Config::parse("[serve]\nmetrics_port = http\n").unwrap();
        assert!(ServeConfig::from_config(&bad_port).is_err());
        // invalid combinations surface as Config errors
        let bad = Config::parse("[serve]\nworkers = 0\n").unwrap();
        assert!(ServeConfig::from_config(&bad).is_err());
    }

    #[test]
    fn supervision_knobs_validate_and_roundtrip() {
        let d = ServeConfig::default();
        assert_eq!(d.worker_timeout(), None);
        assert_eq!(d.restart_limit(), 5);
        assert_eq!(d.restart_backoff(), Duration::from_millis(10));

        let c = ServeConfig::new()
            .worker_timeout_ms(250)
            .restart_limit(3)
            .restart_backoff_ms(0)
            .build()
            .unwrap();
        assert_eq!(c.worker_timeout(), Some(Duration::from_millis(250)));
        assert_eq!(c.restart_limit(), 3);
        assert_eq!(c.restart_backoff(), Duration::ZERO);

        assert!(ServeConfig::new().worker_timeout(Duration::ZERO).build().is_err());
        assert!(ServeConfig::new().restart_limit(0).build().is_err());

        let cfg = Config::parse(
            "[serve]\nworker_timeout_ms = 40\nrestart_limit = 2\nrestart_backoff_ms = 1\n",
        )
        .unwrap();
        let sc = ServeConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.worker_timeout(), Some(Duration::from_millis(40)));
        assert_eq!(sc.restart_limit(), 2);
        assert_eq!(sc.restart_backoff(), Duration::from_millis(1));
        // worker_timeout_ms = 0 (the default) means "no watchdog"
        let sc = ServeConfig::from_config(&Config::default()).unwrap();
        assert_eq!(sc.worker_timeout(), None);
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("Native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("AOT").unwrap(), Backend::Xla);
        assert!(Backend::parse("gpu").is_err());
    }
}
