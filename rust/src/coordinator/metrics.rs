//! Lightweight process metrics: counters and latency histograms used by
//! the trainer and the inference server.
//!
//! Latency series are **fixed-size log-bucketed histograms**, not raw
//! observation vectors: memory is O(1) per series no matter how many
//! observations a long-running server records, and two histograms (e.g.
//! per-worker locals) merge by adding bucket counts. Percentiles are
//! exact to within one bucket (~±2.3% with the default 512 buckets over
//! 1µs–10⁴s); the mean is exact (the running sum is tracked separately).
//! The [`Histogram`] type itself lives in
//! [`runtime::metrics`](crate::runtime::metrics) (promoted there in
//! PR 9) and is re-exported here unchanged.
//!
//! Each [`Metrics`] instance is a private registry — a test server's
//! counters never bleed into another's — but every write is also
//! **mirrored into the process-wide registry** under a sanitized
//! Prometheus name (`serve.rejected` → `minitensor_serve_rejected_total`,
//! `serve.latency` → `minitensor_serve_latency`), so a `/metrics` scrape
//! sees the serve stack with zero extra instrumentation at the call
//! sites. Mirrored counters are process totals across all instances.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

pub use crate::runtime::metrics::Histogram;

use crate::runtime::metrics as global;

/// Sanitize an instance-local metric name into the global scheme:
/// non-alphanumeric characters become `_`, the `minitensor_` prefix is
/// added, and counters get the Prometheus `_total` suffix.
fn global_name(name: &str, counter: bool) -> String {
    let mut s = String::with_capacity(name.len() + 18);
    s.push_str("minitensor_");
    for c in name.chars() {
        s.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    if counter && !s.ends_with("_total") {
        s.push_str("_total");
    }
    s
}

/// Thread-safe metrics registry.
///
/// Every lock acquisition recovers from poisoning
/// (`unwrap_or_else(|e| e.into_inner())`): metrics are bookkeeping, and
/// a panic elsewhere on a thread that happened to hold a metrics mutex —
/// e.g. a serve worker crash being contained by `catch_unwind` — must
/// not cascade into killing the server's accounting. The worst case is
/// one torn counter increment, never a propagated panic.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, u64>>,
    series: Mutex<HashMap<String, Histogram>>,
}

impl Metrics {
    /// Empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        *self
            .counters
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(name.to_string())
            .or_insert(0) += by;
        global::counter_add(&global_name(name, true), by);
    }

    /// Read a counter.
    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap_or_else(|e| e.into_inner()).get(name).unwrap_or(&0)
    }

    /// Record an observation (latencies in seconds; sizes/depths as-is).
    pub fn observe(&self, name: &str, value: f64) {
        self.series
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(name.to_string())
            .or_default()
            .observe(value);
        global::observe(&global_name(name, false), value);
    }

    /// Fold an externally accumulated histogram into a named series.
    pub fn merge_histogram(&self, name: &str, h: &Histogram) {
        self.series
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(name.to_string())
            .or_default()
            .merge(h);
        global::merge_histogram(&global_name(name, false), h);
    }

    /// Snapshot of a series' histogram; `None` if never observed.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.series.lock().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Percentile of a recorded series (q in [0,1]); None if empty.
    pub fn percentile(&self, name: &str, q: f64) -> Option<f64> {
        self.series.lock().unwrap_or_else(|e| e.into_inner()).get(name)?.percentile(q)
    }

    /// Mean of a recorded series.
    pub fn mean(&self, name: &str) -> Option<f64> {
        self.series.lock().unwrap_or_else(|e| e.into_inner()).get(name)?.mean()
    }

    /// Count of observations.
    pub fn observations(&self, name: &str) -> usize {
        self.series
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(name)
            .map_or(0, |h| h.count() as usize)
    }

    /// Render a compact text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        let mut names: Vec<&String> = counters.keys().collect();
        names.sort();
        for n in names {
            out.push_str(&format!("{n} = {}\n", counters[n]));
        }
        drop(counters);
        let series = self.series.lock().unwrap_or_else(|e| e.into_inner());
        let mut names: Vec<&String> = series.keys().collect();
        names.sort();
        for n in names {
            let h = &series[n];
            if h.count() == 0 {
                continue;
            }
            let p = |q: f64| h.percentile(q).unwrap_or(0.0) * 1e3;
            out.push_str(&format!(
                "{n}: n={} mean={:.3}ms p50={:.3}ms p90={:.3}ms p99={:.3}ms\n",
                h.count(),
                h.mean().unwrap_or(0.0) * 1e3,
                p(0.5),
                p(0.9),
                p(0.99),
            ));
        }
        out
    }
}

/// RAII latency timer feeding a [`Metrics`] histogram.
pub struct Timer<'a> {
    metrics: &'a Metrics,
    name: &'a str,
    start: Instant,
}

impl<'a> Timer<'a> {
    /// Start timing `name`.
    pub fn start(metrics: &'a Metrics, name: &'a str) -> Timer<'a> {
        Timer {
            metrics,
            name,
            start: Instant::now(),
        }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.metrics
            .observe(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_percentiles_within_bucket_resolution() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64 / 1000.0);
        }
        assert_eq!(m.observations("lat"), 100);
        // Buckets are ~4.6% wide, so percentiles land within ~±2.5%.
        let p50 = m.percentile("lat", 0.5).unwrap();
        assert!((p50 - 0.0505).abs() < 0.0505 * 0.05, "{p50}");
        let p99 = m.percentile("lat", 0.99).unwrap();
        assert!(p99 >= 0.099 * 0.95, "{p99}");
        assert!(p99 <= 0.1, "clamped to the exact observed max: {p99}");
        assert!(m.percentile("missing", 0.5).is_none());
        // The mean is exact (running sum), not bucketed.
        let mean = m.mean("lat").unwrap();
        assert!((mean - 0.0505).abs() < 1e-9);
    }

    #[test]
    fn merge_histogram_feeds_named_series() {
        let m = Metrics::new();
        let mut local = Histogram::new();
        local.observe(0.002);
        local.observe(0.004);
        m.merge_histogram("lat", &local);
        assert_eq!(m.observations("lat"), 2);
        assert!((m.mean("lat").unwrap() - 0.003).abs() < 1e-9);
        assert!(m.histogram("lat").is_some());
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        {
            let _t = Timer::start(&m, "op");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(m.observations("op"), 1);
        assert!(m.mean("op").unwrap() >= 0.001);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.incr("c", 5);
        m.observe("l", 0.001);
        let r = m.report();
        assert!(r.contains("c = 5"));
        assert!(r.contains("l: n=1"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("n", 1);
                        m.observe("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
        assert_eq!(m.observations("l"), 400);
    }

    #[test]
    fn global_names_are_sanitized() {
        assert_eq!(
            global_name("serve.rejected", true),
            "minitensor_serve_rejected_total"
        );
        assert_eq!(global_name("serve.latency", false), "minitensor_serve_latency");
        assert_eq!(
            global_name("serve.worker0.batches", true),
            "minitensor_serve_worker0_batches_total"
        );
        // Already-suffixed names don't double up.
        assert_eq!(global_name("x_total", true), "minitensor_x_total");
    }

    #[test]
    fn writes_mirror_into_the_global_registry() {
        let m = Metrics::new();
        m.incr("test.mirror.count", 2);
        m.observe("test.mirror.lat", 0.003);
        let s = crate::runtime::metrics::snapshot();
        let c = s
            .counters
            .iter()
            .find(|(k, _)| k == "minitensor_test_mirror_count_total")
            .map(|&(_, v)| v)
            .unwrap_or(0);
        assert!(c >= 2, "mirrored counter missing: {c}");
        assert!(s
            .summaries
            .iter()
            .any(|(k, sum)| k == "minitensor_test_mirror_lat" && sum.count >= 1));
    }
}
