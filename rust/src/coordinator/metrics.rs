//! Lightweight process metrics: counters and latency histograms used by
//! the trainer and the inference server.
//!
//! Latency series are **fixed-size log-bucketed histograms**, not raw
//! observation vectors: memory is O(1) per series no matter how many
//! observations a long-running server records, and two histograms (e.g.
//! per-worker locals) merge by adding bucket counts. Percentiles are
//! exact to within one bucket (~±2.3% with the default 512 buckets over
//! 1µs–10⁴s); the mean is exact (the running sum is tracked separately).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Bucket count of a [`Histogram`]. 512 buckets over [`H_MIN`, `H_MAX`]
/// gives a per-bucket ratio of (1e10)^(1/512) ≈ 1.046 — percentiles are
/// reported within ~±2.3% of the true value.
const BUCKETS: usize = 512;
/// Lower edge of the bucketed range, in seconds (1 µs).
const H_MIN: f64 = 1e-6;
/// Upper edge of the bucketed range, in seconds (~2.8 hours).
const H_MAX: f64 = 1e4;

/// Fixed-size log-bucketed histogram of non-negative observations
/// (seconds, sizes, depths — any positive magnitude).
///
/// O(1) memory, O(1) `observe`, mergeable across threads/workers by
/// adding bucket counts. Values outside [1e-6, 1e4] clamp into the edge
/// buckets; the exact observed `min`/`max` are tracked so the reported
/// percentiles never step outside the observed range.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(v: f64) -> usize {
        if v.is_nan() || v <= H_MIN {
            return 0; // ≤ H_MIN, zero, negative, or NaN
        }
        if v >= H_MAX {
            return BUCKETS - 1;
        }
        let frac = (v / H_MIN).ln() / (H_MAX / H_MIN).ln();
        ((frac * BUCKETS as f64) as usize).min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the value a percentile query
    /// reports for observations that landed there.
    fn representative(i: usize) -> f64 {
        H_MIN * (H_MAX / H_MIN).powf((i as f64 + 0.5) / BUCKETS as f64)
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { 0.0 };
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (bucket-wise addition) —
    /// how per-worker locals combine into a process view.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean (running sum / count); `None` if empty.
    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        Some(self.sum / self.count as f64)
    }

    /// Percentile (q in [0,1]) to within one bucket; `None` if empty.
    /// Reports the containing bucket's geometric midpoint, clamped to
    /// the exact observed [min, max]; the extreme ranks (q=0, q=1)
    /// report the exact observed min/max.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        if rank == 0 {
            return Some(self.min);
        }
        if rank == self.count - 1 {
            return Some(self.max);
        }
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Some(Self::representative(i).clamp(self.min, self.max));
            }
        }
        Some(self.max) // unreachable in practice (counts sum to count)
    }
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, u64>>,
    series: Mutex<HashMap<String, Histogram>>,
}

impl Metrics {
    /// Empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += by;
    }

    /// Read a counter.
    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// Record an observation (latencies in seconds; sizes/depths as-is).
    pub fn observe(&self, name: &str, value: f64) {
        self.series
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .observe(value);
    }

    /// Fold an externally accumulated histogram into a named series.
    pub fn merge_histogram(&self, name: &str, h: &Histogram) {
        self.series
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .merge(h);
    }

    /// Snapshot of a series' histogram; `None` if never observed.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.series.lock().unwrap().get(name).cloned()
    }

    /// Percentile of a recorded series (q in [0,1]); None if empty.
    pub fn percentile(&self, name: &str, q: f64) -> Option<f64> {
        self.series.lock().unwrap().get(name)?.percentile(q)
    }

    /// Mean of a recorded series.
    pub fn mean(&self, name: &str) -> Option<f64> {
        self.series.lock().unwrap().get(name)?.mean()
    }

    /// Count of observations.
    pub fn observations(&self, name: &str) -> usize {
        self.series
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, |h| h.count() as usize)
    }

    /// Render a compact text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        let mut names: Vec<&String> = counters.keys().collect();
        names.sort();
        for n in names {
            out.push_str(&format!("{n} = {}\n", counters[n]));
        }
        drop(counters);
        let series = self.series.lock().unwrap();
        let mut names: Vec<&String> = series.keys().collect();
        names.sort();
        for n in names {
            let h = &series[n];
            if h.count() == 0 {
                continue;
            }
            let p = |q: f64| h.percentile(q).unwrap_or(0.0) * 1e3;
            out.push_str(&format!(
                "{n}: n={} mean={:.3}ms p50={:.3}ms p90={:.3}ms p99={:.3}ms\n",
                h.count(),
                h.mean().unwrap_or(0.0) * 1e3,
                p(0.5),
                p(0.9),
                p(0.99),
            ));
        }
        out
    }
}

/// RAII latency timer feeding a [`Metrics`] histogram.
pub struct Timer<'a> {
    metrics: &'a Metrics,
    name: &'a str,
    start: Instant,
}

impl<'a> Timer<'a> {
    /// Start timing `name`.
    pub fn start(metrics: &'a Metrics, name: &'a str) -> Timer<'a> {
        Timer {
            metrics,
            name,
            start: Instant::now(),
        }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.metrics
            .observe(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_percentiles_within_bucket_resolution() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64 / 1000.0);
        }
        assert_eq!(m.observations("lat"), 100);
        // Buckets are ~4.6% wide, so percentiles land within ~±2.5%.
        let p50 = m.percentile("lat", 0.5).unwrap();
        assert!((p50 - 0.0505).abs() < 0.0505 * 0.05, "{p50}");
        let p99 = m.percentile("lat", 0.99).unwrap();
        assert!(p99 >= 0.099 * 0.95, "{p99}");
        assert!(p99 <= 0.1, "clamped to the exact observed max: {p99}");
        assert!(m.percentile("missing", 0.5).is_none());
        // The mean is exact (running sum), not bucketed.
        let mean = m.mean("lat").unwrap();
        assert!((mean - 0.0505).abs() < 1e-9);
    }

    #[test]
    fn histogram_memory_is_constant_and_extremes_clamp() {
        let mut h = Histogram::new();
        for _ in 0..1_000_000 {
            h.observe(0.001);
        }
        h.observe(0.0); // below range → edge bucket, exact min tracked
        h.observe(1e9); // above range → edge bucket, exact max tracked
        assert_eq!(h.count(), 1_000_002);
        assert_eq!(h.counts.len(), BUCKETS);
        assert_eq!(h.percentile(0.0), Some(0.0));
        assert_eq!(h.percentile(1.0), Some(1e9));
        let p50 = h.percentile(0.5).unwrap();
        assert!((p50 - 0.001).abs() < 0.001 * 0.05, "{p50}");
    }

    #[test]
    fn histograms_merge_like_one_series() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 1..=50 {
            a.observe(i as f64 / 1000.0);
            whole.observe(i as f64 / 1000.0);
        }
        for i in 51..=100 {
            b.observe(i as f64 / 1000.0);
            whole.observe(i as f64 / 1000.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.mean(), whole.mean());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.percentile(q), whole.percentile(q), "q={q}");
        }
    }

    #[test]
    fn merging_an_empty_histogram_changes_nothing() {
        let mut a = Histogram::new();
        a.observe(0.002);
        a.observe(0.004);
        let before_mean = a.mean();
        a.merge(&Histogram::new());
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean(), before_mean);
        // The empty side's sentinel min/max (+inf/-inf) must not leak
        // into the merged extremes.
        assert_eq!(a.percentile(0.0), Some(0.002));
        assert_eq!(a.percentile(1.0), Some(0.004));

        // And merging *into* an empty histogram reproduces the source.
        let mut e = Histogram::new();
        e.merge(&a);
        assert_eq!(e.count(), a.count());
        assert_eq!(e.mean(), a.mean());
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(e.percentile(q), a.percentile(q), "q={q}");
        }
    }

    #[test]
    fn empty_histogram_reports_none() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), None);
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(h.percentile(q), None, "q={q}");
        }
    }

    #[test]
    fn out_of_range_values_clamp_to_edge_buckets() {
        // Below range (and zero/negative/NaN) land in bucket 0; above
        // range lands in the last bucket.
        assert_eq!(Histogram::bucket(1e-9), 0);
        assert_eq!(Histogram::bucket(0.0), 0);
        assert_eq!(Histogram::bucket(-5.0), 0);
        assert_eq!(Histogram::bucket(f64::NAN), 0);
        assert_eq!(Histogram::bucket(1e5), BUCKETS - 1);
        assert_eq!(Histogram::bucket(f64::INFINITY), BUCKETS - 1);

        // Interior percentiles stay within the exact observed range
        // even though the edge buckets' midpoints lie outside it.
        let mut h = Histogram::new();
        for _ in 0..10 {
            h.observe(1e-9);
        }
        for _ in 0..10 {
            h.observe(1e5);
        }
        assert_eq!(h.percentile(0.0), Some(1e-9));
        assert_eq!(h.percentile(1.0), Some(1e5));
        let p40 = h.percentile(0.4).unwrap();
        assert!((1e-9..=1e5).contains(&p40), "{p40}");
    }

    #[test]
    fn single_sample_percentile_is_that_value() {
        let mut h = Histogram::new();
        h.observe(0.0123);
        for q in [0.0, 0.25, 0.5, 0.75, 0.99, 1.0] {
            assert_eq!(h.percentile(q), Some(0.0123), "q={q}");
        }
        assert_eq!(h.mean(), Some(0.0123));
    }

    #[test]
    fn merge_histogram_feeds_named_series() {
        let m = Metrics::new();
        let mut local = Histogram::new();
        local.observe(0.002);
        local.observe(0.004);
        m.merge_histogram("lat", &local);
        assert_eq!(m.observations("lat"), 2);
        assert!((m.mean("lat").unwrap() - 0.003).abs() < 1e-9);
        assert!(m.histogram("lat").is_some());
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        {
            let _t = Timer::start(&m, "op");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(m.observations("op"), 1);
        assert!(m.mean("op").unwrap() >= 0.001);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.incr("c", 5);
        m.observe("l", 0.001);
        let r = m.report();
        assert!(r.contains("c = 5"));
        assert!(r.contains("l: n=1"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("n", 1);
                        m.observe("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
        assert_eq!(m.observations("l"), 400);
    }
}
