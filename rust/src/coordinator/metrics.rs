//! Lightweight process metrics: counters and latency histograms used by
//! the trainer and the inference server.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, u64>>,
    latencies: Mutex<HashMap<String, Vec<f64>>>,
}

impl Metrics {
    /// Empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += by;
    }

    /// Read a counter.
    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// Record a latency observation in seconds.
    pub fn observe(&self, name: &str, seconds: f64) {
        self.latencies
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    /// Percentile of recorded latencies (q in [0,1]); None if empty.
    pub fn percentile(&self, name: &str, q: f64) -> Option<f64> {
        let map = self.latencies.lock().unwrap();
        let v = map.get(name)?;
        if v.is_empty() {
            return None;
        }
        let mut sorted = v.clone();
        sorted.sort_by(f64::total_cmp);
        Some(sorted[((sorted.len() - 1) as f64 * q).round() as usize])
    }

    /// Mean of recorded latencies.
    pub fn mean(&self, name: &str) -> Option<f64> {
        let map = self.latencies.lock().unwrap();
        let v = map.get(name)?;
        if v.is_empty() {
            return None;
        }
        Some(v.iter().sum::<f64>() / v.len() as f64)
    }

    /// Count of observations.
    pub fn observations(&self, name: &str) -> usize {
        self.latencies
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, Vec::len)
    }

    /// Render a compact text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        let mut names: Vec<&String> = counters.keys().collect();
        names.sort();
        for n in names {
            out.push_str(&format!("{n} = {}\n", counters[n]));
        }
        drop(counters);
        let lat = self.latencies.lock().unwrap();
        let mut names: Vec<&String> = lat.keys().collect();
        names.sort();
        for n in names {
            let v = &lat[n];
            if v.is_empty() {
                continue;
            }
            let mut sorted = v.clone();
            sorted.sort_by(f64::total_cmp);
            let p = |q: f64| sorted[((sorted.len() - 1) as f64 * q).round() as usize] * 1e3;
            out.push_str(&format!(
                "{n}: n={} mean={:.3}ms p50={:.3}ms p90={:.3}ms p99={:.3}ms\n",
                v.len(),
                v.iter().sum::<f64>() / v.len() as f64 * 1e3,
                p(0.5),
                p(0.9),
                p(0.99),
            ));
        }
        out
    }
}

/// RAII latency timer feeding a [`Metrics`] histogram.
pub struct Timer<'a> {
    metrics: &'a Metrics,
    name: &'a str,
    start: Instant,
}

impl<'a> Timer<'a> {
    /// Start timing `name`.
    pub fn start(metrics: &'a Metrics, name: &'a str) -> Timer<'a> {
        Timer {
            metrics,
            name,
            start: Instant::now(),
        }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.metrics
            .observe(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64 / 1000.0);
        }
        assert_eq!(m.observations("lat"), 100);
        let p50 = m.percentile("lat", 0.5).unwrap();
        assert!((p50 - 0.0505).abs() < 0.002, "{p50}");
        let p99 = m.percentile("lat", 0.99).unwrap();
        assert!(p99 >= 0.099);
        assert!(m.percentile("missing", 0.5).is_none());
        let mean = m.mean("lat").unwrap();
        assert!((mean - 0.0505).abs() < 0.001);
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        {
            let _t = Timer::start(&m, "op");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(m.observations("op"), 1);
        assert!(m.mean("op").unwrap() >= 0.001);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.incr("c", 5);
        m.observe("l", 0.001);
        let r = m.report();
        assert!(r.contains("c = 5"));
        assert!(r.contains("l: n=1"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.incr("n", 1);
                        m.observe("l", 0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 400);
        assert_eq!(m.observations("l"), 400);
    }
}
