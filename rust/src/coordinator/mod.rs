//! L3 coordinator: configuration, backend dispatch, the training
//! launcher, a threaded batching inference server, and metrics.
//!
//! This is where MiniTensor stops being a kernel library and becomes a
//! system: the coordinator owns process lifecycle, the request loop, and
//! the decision of whether a compute step runs on the native Rust engine
//! or on an AOT-compiled XLA executable ([`Backend`]).

mod config;
mod metrics;
mod serve;
mod trainer;

pub use config::{Backend, Config, ServeConfig, ServeConfigBuilder, TrainConfig};
pub use metrics::{Histogram, Metrics, Timer};
pub use serve::{
    BatchModel, FactoryFn, InferenceServer, ModelFactory, NativeBatchModel, NativeModelFactory,
    ServeStats,
};
pub use trainer::{TrainReport, Trainer};
