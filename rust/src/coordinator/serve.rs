//! Threaded batching inference server.
//!
//! The coordination pattern of a serving stack (vLLM-router-style) scaled
//! to this paper's scope: clients submit single examples; a batcher thread
//! groups them up to `max_batch` (or a deadline) and dispatches one bulk
//! forward per batch — on the native engine or on the AOT XLA forward
//! executable. Backpressure falls out of the bounded queue.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum examples fused into one forward.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before flushing.
    pub max_wait: Duration,
    /// Bounded queue depth (backpressure threshold).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
        }
    }
}

/// One queued request: a feature vector and the channel to answer on.
struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    reply: SyncSender<Result<Vec<f32>>>,
}

/// Aggregate statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
}

/// A model the server can run: takes a `[b, d]` batch, returns `[b, k]`.
pub trait BatchModel: Send {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor>;
    /// Input feature count.
    fn in_features(&self) -> usize;
}

/// Batching inference server over any [`BatchModel`].
pub struct InferenceServer {
    tx: SyncSender<Request>,
    worker: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    in_features: usize,
}

impl InferenceServer {
    /// Spawn the batcher thread over `model`.
    pub fn start(mut model: Box<dyn BatchModel>, cfg: ServeConfig) -> InferenceServer {
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = sync_channel(cfg.queue_depth);
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::new());
        let in_features = model.in_features();

        let stop_w = stop.clone();
        let metrics_w = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
            loop {
                // Block for the first request (with a stop-poll timeout).
                if pending.is_empty() {
                    match rx.recv_timeout(Duration::from_millis(10)) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => {
                            if stop_w.load(Ordering::Relaxed) {
                                return;
                            }
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => return,
                    }
                }
                // Fill up to max_batch or the deadline.
                let deadline = Instant::now() + cfg.max_wait;
                while pending.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }

                // Assemble the batch tensor.
                let b = pending.len();
                let mut flat = Vec::with_capacity(b * in_features);
                for r in &pending {
                    flat.extend_from_slice(&r.features);
                }
                let batch = Tensor::from_vec(flat, &[b, in_features])
                    .expect("request feature lengths validated at submit");

                let result = model.forward_batch(&batch);
                metrics_w.incr("serve.batches", 1);
                metrics_w.incr("serve.requests", b as u64);
                metrics_w.observe("serve.batch_size", b as f64);

                match result {
                    Ok(out) => {
                        let k = out.dims()[1];
                        let ov = out.to_vec();
                        for (i, r) in pending.drain(..).enumerate() {
                            metrics_w
                                .observe("serve.latency", r.enqueued.elapsed().as_secs_f64());
                            let row = ov[i * k..(i + 1) * k].to_vec();
                            let _ = r.reply.send(Ok(row));
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for r in pending.drain(..) {
                            let _ = r.reply.send(Err(Error::msg(msg.clone())));
                        }
                    }
                }

                if stop_w.load(Ordering::Relaxed) && pending.is_empty() {
                    // Drain whatever is still queued before exiting.
                    while let Ok(r) = rx.try_recv() {
                        let _ = r.reply.send(Err(Error::msg("server shutting down")));
                    }
                    return;
                }
            }
        });

        InferenceServer {
            tx,
            worker: Some(worker),
            stop,
            metrics,
            in_features,
        }
    }

    /// Submit one example and wait for its outputs (logits).
    pub fn infer(&self, features: Vec<f32>) -> Result<Vec<f32>> {
        if features.len() != self.in_features {
            return Err(Error::ShapeMismatch {
                op: "serve.infer",
                expected: format!("{} features", self.in_features),
                got: format!("{}", features.len()),
            });
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(Request {
                features,
                enqueued: Instant::now(),
                reply: reply_tx,
            })
            .map_err(|_| Error::msg("server stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| Error::msg("server dropped the request"))?
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            requests: self.metrics.counter("serve.requests"),
            batches: self.metrics.counter("serve.batches"),
            mean_batch_size: self.metrics.mean("serve.batch_size").unwrap_or(0.0),
            p50_latency_ms: self.metrics.percentile("serve.latency", 0.5).unwrap_or(0.0) * 1e3,
            p99_latency_ms: self.metrics.percentile("serve.latency", 0.99).unwrap_or(0.0) * 1e3,
        }
    }

    /// Stop the worker and join it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// A [`BatchModel`] over a native `Sequential` (wrapped in a Mutex: the
/// graph types are not Sync, and the model lives on the worker thread).
pub struct NativeBatchModel {
    model: Mutex<crate::nn::Sequential>,
    in_features: usize,
}

// SAFETY: the Sequential inside is only ever touched by the worker thread
// that owns the Box<dyn BatchModel>; Mutex adds the Sync guarantee needed
// to move it there.
unsafe impl Send for NativeBatchModel {}

impl NativeBatchModel {
    /// Wrap a model for serving.
    pub fn new(model: crate::nn::Sequential, in_features: usize) -> NativeBatchModel {
        NativeBatchModel {
            model: Mutex::new(model),
            in_features,
        }
    }
}

impl BatchModel for NativeBatchModel {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        use crate::nn::Module;
        crate::autograd::no_grad(|| {
            let v = crate::autograd::Var::from_tensor(x.clone(), false);
            let model = self.model.lock().unwrap();
            Ok(model.forward(&v, false)?.data())
        })
    }

    fn in_features(&self) -> usize {
        self.in_features
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::{Activation, Dense, Sequential};

    fn tiny_model() -> Box<dyn BatchModel> {
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 3, &mut rng));
        Box::new(NativeBatchModel::new(model, 4))
    }

    #[test]
    fn single_request_roundtrip() {
        let server = InferenceServer::start(tiny_model(), ServeConfig::default());
        let out = server.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(out.len(), 3);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_feature_count() {
        let server = InferenceServer::start(tiny_model(), ServeConfig::default());
        assert!(server.infer(vec![1.0]).is_err());
        server.shutdown();
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = Arc::new(InferenceServer::start(
            tiny_model(),
            ServeConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
                queue_depth: 64,
            },
        ));
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let s = server.clone();
                std::thread::spawn(move || {
                    s.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 3);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches < 16, "batching should fuse requests: {stats:?}");
        assert!(stats.mean_batch_size > 1.0);
    }

    #[test]
    fn results_match_direct_forward() {
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 3, &mut rng));
        // compute the expected output directly
        use crate::nn::Module;
        let x = Tensor::from_vec(vec![0.5, -1.0, 2.0, 0.0], &[1, 4]).unwrap();
        let expect = model
            .forward(&crate::autograd::Var::from_tensor(x, false), false)
            .unwrap()
            .data()
            .to_vec();

        let server = InferenceServer::start(
            Box::new(NativeBatchModel::new(model, 4)),
            ServeConfig::default(),
        );
        let got = server.infer(vec![0.5, -1.0, 2.0, 0.0]).unwrap();
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5);
        }
        server.shutdown();
    }
}
