//! Multi-worker continuous-batching inference server.
//!
//! The production-serving coordination layer: clients submit single
//! examples; a **dispatcher** thread groups them into batches under a
//! hybrid size-or-deadline flush policy and hands them to a pool of N
//! **worker** threads. Each worker builds and exclusively owns its own
//! model replica (via [`ModelFactory`] — safe by construction, no shared
//! mutable model, no `unsafe impl Send`), so every worker pins a warm
//! per-thread compiled-Program cache: the second identical batch a
//! worker sees skips region partitioning and tape construction entirely.
//! Workers pull the next batch the moment they finish, so batch
//! formation overlaps with execution instead of serializing behind it.
//!
//! Admission control goes beyond the bounded queue:
//!
//! - a saturated admission queue **fast-rejects** with
//!   [`Error::Overloaded`] instead of blocking the client;
//! - requests may carry a **deadline** ([`InferenceServer::infer_deadline`]
//!   or the `serve.deadline_ms` default) — already-expired requests are
//!   shed at dequeue with [`Error::DeadlineExceeded`] instead of burning
//!   a worker on stale work;
//! - shutdown **drains**: every admitted request still receives its real
//!   reply before the threads exit.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::config::ServeConfig;
use super::metrics::Metrics;
use crate::error::{Error, Result};
use crate::runtime::metrics as registry;
use crate::runtime::{stats, trace};
use crate::tensor::Tensor;

/// A model the server can run: takes a `[b, d]` batch, returns `[b, k]`.
///
/// No `Send` bound: a model is **built on the worker thread that runs
/// it** (see [`ModelFactory`]) and never crosses threads afterwards.
pub trait BatchModel {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor>;
    /// Input feature count.
    fn in_features(&self) -> usize;
}

/// Builds one [`BatchModel`] replica per worker.
///
/// The factory is shared across the worker-spawn loop (hence
/// `Send + Sync`), but each `build(worker)` call runs **on** that
/// worker's thread and the replica it returns is exclusively owned
/// there. This is what lets the engine keep its non-`Sync` graph types
/// (`Var` is `Rc`-based) out of any cross-thread traffic without a
/// single `unsafe impl`.
pub trait ModelFactory: Send + Sync + 'static {
    /// Input feature count (needed before any replica exists, for
    /// request validation).
    fn in_features(&self) -> usize;
    /// Construct worker `worker`'s replica. Called once per worker, on
    /// the worker's own thread.
    fn build(&self, worker: usize) -> Result<Box<dyn BatchModel>>;
}

/// [`ModelFactory`] from a plain closure plus an explicit feature count.
pub struct FactoryFn<F> {
    in_features: usize,
    build: F,
}

impl<F> FactoryFn<F>
where
    F: Fn(usize) -> Result<Box<dyn BatchModel>> + Send + Sync + 'static,
{
    /// Wrap `build` (called once per worker, on the worker thread).
    pub fn new(in_features: usize, build: F) -> FactoryFn<F> {
        FactoryFn { in_features, build }
    }
}

impl<F> ModelFactory for FactoryFn<F>
where
    F: Fn(usize) -> Result<Box<dyn BatchModel>> + Send + Sync + 'static,
{
    fn in_features(&self) -> usize {
        self.in_features
    }

    fn build(&self, worker: usize) -> Result<Box<dyn BatchModel>> {
        (self.build)(worker)
    }
}

/// [`ModelFactory`] for native `Sequential` models: captures an
/// architecture-building closure plus a **canonical parameter snapshot**
/// taken from one prototype, and loads that snapshot into every replica
/// — so all workers hold byte-identical weights even if the builder
/// closure is not deterministic.
pub struct NativeModelFactory {
    build_arch: Box<dyn Fn() -> crate::nn::Sequential + Send + Sync>,
    params: Vec<Tensor>,
    in_features: usize,
}

impl NativeModelFactory {
    /// Snapshot the parameters of one `build()` prototype and serve
    /// replicas of it.
    pub fn new(
        in_features: usize,
        build: impl Fn() -> crate::nn::Sequential + Send + Sync + 'static,
    ) -> NativeModelFactory {
        use crate::nn::Module;
        let proto = build();
        let params = proto
            .parameters()
            .iter()
            .map(|p| p.data().contiguous())
            .collect();
        NativeModelFactory {
            build_arch: Box::new(build),
            params,
            in_features,
        }
    }

    /// Serve an *existing* model (e.g. just trained or loaded from a
    /// checkpoint): snapshot `model`'s parameters and rebuild the
    /// architecture with `build` for each worker replica. The replicas
    /// carry `model`'s weights, not whatever `build` initialises.
    pub fn from_trained(
        model: &crate::nn::Sequential,
        in_features: usize,
        build: impl Fn() -> crate::nn::Sequential + Send + Sync + 'static,
    ) -> NativeModelFactory {
        use crate::nn::Module;
        let params = model
            .parameters()
            .iter()
            .map(|p| p.data().contiguous())
            .collect();
        NativeModelFactory {
            build_arch: Box::new(build),
            params,
            in_features,
        }
    }
}

impl ModelFactory for NativeModelFactory {
    fn in_features(&self) -> usize {
        self.in_features
    }

    fn build(&self, _worker: usize) -> Result<Box<dyn BatchModel>> {
        use crate::nn::Module;
        let model = (self.build_arch)();
        let ps = model.parameters();
        if ps.len() != self.params.len() {
            return Err(Error::msg(format!(
                "model builder returned {} parameters, snapshot has {}",
                ps.len(),
                self.params.len()
            )));
        }
        for (p, t) in ps.iter().zip(&self.params) {
            if p.data().dims() != t.dims() {
                return Err(Error::ShapeMismatch {
                    op: "NativeModelFactory::build",
                    expected: format!("{:?}", t.dims()),
                    got: format!("{:?}", p.data().dims()),
                });
            }
            p.set_data(t.clone());
        }
        Ok(Box::new(NativeBatchModel::new(model, self.in_features)))
    }
}

/// A [`BatchModel`] over a native `Sequential`, owned outright by the
/// worker thread that runs it — no `Mutex`, no `unsafe`.
pub struct NativeBatchModel {
    model: crate::nn::Sequential,
    in_features: usize,
}

impl NativeBatchModel {
    /// Wrap a model for serving.
    pub fn new(model: crate::nn::Sequential, in_features: usize) -> NativeBatchModel {
        NativeBatchModel { model, in_features }
    }
}

impl BatchModel for NativeBatchModel {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        use crate::nn::Module;
        crate::autograd::no_grad(|| {
            let v = crate::autograd::Var::from_tensor(x.clone(), false);
            Ok(self.model.forward(&v, false)?.data())
        })
    }

    fn in_features(&self) -> usize {
        self.in_features
    }
}

/// One queued request: a feature vector, its deadline, and the channel
/// to answer on.
struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    reply: SyncSender<Result<Vec<f32>>>,
}

/// Aggregate statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Requests currently waiting in the admission queue.
    pub queue_depth: usize,
    /// Fast-rejected submissions (admission queue full).
    pub rejected: u64,
    /// Requests shed at dequeue because their deadline had expired.
    pub shed: u64,
    /// Batches executed per worker (index = worker id).
    pub worker_batches: Vec<u64>,
    /// Mean time a request spent queued before its batch started
    /// executing (admission + batch formation + work-queue wait).
    pub mean_queue_ms: f64,
    /// Mean time a request's batch spent inside the model forward.
    pub mean_compute_ms: f64,
    /// Engine kernel dispatches executed by the worker pool, summed
    /// across workers (thread-local counters rolled up per batch).
    pub exec_dispatches: u64,
    /// SIMD blocks executed by the worker pool.
    pub simd_blocks: u64,
    /// Fused kernels executed by the worker pool.
    pub fused_kernels: u64,
}

/// The dispatcher→worker hand-off: a bounded deque of formed batches.
/// Workers block on `pop` when it is empty; the dispatcher blocks on
/// `push` when `cap` batches are already waiting (which backs pressure
/// up into the admission queue, where submissions fast-reject).
struct WorkQueue {
    state: Mutex<WorkState>,
    cv: Condvar,
}

struct WorkState {
    batches: VecDeque<Vec<Request>>,
    done: bool,
}

impl WorkQueue {
    fn new() -> WorkQueue {
        WorkQueue {
            state: Mutex::new(WorkState {
                batches: VecDeque::new(),
                done: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, batch: Vec<Request>, cap: usize) {
        let mut st = self.state.lock().unwrap();
        while st.batches.len() >= cap && !st.done {
            st = self.cv.wait(st).unwrap();
        }
        st.batches.push_back(batch);
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(b) = st.batches.pop_front() {
                self.cv.notify_all(); // space freed: wake the dispatcher
                return Some(b);
            }
            if st.done {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn finish(&self) {
        self.state.lock().unwrap().done = true;
        self.cv.notify_all();
    }
}

/// Reply with `DeadlineExceeded` to every request whose deadline has
/// passed, keeping the rest. Called at every dequeue point (dispatcher
/// batch formation and worker batch start).
fn shed_expired(pending: &mut Vec<Request>, metrics: &Metrics) {
    let now = Instant::now();
    pending.retain(|r| match r.deadline {
        Some(d) if d <= now => {
            metrics.incr("serve.shed", 1);
            let _ = r.reply.send(Err(Error::DeadlineExceeded));
            false
        }
        _ => true,
    });
}

/// Continuous-batching inference server over a [`ModelFactory`].
pub struct InferenceServer {
    /// Admission sender; `None` once [`Self::drain`] has run. Behind a
    /// mutex so drain can be initiated through `&self` while clients
    /// are mid-request (the critical section is a non-blocking
    /// `try_send`, so admission stays effectively concurrent).
    tx: Mutex<Option<SyncSender<Request>>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
    in_features: usize,
    n_workers: usize,
    queue_depth: usize,
    default_deadline: Option<Duration>,
    /// Prometheus endpoint, alive while the server is
    /// (`ServeConfig::metrics_port`); dropping it stops the listener.
    metrics_http: Option<registry::MetricsServer>,
}

impl InferenceServer {
    /// Spawn the dispatcher and `cfg.workers()` model-replica workers.
    ///
    /// Blocks until every worker has constructed its replica; the first
    /// construction error tears the pool down and is returned.
    pub fn start(factory: impl ModelFactory, cfg: ServeConfig) -> Result<InferenceServer> {
        let factory = Arc::new(factory);
        let in_features = factory.in_features();
        let n_workers = cfg.workers();
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = sync_channel(cfg.queue_depth());
        let metrics = Arc::new(Metrics::new());
        let depth = Arc::new(AtomicUsize::new(0));
        let queue = Arc::new(WorkQueue::new());
        // Batches the dispatcher may run ahead by: enough to keep every
        // worker busy plus one forming, without unbounded buildup.
        let cap = n_workers * 2;

        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let factory = factory.clone();
            let queue = queue.clone();
            let metrics = metrics.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                // Build the replica on this thread: it never migrates,
                // and its thread-local program cache stays warm across
                // every batch this worker executes.
                let model = match factory.build(i) {
                    Ok(m) => {
                        let _ = ready.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                drop(ready);
                worker_loop(i, model, &queue, &metrics, in_features);
            }));
        }
        drop(ready_tx);

        let dispatcher = {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let depth = depth.clone();
            let (max_batch, max_wait) = (cfg.max_batch(), cfg.max_wait());
            std::thread::spawn(move || {
                dispatcher_loop(rx, &queue, cap, max_batch, max_wait, &metrics, &depth);
            })
        };

        let mut first_err: Option<Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(Error::msg("worker thread died during startup"));
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            drop(tx); // dispatcher drains and finishes the work queue
            let _ = dispatcher.join();
            for w in workers {
                let _ = w.join();
            }
            return Err(e);
        }

        // Everything is running: expose the process-wide registry (which
        // this server's counters mirror into) over HTTP if configured.
        let metrics_http = match cfg.metrics_port() {
            Some(port) => match registry::serve_http(port) {
                Ok(s) => Some(s),
                Err(e) => {
                    drop(tx);
                    let _ = dispatcher.join();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(Error::msg(format!(
                        "cannot bind metrics endpoint on port {port}: {e}"
                    )));
                }
            },
            None => None,
        };

        Ok(InferenceServer {
            tx: Mutex::new(Some(tx)),
            dispatcher: Some(dispatcher),
            workers,
            metrics,
            depth,
            in_features,
            n_workers,
            queue_depth: cfg.queue_depth(),
            default_deadline: cfg.deadline(),
            metrics_http,
        })
    }

    /// Submit one example and wait for its outputs (logits).
    ///
    /// Fast-rejects with [`Error::Overloaded`] when the admission queue
    /// is saturated. Applies the config's default deadline, if any.
    pub fn infer(&self, features: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(features, self.default_deadline)
    }

    /// [`Self::infer`] with an explicit per-request deadline: if no
    /// worker has started the request within `deadline`, it is shed
    /// with [`Error::DeadlineExceeded`] instead of executed late.
    pub fn infer_deadline(&self, features: Vec<f32>, deadline: Duration) -> Result<Vec<f32>> {
        self.submit(features, Some(deadline))
    }

    fn submit(&self, features: Vec<f32>, deadline: Option<Duration>) -> Result<Vec<f32>> {
        if features.len() != self.in_features {
            return Err(Error::ShapeMismatch {
                op: "serve.infer",
                expected: format!("{} features", self.in_features),
                got: format!("{}", features.len()),
            });
        }
        let now = Instant::now();
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            features,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            reply: reply_tx,
        };
        {
            let mut asp = trace::span("serve", "admit");
            asp.arg_u("queue_depth", self.depth.load(Ordering::Relaxed) as u64);
            let guard = self.tx.lock().unwrap();
            let Some(tx) = guard.as_ref() else {
                return Err(Error::msg("server stopped"));
            };
            match tx.try_send(req) {
                Ok(()) => {
                    self.depth.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(_)) => {
                    self.metrics.incr("serve.rejected", 1);
                    return Err(Error::Overloaded {
                        queue_depth: self.queue_depth,
                    });
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(Error::msg("server stopped"));
                }
            }
        }
        reply_rx
            .recv()
            .map_err(|_| Error::msg("server dropped the request"))?
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> ServeStats {
        let ms = |q| self.metrics.percentile("serve.latency", q).unwrap_or(0.0) * 1e3;
        ServeStats {
            requests: self.metrics.counter("serve.requests"),
            batches: self.metrics.counter("serve.batches"),
            mean_batch_size: self.metrics.mean("serve.batch_size").unwrap_or(0.0),
            p50_latency_ms: ms(0.5),
            p95_latency_ms: ms(0.95),
            p99_latency_ms: ms(0.99),
            queue_depth: self.depth.load(Ordering::Relaxed),
            rejected: self.metrics.counter("serve.rejected"),
            shed: self.metrics.counter("serve.shed"),
            worker_batches: (0..self.n_workers)
                .map(|i| self.metrics.counter(&format!("serve.worker{i}.batches")))
                .collect(),
            mean_queue_ms: self.metrics.mean("serve.queue_time").unwrap_or(0.0) * 1e3,
            mean_compute_ms: self.metrics.mean("serve.compute_time").unwrap_or(0.0) * 1e3,
            exec_dispatches: self.metrics.counter("serve.exec_dispatches"),
            simd_blocks: self.metrics.counter("serve.simd_blocks"),
            fused_kernels: self.metrics.counter("serve.fused_kernels"),
        }
    }

    /// The server's metrics registry (counters include
    /// `serve.program_cache_hits`, summed across workers).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Address of the Prometheus `/metrics` endpoint, when
    /// `ServeConfig::metrics_port` was set (port 0 resolves to the
    /// OS-assigned ephemeral port here).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.metrics_http.as_ref().map(|s| s.addr())
    }

    /// Close admission: subsequent `infer` calls fail fast with
    /// "server stopped", while every already-admitted request still
    /// receives its real reply (dropping the admission sender
    /// disconnects the dispatcher's receiver only *after* the channel's
    /// buffered requests are delivered — mpsc drains before reporting
    /// disconnect). The threads are joined by [`Self::shutdown`]/`Drop`.
    pub fn drain(&self) {
        self.tx.lock().unwrap().take();
    }

    /// Graceful shutdown: stop admitting, drain every in-flight request
    /// to its real reply, then join the dispatcher and all workers.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.drain();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Dispatcher: form batches under the size-or-deadline flush policy and
/// hand them to the worker pool. Exits (finishing the work queue) when
/// the admission sender is dropped and the channel is drained.
fn dispatcher_loop(
    rx: Receiver<Request>,
    queue: &WorkQueue,
    cap: usize,
    max_batch: usize,
    max_wait: Duration,
    metrics: &Metrics,
    depth: &AtomicUsize,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
    'outer: loop {
        // Block for the first request of the next batch.
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    pending.push(r);
                }
                Err(_) => break 'outer, // admission closed and drained
            }
        }
        // Formation starts once the batch has its first member; the
        // span ends when the batch is handed to the worker pool.
        let form_start = Instant::now();
        // Fill up to max_batch or the flush deadline.
        let flush_at = Instant::now() + max_wait;
        let mut disconnected = false;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            match rx.recv_timeout(flush_at - now) {
                Ok(r) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Shed requests that expired while queued, then dispatch.
        shed_expired(&mut pending, metrics);
        if !pending.is_empty() {
            let d = depth.load(Ordering::Relaxed);
            metrics.observe("serve.queue_depth", d as f64);
            // Live gauge for scrapers (the observe above feeds the
            // distribution; this is the "right now" value).
            registry::gauge_set("minitensor_serve_queue_depth_current", d as f64);
            trace::record_interval(
                0,
                "serve",
                "batch_form",
                form_start,
                Instant::now(),
                &[("size", trace::ArgVal::U(pending.len() as u64))],
            );
            queue.push(std::mem::take(&mut pending), cap);
        }
        if disconnected {
            break 'outer;
        }
    }
    queue.finish();
}

/// Worker: pull batches as they become available, run the replica's
/// bulk forward, reply per request. One long-lived thread per replica —
/// its program cache, tensor pool, and any model-internal scratch stay
/// warm for the server's lifetime.
fn worker_loop(
    id: usize,
    mut model: Box<dyn BatchModel>,
    queue: &WorkQueue,
    metrics: &Metrics,
    in_features: usize,
) {
    while let Some(mut batch) = queue.pop() {
        // A batch may have waited behind slow forwards: shed expiries
        // here too so a stale request never occupies the replica.
        shed_expired(&mut batch, metrics);
        if batch.is_empty() {
            continue;
        }
        let b = batch.len();
        let mut flat = Vec::with_capacity(b * in_features);
        for r in &batch {
            flat.extend_from_slice(&r.features);
        }
        let x = Tensor::from_vec(flat, &[b, in_features])
            .expect("request feature lengths validated at submit");

        let exec_start = Instant::now();
        let before = stats::snapshot();
        let result = {
            let mut xsp = trace::span("serve", "execute");
            xsp.arg_u("worker", id as u64);
            xsp.arg_u("batch", b as u64);
            model.forward_batch(&x)
        };
        let exec_end = Instant::now();
        let delta = stats::snapshot().delta(&before);
        // Thread-local engine counters surfaced through the shared
        // registry: the warm-cache story is observable per server, and
        // the kernel-level counters pin what the pool actually executed.
        metrics.incr("serve.program_cache_hits", delta.program_cache_hits);
        metrics.incr("serve.program_cache_misses", delta.program_cache_misses);
        metrics.incr("serve.exec_dispatches", delta.exec_dispatches);
        metrics.incr("serve.simd_blocks", delta.simd_blocks);
        metrics.incr("serve.fused_kernels", delta.fused_kernels);
        metrics.incr("serve.batches", 1);
        metrics.incr(&format!("serve.worker{id}.batches"), 1);
        metrics.incr("serve.requests", b as u64);
        metrics.observe("serve.batch_size", b as f64);

        match result {
            Ok(out) if out.rank() == 2 && out.dims()[0] == b => {
                let k = out.dims()[1];
                let ov = out.to_vec();
                let compute = exec_end.saturating_duration_since(exec_start);
                let track = if trace::enabled() {
                    trace::virtual_track("serve.requests")
                } else {
                    0
                };
                for (i, r) in batch.drain(..).enumerate() {
                    metrics.observe("serve.latency", r.enqueued.elapsed().as_secs_f64());
                    let queued = exec_start.saturating_duration_since(r.enqueued);
                    metrics.observe("serve.queue_time", queued.as_secs_f64());
                    metrics.observe("serve.compute_time", compute.as_secs_f64());
                    let row = ov[i * k..(i + 1) * k].to_vec();
                    let _ = r.reply.send(Ok(row));
                    // Full request lifecycle (admit -> queue -> execute
                    // -> respond) on the synthetic per-request track,
                    // with the queue/compute breakdown as args.
                    trace::record_interval(
                        track,
                        "serve",
                        "request",
                        r.enqueued,
                        Instant::now(),
                        &[
                            ("queue_us", trace::ArgVal::U(queued.as_micros() as u64)),
                            ("compute_us", trace::ArgVal::U(compute.as_micros() as u64)),
                            ("worker", trace::ArgVal::U(id as u64)),
                        ],
                    );
                }
            }
            Ok(out) => {
                let msg = format!(
                    "model returned shape {:?} for a {b}-row batch",
                    out.dims()
                );
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(Error::msg(msg.clone())));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in batch.drain(..) {
                    let _ = r.reply.send(Err(Error::msg(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::{Activation, Dense, Sequential};

    fn tiny_factory() -> NativeModelFactory {
        NativeModelFactory::new(4, || {
            let mut rng = Rng::new(1);
            Sequential::new()
                .add(Dense::new(4, 8, &mut rng))
                .add(Activation::Relu)
                .add(Dense::new(8, 3, &mut rng))
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let server = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        let out = server.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(out.len(), 3);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_feature_count() {
        let server = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        assert!(server.infer(vec![1.0]).is_err());
        server.shutdown();
    }

    #[test]
    fn batches_concurrent_requests() {
        let cfg = ServeConfig::new()
            .max_batch(8)
            .max_wait(Duration::from_millis(20))
            .queue_depth(64)
            .build()
            .unwrap();
        let server = Arc::new(InferenceServer::start(tiny_factory(), cfg).unwrap());
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let s = server.clone();
                std::thread::spawn(move || s.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 3);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches < 16, "batching should fuse requests: {stats:?}");
        assert!(stats.mean_batch_size > 1.0);
        assert_eq!(stats.worker_batches.len(), 1);
        assert_eq!(stats.worker_batches[0], stats.batches);
        assert!(
            stats.exec_dispatches > 0,
            "worker-pool kernel counters must roll up: {stats:?}"
        );
        assert!(stats.mean_compute_ms > 0.0);
        assert!(stats.mean_queue_ms >= 0.0);
    }

    #[test]
    fn results_match_direct_forward() {
        // Compute the expected output directly on a prototype with the
        // same seed the factory snapshots.
        use crate::nn::Module;
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 3, &mut rng));
        let x = Tensor::from_vec(vec![0.5, -1.0, 2.0, 0.0], &[1, 4]).unwrap();
        let expect = model
            .forward(&crate::autograd::Var::from_tensor(x, false), false)
            .unwrap()
            .data()
            .to_vec();

        let server = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        let got = server.infer(vec![0.5, -1.0, 2.0, 0.0]).unwrap();
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5);
        }
        server.shutdown();
    }

    #[test]
    fn metrics_endpoint_starts_on_ephemeral_port() {
        let cfg = ServeConfig::new().metrics_port(0).build().unwrap();
        let server = InferenceServer::start(tiny_factory(), cfg).unwrap();
        let addr = server.metrics_addr().expect("endpoint configured");
        assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");
        assert!(addr.ip().is_loopback());
        // Without metrics_port there is no endpoint.
        let plain = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        assert!(plain.metrics_addr().is_none());
        plain.shutdown();
        server.shutdown();
    }

    #[test]
    fn factory_error_fails_start_and_joins_cleanly() {
        struct Broken;
        impl ModelFactory for Broken {
            fn in_features(&self) -> usize {
                4
            }
            fn build(&self, worker: usize) -> Result<Box<dyn BatchModel>> {
                if worker == 1 {
                    Err(Error::msg("replica 1 refuses to build"))
                } else {
                    tiny_factory().build(worker)
                }
            }
        }
        let cfg = ServeConfig::new().workers(2).build().unwrap();
        let err = InferenceServer::start(Broken, cfg).err().expect("must fail");
        assert!(err.to_string().contains("refuses to build"));
    }
}
