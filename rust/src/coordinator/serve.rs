//! Multi-worker continuous-batching inference server.
//!
//! The production-serving coordination layer: clients submit single
//! examples; a **dispatcher** thread groups them into batches under a
//! hybrid size-or-deadline flush policy and hands them to a pool of N
//! **worker** threads. Each worker builds and exclusively owns its own
//! model replica (via [`ModelFactory`] — safe by construction, no shared
//! mutable model, no `unsafe impl Send`), so every worker pins a warm
//! per-thread compiled-Program cache: the second identical batch a
//! worker sees skips region partitioning and tape construction entirely.
//! Workers pull the next batch the moment they finish, so batch
//! formation overlaps with execution instead of serializing behind it.
//!
//! Admission control goes beyond the bounded queue:
//!
//! - a saturated admission queue **fast-rejects** with
//!   [`Error::Overloaded`] instead of blocking the client;
//! - requests may carry a **deadline** ([`InferenceServer::infer_deadline`]
//!   or the `serve.deadline_ms` default) — already-expired requests are
//!   shed at dequeue with [`Error::DeadlineExceeded`] instead of burning
//!   a worker on stale work;
//! - shutdown **drains**: every admitted request still receives its real
//!   reply before the threads exit.
//!
//! # Fault tolerance
//!
//! A worker whose forward **panics** does not take the server down: the
//! panic is contained with `catch_unwind`, every request in the batch
//! gets a definite [`Error::WorkerCrashed`] reply (safe to retry — the
//! batch never produced output), and the worker rebuilds its replica in
//! place through the shared [`ModelFactory`] under a capped exponential
//! backoff (`ServeConfig::restart_backoff`, doubled per attempt, capped
//! at 1 s). After `ServeConfig::restart_limit` consecutive rebuild
//! failures the slot is abandoned and the server **degrades**; when the
//! last replica is lost the server drains itself: admission closes, and
//! every queued request is failed with a definite reply instead of
//! hanging.
//!
//! With `ServeConfig::worker_timeout` set, a **watchdog** thread patrols
//! in-flight batches: a worker stuck in one forward longer than the
//! timeout is abandoned (its generation is bumped so it discards its
//! result and exits whenever the forward finally returns), its requests
//! are failed with [`Error::WorkerCrashed`], and a replacement replica
//! is built on a fresh thread.
//!
//! The invariant all of this buys: **every admitted request gets exactly
//! one definite reply** — success, `WorkerCrashed`, `DeadlineExceeded`,
//! or `Overloaded` — no request ever hangs because a replica died.
//! Recovery is observable: `serve.worker_crashes`, `.worker_restarts`,
//! `.worker_timeouts`, and `.replies_dropped` counters (mirrored into
//! the process registry as `minitensor_serve_*_total`), plus the
//! live/degraded/draining health state served on `/healthz` when the
//! server owns a metrics endpoint. The `serve.worker.forward` failpoint
//! ([`runtime::faults`](crate::runtime::faults)) injects the crashes the
//! chaos tests use to prove all of the above.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::config::ServeConfig;
use super::metrics::Metrics;
use crate::error::{Error, Result};
use crate::runtime::metrics as registry;
use crate::runtime::{faults, stats, trace};
use crate::tensor::Tensor;

/// A model the server can run: takes a `[b, d]` batch, returns `[b, k]`.
///
/// No `Send` bound: a model is **built on the worker thread that runs
/// it** (see [`ModelFactory`]) and never crosses threads afterwards.
pub trait BatchModel {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor>;
    /// Input feature count.
    fn in_features(&self) -> usize;
}

/// Builds one [`BatchModel`] replica per worker.
///
/// The factory is shared across the worker-spawn loop (hence
/// `Send + Sync`), but each `build(worker)` call runs **on** that
/// worker's thread and the replica it returns is exclusively owned
/// there. This is what lets the engine keep its non-`Sync` graph types
/// (`Var` is `Rc`-based) out of any cross-thread traffic without a
/// single `unsafe impl`. It is also the recovery path: a crashed
/// worker rebuilds its replica through the same factory, so a factory
/// must remain able to build replicas for the server's whole lifetime.
pub trait ModelFactory: Send + Sync + 'static {
    /// Input feature count (needed before any replica exists, for
    /// request validation).
    fn in_features(&self) -> usize;
    /// Construct worker `worker`'s replica. Called once per worker, on
    /// the worker's own thread — and again after a crash, during
    /// supervised restart.
    fn build(&self, worker: usize) -> Result<Box<dyn BatchModel>>;
}

/// [`ModelFactory`] from a plain closure plus an explicit feature count.
pub struct FactoryFn<F> {
    in_features: usize,
    build: F,
}

impl<F> FactoryFn<F>
where
    F: Fn(usize) -> Result<Box<dyn BatchModel>> + Send + Sync + 'static,
{
    /// Wrap `build` (called once per worker, on the worker thread).
    pub fn new(in_features: usize, build: F) -> FactoryFn<F> {
        FactoryFn { in_features, build }
    }
}

impl<F> ModelFactory for FactoryFn<F>
where
    F: Fn(usize) -> Result<Box<dyn BatchModel>> + Send + Sync + 'static,
{
    fn in_features(&self) -> usize {
        self.in_features
    }

    fn build(&self, worker: usize) -> Result<Box<dyn BatchModel>> {
        (self.build)(worker)
    }
}

/// [`ModelFactory`] for native `Sequential` models: captures an
/// architecture-building closure plus a **canonical parameter snapshot**
/// taken from one prototype, and loads that snapshot into every replica
/// — so all workers hold byte-identical weights even if the builder
/// closure is not deterministic. The same property makes restarts
/// byte-faithful: a rebuilt replica is indistinguishable from the one
/// that crashed.
pub struct NativeModelFactory {
    build_arch: Box<dyn Fn() -> crate::nn::Sequential + Send + Sync>,
    params: Vec<Tensor>,
    in_features: usize,
}

impl NativeModelFactory {
    /// Snapshot the parameters of one `build()` prototype and serve
    /// replicas of it.
    pub fn new(
        in_features: usize,
        build: impl Fn() -> crate::nn::Sequential + Send + Sync + 'static,
    ) -> NativeModelFactory {
        use crate::nn::Module;
        let proto = build();
        let params = proto
            .parameters()
            .iter()
            .map(|p| p.data().contiguous())
            .collect();
        NativeModelFactory {
            build_arch: Box::new(build),
            params,
            in_features,
        }
    }

    /// Serve an *existing* model (e.g. just trained or loaded from a
    /// checkpoint): snapshot `model`'s parameters and rebuild the
    /// architecture with `build` for each worker replica. The replicas
    /// carry `model`'s weights, not whatever `build` initialises.
    pub fn from_trained(
        model: &crate::nn::Sequential,
        in_features: usize,
        build: impl Fn() -> crate::nn::Sequential + Send + Sync + 'static,
    ) -> NativeModelFactory {
        use crate::nn::Module;
        let params = model
            .parameters()
            .iter()
            .map(|p| p.data().contiguous())
            .collect();
        NativeModelFactory {
            build_arch: Box::new(build),
            params,
            in_features,
        }
    }
}

impl ModelFactory for NativeModelFactory {
    fn in_features(&self) -> usize {
        self.in_features
    }

    fn build(&self, _worker: usize) -> Result<Box<dyn BatchModel>> {
        use crate::nn::Module;
        let model = (self.build_arch)();
        let ps = model.parameters();
        if ps.len() != self.params.len() {
            return Err(Error::msg(format!(
                "model builder returned {} parameters, snapshot has {}",
                ps.len(),
                self.params.len()
            )));
        }
        for (p, t) in ps.iter().zip(&self.params) {
            if p.data().dims() != t.dims() {
                return Err(Error::ShapeMismatch {
                    op: "NativeModelFactory::build",
                    expected: format!("{:?}", t.dims()),
                    got: format!("{:?}", p.data().dims()),
                });
            }
            p.set_data(t.clone());
        }
        Ok(Box::new(NativeBatchModel::new(model, self.in_features)))
    }
}

/// A [`BatchModel`] over a native `Sequential`, owned outright by the
/// worker thread that runs it — no `Mutex`, no `unsafe`.
pub struct NativeBatchModel {
    model: crate::nn::Sequential,
    in_features: usize,
}

impl NativeBatchModel {
    /// Wrap a model for serving.
    pub fn new(model: crate::nn::Sequential, in_features: usize) -> NativeBatchModel {
        NativeBatchModel { model, in_features }
    }
}

impl BatchModel for NativeBatchModel {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        use crate::nn::Module;
        crate::autograd::no_grad(|| {
            let v = crate::autograd::Var::from_tensor(x.clone(), false);
            Ok(self.model.forward(&v, false)?.data())
        })
    }

    fn in_features(&self) -> usize {
        self.in_features
    }
}

/// One queued request: a feature vector, its deadline, and the channel
/// to answer on.
struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    reply: SyncSender<Result<Vec<f32>>>,
}

/// Send `result` to the request's client, counting the send as dropped
/// if the client has already walked away (e.g. an `infer_timeout` that
/// gave up). Every reply in the server funnels through here or through
/// [`shed_expired`] so `serve.replies_dropped` is complete.
fn reply(metrics: &Metrics, r: Request, result: Result<Vec<f32>>) {
    if r.reply.send(result).is_err() {
        metrics.incr("serve.replies_dropped", 1);
    }
}

/// Aggregate statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Requests currently waiting in the admission queue.
    pub queue_depth: usize,
    /// Fast-rejected submissions (admission queue full).
    pub rejected: u64,
    /// Requests shed at dequeue because their deadline had expired.
    pub shed: u64,
    /// Batches executed per worker (index = worker id).
    pub worker_batches: Vec<u64>,
    /// Mean time a request spent queued before its batch started
    /// executing (admission + batch formation + work-queue wait).
    pub mean_queue_ms: f64,
    /// Mean time a request's batch spent inside the model forward.
    pub mean_compute_ms: f64,
    /// Engine kernel dispatches executed by the worker pool, summed
    /// across workers (thread-local counters rolled up per batch).
    pub exec_dispatches: u64,
    /// SIMD blocks executed by the worker pool.
    pub simd_blocks: u64,
    /// Fused kernels executed by the worker pool.
    pub fused_kernels: u64,
    /// Worker forwards that panicked and were contained.
    pub worker_crashes: u64,
    /// Successful supervised replica rebuilds (crash + watchdog paths).
    pub worker_restarts: u64,
    /// Stuck workers abandoned by the watchdog.
    pub worker_timeouts: u64,
    /// Replies whose client had already dropped its receiver.
    pub replies_dropped: u64,
    /// Worker threads currently serving (replicas built and live).
    pub workers_alive: usize,
    /// `"live"`, `"degraded"` (≥1 replica slot lost), or `"draining"`.
    pub health: String,
}

/// The dispatcher→worker hand-off: a bounded deque of formed batches.
/// Workers block on `pop` when it is empty; the dispatcher blocks on
/// `push` when `cap` batches are already waiting (which backs pressure
/// up into the admission queue, where submissions fast-reject).
///
/// `fail()` is the all-replicas-lost escape hatch: it marks the queue
/// dead and hands back everything queued so the caller can give each
/// request a definite reply — `push` stops blocking (returning the
/// rejected batch) and `pop` returns `None`, so neither the dispatcher
/// nor any late-built replacement worker can hang on a queue nobody
/// will ever serve.
struct WorkQueue {
    state: Mutex<WorkState>,
    cv: Condvar,
}

struct WorkState {
    batches: VecDeque<Vec<Request>>,
    done: bool,
    failed: bool,
}

impl WorkQueue {
    fn new() -> WorkQueue {
        WorkQueue {
            state: Mutex::new(WorkState {
                batches: VecDeque::new(),
                done: false,
                failed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Queue a batch, blocking while `cap` batches are already waiting.
    /// Returns the batch back if the queue has failed (all replicas
    /// lost) so the caller can reply to its requests.
    fn push(&self, batch: Vec<Request>, cap: usize) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.batches.len() >= cap && !st.done && !st.failed {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.failed {
            return Some(batch);
        }
        st.batches.push_back(batch);
        self.cv.notify_all();
        None
    }

    fn pop(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if st.failed {
                return None;
            }
            if let Some(b) = st.batches.pop_front() {
                self.cv.notify_all(); // space freed: wake the dispatcher
                return Some(b);
            }
            if st.done {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn finish(&self) {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).done = true;
        self.cv.notify_all();
    }

    /// Mark the queue dead and return every batch still waiting.
    fn fail(&self) -> Vec<Vec<Request>> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.failed = true;
        let orphaned: Vec<Vec<Request>> = st.batches.drain(..).collect();
        self.cv.notify_all();
        orphaned
    }
}

/// Reply with `DeadlineExceeded` to every request whose deadline has
/// passed, keeping the rest. Called at every dequeue point (dispatcher
/// batch formation and worker batch start).
fn shed_expired(pending: &mut Vec<Request>, metrics: &Metrics) {
    let now = Instant::now();
    pending.retain(|r| match r.deadline {
        Some(d) if d <= now => {
            metrics.incr("serve.shed", 1);
            if r.reply.send(Err(Error::DeadlineExceeded)).is_err() {
                metrics.incr("serve.replies_dropped", 1);
            }
            false
        }
        _ => true,
    });
}

const HEALTH_LIVE: u8 = 0;
const HEALTH_DEGRADED: u8 = 1;
const HEALTH_DRAINING: u8 = 2;

/// A batch currently inside a worker's forward, parked where the
/// watchdog can see (and, past the timeout, confiscate) it.
struct InFlight {
    gen: u64,
    started: Instant,
    requests: Vec<Request>,
}

/// Per-worker-slot supervision state. The **generation** is the slot's
/// ownership token: exactly one thread serves a slot at a time — the
/// one whose generation matches. The watchdog revokes ownership by
/// bumping the generation; the stuck thread notices (at its next loop
/// turn, or when reclaiming its in-flight batch) and bows out.
struct Slot {
    generation: AtomicU64,
    inflight: Mutex<Option<InFlight>>,
}

/// State shared by the dispatcher, the workers, the watchdog, and the
/// client-facing handle.
struct Shared {
    queue: WorkQueue,
    metrics: Arc<Metrics>,
    factory: Arc<dyn ModelFactory>,
    in_features: usize,
    restart_limit: usize,
    restart_backoff: Duration,
    slots: Vec<Slot>,
    /// Worker threads currently serving batches.
    live: AtomicUsize,
    health: AtomicU8,
    /// Mirror health transitions into the process-wide registry (only
    /// when this server owns the `/metrics`+`/healthz` endpoint, so
    /// side-by-side test servers don't fight over the global state).
    mirror_health: bool,
    /// Admission sender; `None` once draining. Behind a mutex so drain
    /// and the all-replicas-lost path can close admission through
    /// `&self` while clients are mid-request (the critical section is a
    /// non-blocking `try_send`, so admission stays effectively
    /// concurrent).
    tx: Mutex<Option<SyncSender<Request>>>,
    /// Replacement worker threads spawned by the watchdog.
    extra_workers: Mutex<Vec<JoinHandle<()>>>,
    depth: AtomicUsize,
}

impl Shared {
    fn health_name(h: u8) -> &'static str {
        match h {
            HEALTH_DEGRADED => "degraded",
            HEALTH_DRAINING => "draining",
            _ => "live",
        }
    }

    fn set_health(&self, h: u8) {
        self.health.store(h, Ordering::SeqCst);
        if self.mirror_health {
            registry::health_set(Self::health_name(h));
        }
    }

    /// live → degraded; never un-drains a draining server.
    fn degrade(&self) {
        if self
            .health
            .compare_exchange(HEALTH_LIVE, HEALTH_DEGRADED, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
            && self.mirror_health
        {
            registry::health_set("degraded");
        }
    }

    fn close_admission(&self) {
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
    }
}

fn panic_detail(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Continuous-batching inference server over a [`ModelFactory`], with
/// supervised worker restart (see the module docs' fault-tolerance
/// section).
pub struct InferenceServer {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// Watchdog stop flag + thread, when `worker_timeout` is set.
    supervisor: Option<(Arc<StopFlag>, JoinHandle<()>)>,
    n_workers: usize,
    queue_depth: usize,
    default_deadline: Option<Duration>,
    /// Prometheus endpoint, alive while the server is
    /// (`ServeConfig::metrics_port`); dropping it stops the listener.
    metrics_http: Option<registry::MetricsServer>,
}

type StopFlag = (Mutex<bool>, Condvar);

impl InferenceServer {
    /// Spawn the dispatcher and `cfg.workers()` model-replica workers
    /// (plus the stuck-worker watchdog if `cfg.worker_timeout()` is set).
    ///
    /// Blocks until every worker has constructed its replica; the first
    /// construction error tears the pool down and is returned.
    pub fn start(factory: impl ModelFactory, cfg: ServeConfig) -> Result<InferenceServer> {
        let factory: Arc<dyn ModelFactory> = Arc::new(factory);
        let in_features = factory.in_features();
        let n_workers = cfg.workers();
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = sync_channel(cfg.queue_depth());
        let shared = Arc::new(Shared {
            queue: WorkQueue::new(),
            metrics: Arc::new(Metrics::new()),
            factory,
            in_features,
            restart_limit: cfg.restart_limit(),
            restart_backoff: cfg.restart_backoff(),
            slots: (0..n_workers)
                .map(|_| Slot {
                    generation: AtomicU64::new(0),
                    inflight: Mutex::new(None),
                })
                .collect(),
            live: AtomicUsize::new(0),
            health: AtomicU8::new(HEALTH_LIVE),
            mirror_health: cfg.metrics_port().is_some(),
            tx: Mutex::new(Some(tx)),
            extra_workers: Mutex::new(Vec::new()),
            depth: AtomicUsize::new(0),
        });
        // Batches the dispatcher may run ahead by: enough to keep every
        // worker busy plus one forming, without unbounded buildup.
        let cap = n_workers * 2;

        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let shared = shared.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                // Build the replica on this thread: it never migrates,
                // and its thread-local program cache stays warm across
                // every batch this worker executes.
                let model = match shared.factory.build(i) {
                    Ok(m) => {
                        let _ = ready.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                drop(ready);
                run_worker(shared, i, 0, model);
            }));
        }
        drop(ready_tx);

        let dispatcher = {
            let shared = shared.clone();
            let (max_batch, max_wait) = (cfg.max_batch(), cfg.max_wait());
            std::thread::spawn(move || {
                dispatcher_loop(rx, &shared, cap, max_batch, max_wait);
            })
        };

        let mut first_err: Option<Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(Error::msg("worker thread died during startup"));
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            shared.close_admission(); // dispatcher drains and finishes the queue
            let _ = dispatcher.join();
            for w in workers {
                let _ = w.join();
            }
            return Err(e);
        }

        // Everything is running: expose the process-wide registry (which
        // this server's counters mirror into) over HTTP if configured.
        let metrics_http = match cfg.metrics_port() {
            Some(port) => match registry::serve_http(port) {
                Ok(s) => {
                    registry::health_set("live");
                    Some(s)
                }
                Err(e) => {
                    shared.close_admission();
                    let _ = dispatcher.join();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(Error::msg(format!(
                        "cannot bind metrics endpoint on port {port}: {e}"
                    )));
                }
            },
            None => None,
        };

        let supervisor = cfg.worker_timeout().map(|timeout| {
            let stop: Arc<StopFlag> = Arc::new((Mutex::new(false), Condvar::new()));
            let sh = shared.clone();
            let st = stop.clone();
            let h = std::thread::spawn(move || supervisor_loop(&sh, &st, timeout));
            (stop, h)
        });

        Ok(InferenceServer {
            shared,
            dispatcher: Some(dispatcher),
            workers,
            supervisor,
            n_workers,
            queue_depth: cfg.queue_depth(),
            default_deadline: cfg.deadline(),
            metrics_http,
        })
    }

    /// Submit one example and wait for its outputs (logits).
    ///
    /// Fast-rejects with [`Error::Overloaded`] when the admission queue
    /// is saturated. Applies the config's default deadline, if any.
    pub fn infer(&self, features: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit(features, self.default_deadline)?;
        rx.recv().map_err(|_| Error::msg("server dropped the request"))?
    }

    /// [`Self::infer`] with an explicit per-request deadline: if no
    /// worker has started the request within `deadline`, it is shed
    /// with [`Error::DeadlineExceeded`] instead of executed late.
    pub fn infer_deadline(&self, features: Vec<f32>, deadline: Duration) -> Result<Vec<f32>> {
        let rx = self.submit(features, Some(deadline))?;
        rx.recv().map_err(|_| Error::msg("server dropped the request"))?
    }

    /// [`Self::infer`] that also bounds the **client's wait**: gives up
    /// with [`Error::DeadlineExceeded`] after `timeout` even if the
    /// request is mid-execution. The abandoned reply is counted in
    /// `serve.replies_dropped` when the worker eventually produces it.
    pub fn infer_timeout(&self, features: Vec<f32>, timeout: Duration) -> Result<Vec<f32>> {
        let rx = self.submit(features, Some(timeout))?;
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(Error::DeadlineExceeded),
            Err(RecvTimeoutError::Disconnected) => Err(Error::msg("server dropped the request")),
        }
    }

    fn submit(
        &self,
        features: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Vec<f32>>>> {
        if features.len() != self.shared.in_features {
            return Err(Error::ShapeMismatch {
                op: "serve.infer",
                expected: format!("{} features", self.shared.in_features),
                got: format!("{}", features.len()),
            });
        }
        let now = Instant::now();
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            features,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            reply: reply_tx,
        };
        {
            let mut asp = trace::span("serve", "admit");
            asp.arg_u("queue_depth", self.shared.depth.load(Ordering::Relaxed) as u64);
            let guard = self.shared.tx.lock().unwrap_or_else(|e| e.into_inner());
            let Some(tx) = guard.as_ref() else {
                return Err(Error::msg("server stopped"));
            };
            match tx.try_send(req) {
                Ok(()) => {
                    self.shared.depth.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(_)) => {
                    self.shared.metrics.incr("serve.rejected", 1);
                    return Err(Error::Overloaded {
                        queue_depth: self.queue_depth,
                    });
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(Error::msg("server stopped"));
                }
            }
        }
        Ok(reply_rx)
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> ServeStats {
        let m = &self.shared.metrics;
        let ms = |q| m.percentile("serve.latency", q).unwrap_or(0.0) * 1e3;
        ServeStats {
            requests: m.counter("serve.requests"),
            batches: m.counter("serve.batches"),
            mean_batch_size: m.mean("serve.batch_size").unwrap_or(0.0),
            p50_latency_ms: ms(0.5),
            p95_latency_ms: ms(0.95),
            p99_latency_ms: ms(0.99),
            queue_depth: self.shared.depth.load(Ordering::Relaxed),
            rejected: m.counter("serve.rejected"),
            shed: m.counter("serve.shed"),
            worker_batches: (0..self.n_workers)
                .map(|i| m.counter(&format!("serve.worker{i}.batches")))
                .collect(),
            mean_queue_ms: m.mean("serve.queue_time").unwrap_or(0.0) * 1e3,
            mean_compute_ms: m.mean("serve.compute_time").unwrap_or(0.0) * 1e3,
            exec_dispatches: m.counter("serve.exec_dispatches"),
            simd_blocks: m.counter("serve.simd_blocks"),
            fused_kernels: m.counter("serve.fused_kernels"),
            worker_crashes: m.counter("serve.worker_crashes"),
            worker_restarts: m.counter("serve.worker_restarts"),
            worker_timeouts: m.counter("serve.worker_timeouts"),
            replies_dropped: m.counter("serve.replies_dropped"),
            workers_alive: self.shared.live.load(Ordering::SeqCst),
            health: Shared::health_name(self.shared.health.load(Ordering::SeqCst)).to_string(),
        }
    }

    /// The server's metrics registry (counters include
    /// `serve.program_cache_hits`, summed across workers).
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// Address of the Prometheus `/metrics` + `/healthz` endpoint, when
    /// `ServeConfig::metrics_port` was set (port 0 resolves to the
    /// OS-assigned ephemeral port here).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.metrics_http.as_ref().map(|s| s.addr())
    }

    /// Close admission: subsequent `infer` calls fail fast with
    /// "server stopped", while every already-admitted request still
    /// receives its real reply (dropping the admission sender
    /// disconnects the dispatcher's receiver only *after* the channel's
    /// buffered requests are delivered — mpsc drains before reporting
    /// disconnect). Health moves to `draining`. The threads are joined
    /// by [`Self::shutdown`]/`Drop`.
    pub fn drain(&self) {
        self.shared.close_admission();
        self.shared.set_health(HEALTH_DRAINING);
    }

    /// Graceful shutdown: stop admitting, drain every in-flight request
    /// to its real reply, then join the dispatcher and all workers.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.drain();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for (i, w) in self.workers.drain(..).enumerate() {
            // A slot the watchdog abandoned may still hold its original
            // thread stuck inside a forward. It discards its result and
            // exits on its own when the forward returns, so join it only
            // if it has actually finished — never block shutdown on it.
            if self.shared.slots[i].generation.load(Ordering::SeqCst) == 0 || w.is_finished() {
                let _ = w.join();
            }
        }
        // The watchdog stops only after the workers are down, so a
        // worker that gets stuck *during* the drain is still replaced
        // and its batches still reach definite replies.
        if let Some((stop, h)) = self.supervisor.take() {
            *stop.0.lock().unwrap_or_else(|e| e.into_inner()) = true;
            stop.1.notify_all();
            let _ = h.join();
        }
        let extras: Vec<JoinHandle<()>> = self
            .shared
            .extra_workers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for w in extras {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Dispatcher: form batches under the size-or-deadline flush policy and
/// hand them to the worker pool. Exits (finishing the work queue) when
/// the admission sender is dropped and the channel is drained.
fn dispatcher_loop(
    rx: Receiver<Request>,
    shared: &Shared,
    cap: usize,
    max_batch: usize,
    max_wait: Duration,
) {
    let metrics = &shared.metrics;
    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
    'outer: loop {
        // Block for the first request of the next batch.
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => {
                    shared.depth.fetch_sub(1, Ordering::Relaxed);
                    pending.push(r);
                }
                Err(_) => break 'outer, // admission closed and drained
            }
        }
        // Formation starts once the batch has its first member; the
        // span ends when the batch is handed to the worker pool.
        let form_start = Instant::now();
        // Fill up to max_batch or the flush deadline.
        let flush_at = Instant::now() + max_wait;
        let mut disconnected = false;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            match rx.recv_timeout(flush_at - now) {
                Ok(r) => {
                    shared.depth.fetch_sub(1, Ordering::Relaxed);
                    pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Shed requests that expired while queued, then dispatch.
        shed_expired(&mut pending, metrics);
        if !pending.is_empty() {
            let d = shared.depth.load(Ordering::Relaxed);
            metrics.observe("serve.queue_depth", d as f64);
            // Live gauge for scrapers (the observe above feeds the
            // distribution; this is the "right now" value).
            registry::gauge_set("minitensor_serve_queue_depth_current", d as f64);
            trace::record_interval(
                0,
                "serve",
                "batch_form",
                form_start,
                Instant::now(),
                &[("size", trace::ArgVal::U(pending.len() as u64))],
            );
            if let Some(rejected) = shared.queue.push(std::mem::take(&mut pending), cap) {
                // All replicas are lost: the queue will never be served
                // again, so these requests get their definite reply here.
                for r in rejected {
                    reply(
                        metrics,
                        r,
                        Err(Error::WorkerCrashed {
                            worker: 0,
                            detail: "all model replicas lost; server is draining".into(),
                        }),
                    );
                }
            }
        }
        if disconnected {
            break 'outer;
        }
    }
    shared.queue.finish();
}

/// Why a worker thread left its serving loop.
enum WorkerExit {
    /// The work queue finished (drain) or failed (all replicas lost).
    Drained,
    /// The watchdog bumped the slot generation; a replacement owns it.
    Superseded,
    /// `restart_limit` consecutive rebuilds failed; the slot is lost.
    GaveUp,
}

/// Worker thread body: maintain the live count around the serving loop
/// and handle the slot-lost aftermath (degrade; if this was the last
/// replica, fail everything still queued so no request hangs).
fn run_worker(shared: Arc<Shared>, slot_id: usize, gen: u64, model: Box<dyn BatchModel>) {
    shared.live.fetch_add(1, Ordering::SeqCst);
    let exit = worker_loop(&shared, slot_id, gen, model);
    let left = shared.live.fetch_sub(1, Ordering::SeqCst) - 1;
    if let WorkerExit::GaveUp = exit {
        shared.degrade();
        if left == 0 {
            fail_all(&shared, slot_id);
        }
    }
}

/// Terminal failure: every replica slot is lost. Close admission, mark
/// the server draining, and fail everything still queued with a definite
/// reply (the dispatcher handles anything still in the admission channel
/// the same way via the failed queue's `push` rejection).
fn fail_all(shared: &Shared, slot_id: usize) {
    shared.close_admission();
    shared.set_health(HEALTH_DRAINING);
    for batch in shared.queue.fail() {
        for r in batch {
            reply(
                &shared.metrics,
                r,
                Err(Error::WorkerCrashed {
                    worker: slot_id,
                    detail: "all model replicas lost; server is draining".into(),
                }),
            );
        }
    }
}

/// Rebuild a replica through the shared factory under capped exponential
/// backoff. Returns `None` after `restart_limit` failed attempts, or as
/// soon as the slot generation moves on (a replacement owns the slot —
/// stop competing with it). A successful rebuild counts one
/// `serve.worker_restarts`.
fn build_with_backoff(shared: &Shared, slot_id: usize, gen: u64) -> Option<Box<dyn BatchModel>> {
    let slot = &shared.slots[slot_id];
    for attempt in 0..shared.restart_limit as u32 {
        let delay = backoff_delay(shared.restart_backoff, attempt);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        if slot.generation.load(Ordering::SeqCst) != gen {
            return None;
        }
        let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared.factory.build(slot_id)
        }));
        if let Ok(Ok(m)) = built {
            shared.metrics.incr("serve.worker_restarts", 1);
            return Some(m);
        }
        // Factory error or panic: try again after a longer pause.
    }
    None
}

/// `base · 2^attempt`, capped at 1 s. A zero base retries immediately.
fn backoff_delay(base: Duration, attempt: u32) -> Duration {
    if base.is_zero() {
        return Duration::ZERO;
    }
    std::cmp::min(
        base.saturating_mul(1u32 << attempt.min(20)),
        Duration::from_secs(1),
    )
}

/// Worker: pull batches as they become available, run the replica's
/// bulk forward (panic-contained), reply per request. One long-lived
/// thread per replica — its program cache, tensor pool, and any
/// model-internal scratch stay warm for the server's lifetime.
fn worker_loop(
    shared: &Arc<Shared>,
    slot_id: usize,
    my_gen: u64,
    mut model: Box<dyn BatchModel>,
) -> WorkerExit {
    let metrics = &shared.metrics;
    let in_features = shared.in_features;
    let slot = &shared.slots[slot_id];
    loop {
        if slot.generation.load(Ordering::SeqCst) != my_gen {
            return WorkerExit::Superseded;
        }
        let Some(mut batch) = shared.queue.pop() else {
            return WorkerExit::Drained;
        };
        // A batch may have waited behind slow forwards: shed expiries
        // here too so a stale request never occupies the replica.
        shed_expired(&mut batch, metrics);
        if batch.is_empty() {
            continue;
        }
        let b = batch.len();
        let mut flat = Vec::with_capacity(b * in_features);
        for r in &batch {
            flat.extend_from_slice(&r.features);
        }
        let x = match Tensor::from_vec(flat, &[b, in_features]) {
            Ok(x) => x,
            Err(e) => {
                // Unreachable while submit validates lengths, but a
                // definite reply beats a poisoned worker either way.
                let msg = e.to_string();
                for r in batch {
                    reply(metrics, r, Err(Error::msg(msg.clone())));
                }
                continue;
            }
        };
        // Park the batch where the watchdog can see it before entering
        // the forward.
        {
            let mut inf = slot.inflight.lock().unwrap_or_else(|e| e.into_inner());
            *inf = Some(InFlight {
                gen: my_gen,
                started: Instant::now(),
                requests: batch,
            });
        }

        let exec_start = Instant::now();
        let before = stats::snapshot();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut xsp = trace::span("serve", "execute");
            xsp.arg_u("worker", slot_id as u64);
            xsp.arg_u("batch", b as u64);
            faults::fire("serve.worker.forward")?;
            model.forward_batch(&x)
        }));
        let exec_end = Instant::now();
        let delta = stats::snapshot().delta(&before);
        // Thread-local engine counters surfaced through the shared
        // registry: the warm-cache story is observable per server, and
        // the kernel-level counters pin what the pool actually executed.
        metrics.incr("serve.program_cache_hits", delta.program_cache_hits);
        metrics.incr("serve.program_cache_misses", delta.program_cache_misses);
        metrics.incr("serve.exec_dispatches", delta.exec_dispatches);
        metrics.incr("serve.simd_blocks", delta.simd_blocks);
        metrics.incr("serve.fused_kernels", delta.fused_kernels);
        metrics.incr("serve.batches", 1);
        metrics.incr(&format!("serve.worker{slot_id}.batches"), 1);
        metrics.incr("serve.requests", b as u64);
        metrics.observe("serve.batch_size", b as f64);

        // Reclaim the batch — unless the watchdog confiscated it (then
        // this thread no longer owns the slot and the result is void).
        let mut batch = {
            let mut inf = slot.inflight.lock().unwrap_or_else(|e| e.into_inner());
            match inf.take() {
                Some(f) if f.gen == my_gen => f.requests,
                other => {
                    *inf = other; // a replacement's in-flight batch: put it back
                    Vec::new()
                }
            }
        };

        match outcome {
            Err(payload) => {
                // Contained panic: the replica is assumed poisoned. Fail
                // the batch with a retryable error and rebuild in place.
                let detail = panic_detail(payload.as_ref());
                metrics.incr("serve.worker_crashes", 1);
                for r in batch.drain(..) {
                    reply(
                        metrics,
                        r,
                        Err(Error::WorkerCrashed {
                            worker: slot_id,
                            detail: detail.clone(),
                        }),
                    );
                }
                match build_with_backoff(shared, slot_id, my_gen) {
                    Some(m) => model = m,
                    None => {
                        if slot.generation.load(Ordering::SeqCst) != my_gen {
                            return WorkerExit::Superseded;
                        }
                        return WorkerExit::GaveUp;
                    }
                }
            }
            Ok(result) => {
                if batch.is_empty() {
                    // Confiscated by the watchdog mid-forward: requests
                    // were already failed; the loop head retires this
                    // superseded thread.
                    continue;
                }
                match result {
                    Ok(out) if out.rank() == 2 && out.dims()[0] == b => {
                        let k = out.dims()[1];
                        let ov = out.to_vec();
                        let compute = exec_end.saturating_duration_since(exec_start);
                        let track = if trace::enabled() {
                            trace::virtual_track("serve.requests")
                        } else {
                            0
                        };
                        for (i, r) in batch.drain(..).enumerate() {
                            let enqueued = r.enqueued;
                            metrics.observe("serve.latency", enqueued.elapsed().as_secs_f64());
                            let queued = exec_start.saturating_duration_since(enqueued);
                            metrics.observe("serve.queue_time", queued.as_secs_f64());
                            metrics.observe("serve.compute_time", compute.as_secs_f64());
                            let row = ov[i * k..(i + 1) * k].to_vec();
                            reply(metrics, r, Ok(row));
                            // Full request lifecycle (admit -> queue ->
                            // execute -> respond) on the synthetic
                            // per-request track, with the queue/compute
                            // breakdown as args.
                            trace::record_interval(
                                track,
                                "serve",
                                "request",
                                enqueued,
                                Instant::now(),
                                &[
                                    ("queue_us", trace::ArgVal::U(queued.as_micros() as u64)),
                                    ("compute_us", trace::ArgVal::U(compute.as_micros() as u64)),
                                    ("worker", trace::ArgVal::U(slot_id as u64)),
                                ],
                            );
                        }
                    }
                    Ok(out) => {
                        let msg = format!(
                            "model returned shape {:?} for a {b}-row batch",
                            out.dims()
                        );
                        for r in batch.drain(..) {
                            reply(metrics, r, Err(Error::msg(msg.clone())));
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for r in batch.drain(..) {
                            reply(metrics, r, Err(Error::msg(msg.clone())));
                        }
                    }
                }
            }
        }
    }
}

/// Watchdog: patrol the slots every quarter-timeout; a batch in flight
/// longer than the timeout means its worker is stuck — confiscate the
/// batch (definite `WorkerCrashed` replies), revoke the slot by bumping
/// its generation, and bring up a replacement replica on a fresh thread.
fn supervisor_loop(shared: &Arc<Shared>, stop: &StopFlag, timeout: Duration) {
    let tick = std::cmp::max(timeout / 4, Duration::from_millis(1));
    let (lock, cv) = (&stop.0, &stop.1);
    let mut stopped = lock.lock().unwrap_or_else(|e| e.into_inner());
    while !*stopped {
        let (guard, _) = cv
            .wait_timeout(stopped, tick)
            .unwrap_or_else(|e| e.into_inner());
        stopped = guard;
        if *stopped {
            return;
        }
        for (slot_id, slot) in shared.slots.iter().enumerate() {
            let confiscated = {
                let mut inf = slot.inflight.lock().unwrap_or_else(|e| e.into_inner());
                match &*inf {
                    Some(f)
                        if f.started.elapsed() >= timeout
                            && slot.generation.load(Ordering::SeqCst) == f.gen =>
                    {
                        inf.take()
                    }
                    _ => None,
                }
            };
            let Some(f) = confiscated else { continue };
            // Revoke the slot: the stuck thread discards its result and
            // exits whenever its forward returns.
            let new_gen = slot.generation.fetch_add(1, Ordering::SeqCst) + 1;
            shared.metrics.incr("serve.worker_timeouts", 1);
            for r in f.requests {
                reply(
                    &shared.metrics,
                    r,
                    Err(Error::WorkerCrashed {
                        worker: slot_id,
                        detail: format!(
                            "stuck in forward past the {timeout:?} worker timeout; replica abandoned"
                        ),
                    }),
                );
            }
            let sh = shared.clone();
            let h = std::thread::spawn(move || match build_with_backoff(&sh, slot_id, new_gen) {
                Some(m) => run_worker(sh.clone(), slot_id, new_gen, m),
                None => {
                    if sh.slots[slot_id].generation.load(Ordering::SeqCst) == new_gen {
                        sh.degrade();
                        if sh.live.load(Ordering::SeqCst) == 0 {
                            fail_all(&sh, slot_id);
                        }
                    }
                }
            });
            shared
                .extra_workers
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::{Activation, Dense, Sequential};

    fn tiny_factory() -> NativeModelFactory {
        NativeModelFactory::new(4, || {
            let mut rng = Rng::new(1);
            Sequential::new()
                .add(Dense::new(4, 8, &mut rng))
                .add(Activation::Relu)
                .add(Dense::new(8, 3, &mut rng))
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let server = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        let out = server.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(out.len(), 3);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_feature_count() {
        let server = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        assert!(server.infer(vec![1.0]).is_err());
        server.shutdown();
    }

    #[test]
    fn batches_concurrent_requests() {
        let cfg = ServeConfig::new()
            .max_batch(8)
            .max_wait(Duration::from_millis(20))
            .queue_depth(64)
            .build()
            .unwrap();
        let server = Arc::new(InferenceServer::start(tiny_factory(), cfg).unwrap());
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let s = server.clone();
                std::thread::spawn(move || s.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 3);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches < 16, "batching should fuse requests: {stats:?}");
        assert!(stats.mean_batch_size > 1.0);
        assert_eq!(stats.worker_batches.len(), 1);
        assert_eq!(stats.worker_batches[0], stats.batches);
        assert!(
            stats.exec_dispatches > 0,
            "worker-pool kernel counters must roll up: {stats:?}"
        );
        assert!(stats.mean_compute_ms > 0.0);
        assert!(stats.mean_queue_ms >= 0.0);
        // A healthy server reports itself so.
        assert_eq!(stats.health, "live");
        assert_eq!(stats.workers_alive, 1);
        assert_eq!(stats.worker_crashes, 0);
        assert_eq!(stats.worker_restarts, 0);
    }

    #[test]
    fn results_match_direct_forward() {
        // Compute the expected output directly on a prototype with the
        // same seed the factory snapshots.
        use crate::nn::Module;
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 3, &mut rng));
        let x = Tensor::from_vec(vec![0.5, -1.0, 2.0, 0.0], &[1, 4]).unwrap();
        let expect = model
            .forward(&crate::autograd::Var::from_tensor(x, false), false)
            .unwrap()
            .data()
            .to_vec();

        let server = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        let got = server.infer(vec![0.5, -1.0, 2.0, 0.0]).unwrap();
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5);
        }
        server.shutdown();
    }

    #[test]
    fn metrics_endpoint_starts_on_ephemeral_port() {
        let cfg = ServeConfig::new().metrics_port(0).build().unwrap();
        let server = InferenceServer::start(tiny_factory(), cfg).unwrap();
        let addr = server.metrics_addr().expect("endpoint configured");
        assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");
        assert!(addr.ip().is_loopback());
        // Without metrics_port there is no endpoint.
        let plain = InferenceServer::start(tiny_factory(), ServeConfig::default()).unwrap();
        assert!(plain.metrics_addr().is_none());
        plain.shutdown();
        server.shutdown();
    }

    #[test]
    fn factory_error_fails_start_and_joins_cleanly() {
        struct Broken;
        impl ModelFactory for Broken {
            fn in_features(&self) -> usize {
                4
            }
            fn build(&self, worker: usize) -> Result<Box<dyn BatchModel>> {
                if worker == 1 {
                    Err(Error::msg("replica 1 refuses to build"))
                } else {
                    tiny_factory().build(worker)
                }
            }
        }
        let cfg = ServeConfig::new().workers(2).build().unwrap();
        let err = InferenceServer::start(Broken, cfg).err().expect("must fail");
        assert!(err.to_string().contains("refuses to build"));
    }

    #[test]
    fn infer_timeout_gives_up_and_counts_the_dropped_reply() {
        struct Slow;
        impl BatchModel for Slow {
            fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
                std::thread::sleep(Duration::from_millis(80));
                Ok(Tensor::zeros(&[x.dims()[0], 1]))
            }
            fn in_features(&self) -> usize {
                2
            }
        }
        let factory = FactoryFn::new(2, |_| Ok(Box::new(Slow) as Box<dyn BatchModel>));
        let cfg = ServeConfig::new().max_wait_ms(1).build().unwrap();
        let server = InferenceServer::start(factory, cfg).unwrap();
        let err = server
            .infer_timeout(vec![1.0, 2.0], Duration::from_millis(15))
            .unwrap_err();
        assert!(matches!(err, Error::DeadlineExceeded), "{err}");
        // The worker finishes the batch eventually; its reply lands on a
        // dropped receiver and must be counted, not panicked on.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.stats().replies_dropped == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(server.stats().replies_dropped >= 1);
        server.shutdown();
    }

    #[test]
    fn work_queue_fail_drains_and_rejects() {
        let q = WorkQueue::new();
        let (tx1, _rx1) = sync_channel(1);
        let mk = |tx: &SyncSender<Result<Vec<f32>>>| Request {
            features: vec![0.0],
            enqueued: Instant::now(),
            deadline: None,
            reply: tx.clone(),
        };
        assert!(q.push(vec![mk(&tx1)], 4).is_none());
        let orphaned = q.fail();
        assert_eq!(orphaned.len(), 1, "queued batch handed back on fail");
        // After failure: pushes bounce (even at capacity) and pops end.
        let bounced = q.push(vec![mk(&tx1)], 4);
        assert!(bounced.is_some(), "failed queue must reject, not buffer");
        assert!(q.pop().is_none());
    }
}
