//! Training launcher: builds the model/dataset/optimizer from a
//! [`TrainConfig`] and runs the loop on the selected backend.
//!
//! - [`Backend::Native`]: the Rust engine end-to-end — autograd tape,
//!   fused cross-entropy, optimizer updates.
//! - [`Backend::Xla`]: the AOT path — one fused HLO executable per train
//!   step (forward + backward + SGD update, lowered once from JAX by
//!   `python/compile/aot.py`), driven from Rust with parameters held as
//!   plain tensors. Python is not involved at run time.

use std::time::Instant;

use super::config::{Backend, TrainConfig};
use super::metrics::{Metrics, Timer};
use crate::autograd::Var;
use crate::data::{self, DataLoader, Dataset};
use crate::error::{Error, Result};
use crate::nn::{losses, Activation, Dense, Module, Sequential};
use crate::optim::{Adam, Optimizer, RmsProp, Sgd};
#[cfg(feature = "xla")]
use crate::runtime::Engine;
#[cfg(feature = "xla")]
use crate::tensor::Tensor;

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// `(step, loss)` samples at `log_every` cadence (plus first and last).
    pub losses: Vec<(usize, f32)>,
    pub initial_loss: f32,
    pub final_loss: f32,
    /// Training-set accuracy after the run (classification only).
    pub accuracy: Option<f32>,
    pub steps_per_sec: f64,
    pub backend: Backend,
    pub num_parameters: usize,
}

impl TrainReport {
    /// Loss descent sanity check used by tests and EXPERIMENTS.md (§5
    /// "consistent loss descent").
    pub fn descended(&self, factor: f32) -> bool {
        self.final_loss < self.initial_loss / factor
    }
}

/// Training orchestrator.
pub struct Trainer {
    cfg: TrainConfig,
    pub metrics: Metrics,
}

impl Trainer {
    /// New trainer for a config.
    pub fn new(cfg: TrainConfig) -> Trainer {
        Trainer {
            cfg,
            metrics: Metrics::new(),
        }
    }

    /// The resolved config.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Build the configured dataset.
    pub fn dataset(&self) -> Result<Dataset> {
        let c = &self.cfg;
        Ok(match c.dataset.as_str() {
            "synthetic_mnist" => data::synthetic_mnist(c.n_examples, c.input_side, c.seed),
            "blobs" => data::gaussian_blobs(c.n_examples, c.input_features(), c.classes, 0.8, c.seed),
            "moons" => data::two_moons(c.n_examples, 0.1, c.seed),
            "spiral" => data::spiral(c.n_examples, c.classes, 0.05, c.seed),
            other => return Err(Error::Config(format!("unknown dataset '{other}'"))),
        })
    }

    /// Build the configured MLP.
    pub fn build_model(&self, in_features: usize, classes: usize) -> Sequential {
        let mut rng = data::Rng::new(self.cfg.seed ^ MODEL_SEED_SALT);
        let mut model = Sequential::new();
        let mut prev = in_features;
        for &h in &self.cfg.hidden {
            model = model.add(Dense::new(prev, h, &mut rng)).add(Activation::Relu);
            prev = h;
        }
        model.add(Dense::new(prev, classes, &mut rng))
    }

    /// Build the configured optimizer over `params`.
    pub fn build_optimizer(&self, params: Vec<Var>) -> Result<Box<dyn Optimizer>> {
        let c = &self.cfg;
        Ok(match c.optimizer.as_str() {
            "sgd" => Box::new(Sgd::with_momentum(params, c.lr, c.momentum, c.weight_decay)),
            "adam" => Box::new(Adam::new(params, c.lr)),
            "adamw" => Box::new(Adam::adamw(params, c.lr, c.weight_decay)),
            "rmsprop" => Box::new(RmsProp::new(params, c.lr, 0.99)),
            other => return Err(Error::Config(format!("unknown optimizer '{other}'"))),
        })
    }

    /// Run the configured training job. `train.threads` (when nonzero)
    /// pins the execution layer's worker count for the whole process
    /// before any kernel runs.
    pub fn run(&self) -> Result<TrainReport> {
        if self.cfg.threads > 0 {
            crate::runtime::parallel::set_num_threads(self.cfg.threads);
        }
        match self.cfg.backend {
            Backend::Native => self.run_native(),
            #[cfg(feature = "xla")]
            Backend::Xla => self.run_xla(),
            #[cfg(not(feature = "xla"))]
            Backend::Xla => Err(Error::Config(
                "backend 'xla' requires building with `--features xla`".into(),
            )),
        }
    }

    /// Native backend: autograd + optimizer.
    pub fn run_native(&self) -> Result<TrainReport> {
        let c = &self.cfg;
        let ds = self.dataset()?;
        let in_features = ds.x.dims()[1];
        let classes = ds.classes.max(2);
        let model = self.build_model(in_features, classes);
        let mut opt = self.build_optimizer(model.parameters())?;
        let mut loader = DataLoader::new(ds.clone(), c.batch_size, true, c.seed).drop_last();

        let mut losses = Vec::new();
        let t0 = Instant::now();
        let mut step = 0usize;
        while step < c.steps {
            let Some(batch) = loader.next() else {
                loader.reset();
                continue;
            };
            let _t = Timer::start(&self.metrics, "train.step");
            let x = Var::from_tensor(batch.x, false);
            let logits = model.forward(&x, true)?;
            let loss = losses::cross_entropy(&logits, &batch.y)?;
            let l = loss.item()?;
            opt.zero_grad();
            loss.backward()?;
            opt.step()?;
            if step % c.log_every == 0 || step + 1 == c.steps {
                losses.push((step, l));
            }
            self.metrics.incr("train.steps", 1);
            step += 1;
        }
        let elapsed = t0.elapsed().as_secs_f64();

        // Final accuracy over the full dataset (no grad).
        let acc = crate::autograd::no_grad(|| -> Result<f32> {
            let x = Var::from_tensor(ds.x.clone(), false);
            let logits = model.forward(&x, false)?;
            losses::accuracy(&logits.data(), &ds.y)
        })?;

        Ok(TrainReport {
            initial_loss: losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN),
            final_loss: losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
            losses,
            accuracy: Some(acc),
            steps_per_sec: c.steps as f64 / elapsed,
            backend: Backend::Native,
            num_parameters: model.num_parameters(),
        })
    }

    /// XLA backend: the fused `mlp_train_step` artifact carries
    /// forward+backward+update; Rust owns parameters and the data loop.
    #[cfg(feature = "xla")]
    pub fn run_xla(&self) -> Result<TrainReport> {
        let c = &self.cfg;
        let mut engine = Engine::cpu(&c.artifacts_dir)?;
        let art = engine.manifest().get("mlp_train_step")?.clone();

        // Artifact layout: inputs [x, y_onehot, w1, b1, w2, b2, w3, b3],
        // outputs [loss, w1', b1', w2', b2', w3', b3'].
        let batch = art.input_shapes[0][0];
        let in_features = art.input_shapes[0][1];
        let classes = art.input_shapes[1][1];
        let n_params = art.input_shapes.len() - 2;

        // Validate config compatibility (shapes are baked at AOT time).
        if c.input_features() != in_features && c.dataset == "synthetic_mnist" {
            return Err(Error::Config(format!(
                "xla backend: artifact expects {in_features} input features; set train.input_side so side² matches (artifact batch={batch}, classes={classes})"
            )));
        }

        // Initialize parameters exactly like the native model would.
        let mut rng = data::Rng::new(c.seed ^ MODEL_SEED_SALT);
        let mut params: Vec<Tensor> = Vec::with_capacity(n_params);
        for shape in &art.input_shapes[2..] {
            if shape.len() == 2 {
                let fan_in = shape[1];
                params.push(crate::nn::kaiming_uniform(shape, fan_in, &mut rng));
            } else {
                params.push(Tensor::zeros(shape));
            }
        }

        let ds = self.dataset()?;
        let mut loader = DataLoader::new(ds.clone(), batch, true, c.seed).drop_last();

        let mut losses = Vec::new();
        let t0 = Instant::now();
        let mut step = 0usize;
        while step < c.steps {
            let Some(b) = loader.next() else {
                loader.reset();
                continue;
            };
            let _t = Timer::start(&self.metrics, "train.step");
            let y_onehot = Tensor::one_hot(&b.y, classes)?;
            let mut inputs: Vec<&Tensor> = vec![&b.x, &y_onehot];
            inputs.extend(params.iter());
            let mut outs = engine.run("mlp_train_step", &inputs)?;
            let loss = outs.remove(0).item()?;
            params = outs;
            if step % c.log_every == 0 || step + 1 == c.steps {
                losses.push((step, loss));
            }
            self.metrics.incr("train.steps", 1);
            step += 1;
        }
        let elapsed = t0.elapsed().as_secs_f64();

        // Accuracy via the forward artifact (batch-sized chunks).
        let acc = self.xla_accuracy(&mut engine, &params, &ds, batch, classes)?;

        Ok(TrainReport {
            initial_loss: losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN),
            final_loss: losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
            losses,
            accuracy: acc,
            steps_per_sec: c.steps as f64 / elapsed,
            backend: Backend::Xla,
            num_parameters: params.iter().map(Tensor::numel).sum(),
        })
    }

    #[cfg(feature = "xla")]
    fn xla_accuracy(
        &self,
        engine: &mut Engine,
        params: &[Tensor],
        ds: &Dataset,
        batch: usize,
        _classes: usize,
    ) -> Result<Option<f32>> {
        if engine.manifest().get("mlp_forward").is_err() {
            return Ok(None);
        }
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut loader = DataLoader::new(ds.clone(), batch, false, 0).drop_last();
        for b in &mut loader {
            let mut inputs: Vec<&Tensor> = vec![&b.x];
            inputs.extend(params.iter());
            let outs = engine.run("mlp_forward", &inputs)?;
            let pred = outs[0].argmax_axis(1)?;
            correct += pred
                .iter()
                .zip(b.y.iter())
                .filter(|(p, y)| p == y)
                .count();
            total += b.y.numel();
        }
        Ok(if total == 0 {
            None
        } else {
            Some(correct as f32 / total as f32)
        })
    }
}

// A u64 salt spelled as a hex-ish identifier is invalid Rust; define the
// constant properly here.
#[allow(non_upper_case_globals)]
const MODEL_SEED_SALT: u64 = 0x5EED_CAFE;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Config;

    fn quick_cfg() -> TrainConfig {
        let cfg = Config::parse(
            "[train]\ndataset = blobs\nn_examples = 256\ninput_side = 2\nhidden = 16\nclasses = 3\nsteps = 60\nbatch_size = 32\nlr = 0.01\noptimizer = adam\nlog_every = 10\n",
        )
        .unwrap();
        TrainConfig::from_config(&cfg).unwrap()
    }

    #[test]
    fn native_training_descends_on_blobs() {
        let trainer = Trainer::new(quick_cfg());
        let report = trainer.run().unwrap();
        assert!(report.initial_loss.is_finite());
        assert!(
            report.final_loss < report.initial_loss,
            "loss should descend: {} -> {}",
            report.initial_loss,
            report.final_loss
        );
        assert!(report.accuracy.unwrap() > 0.8, "{report:?}");
        assert!(report.steps_per_sec > 0.0);
        assert_eq!(trainer.metrics.counter("train.steps"), 60);
    }

    #[test]
    fn all_optimizers_run() {
        for opt in ["sgd", "adam", "adamw", "rmsprop"] {
            let mut cfg = quick_cfg();
            cfg.optimizer = opt.into();
            cfg.steps = 10;
            let report = Trainer::new(cfg).run().unwrap();
            assert!(report.final_loss.is_finite(), "{opt}");
        }
        let mut cfg = quick_cfg();
        cfg.optimizer = "bogus".into();
        assert!(Trainer::new(cfg).run().is_err());
    }

    #[test]
    fn unknown_dataset_errors() {
        let mut cfg = quick_cfg();
        cfg.dataset = "imagenet".into();
        assert!(Trainer::new(cfg).run().is_err());
    }

    #[test]
    fn report_descended_check() {
        let r = TrainReport {
            losses: vec![(0, 2.0), (10, 0.5)],
            initial_loss: 2.0,
            final_loss: 0.5,
            accuracy: None,
            steps_per_sec: 1.0,
            backend: Backend::Native,
            num_parameters: 1,
        };
        assert!(r.descended(2.0));
        assert!(!r.descended(10.0));
    }
}
