//! Synthetic datasets.
//!
//! Each generator returns a [`Dataset`]: a features tensor `[n, d…]` and an
//! i32 label (or f32 target) tensor `[n]` / `[n, k]`. All are seeded and
//! CPU-cheap, standing in for the small real workloads the paper trains on.

use super::Rng;
use crate::tensor::Tensor;

/// An in-memory supervised dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Features, first axis = examples.
    pub x: Tensor,
    /// Labels (I32 classes) or regression targets (F32).
    pub y: Tensor,
    /// Number of distinct classes (0 for regression).
    pub classes: usize,
}

impl Dataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.x.dims()[0]
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Split into (train, test) at `train_frac`.
    pub fn split(&self, train_frac: f32) -> (Dataset, Dataset) {
        let n = self.len();
        let n_train = ((n as f32) * train_frac).round() as usize;
        let tr = Dataset {
            x: self.x.narrow(0, 0, n_train).unwrap().contiguous(),
            y: self.y.narrow(0, 0, n_train).unwrap().contiguous(),
            classes: self.classes,
        };
        let te = Dataset {
            x: self.x.narrow(0, n_train, n - n_train).unwrap().contiguous(),
            y: self.y.narrow(0, n_train, n - n_train).unwrap().contiguous(),
            classes: self.classes,
        };
        (tr, te)
    }
}

/// `k` isotropic Gaussian blobs in `d` dimensions, `n` points total.
pub fn gaussian_blobs(n: usize, d: usize, k: usize, std: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    // Blob centers on a scaled hypercube corner-ish layout.
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| 4.0 * (rng.next_f32() - 0.5) * 2.0).collect())
        .collect();
    let mut xs = Vec::with_capacity(n * d);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        for j in 0..d {
            xs.push(centers[c][j] + std * rng.next_normal());
        }
        ys.push(c as i32);
    }
    Dataset {
        x: Tensor::from_vec(xs, &[n, d]).unwrap(),
        y: Tensor::from_vec_i32(ys, &[n]).unwrap(),
        classes: k,
    }
}

/// Classic two-moons binary classification set.
pub fn two_moons(n: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let half = n / 2;
    let mut xs = Vec::with_capacity(n * 2);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let upper = i < half;
        let t = std::f32::consts::PI * rng.next_f32();
        let (mut x0, mut x1) = if upper {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        x0 += noise * rng.next_normal();
        x1 += noise * rng.next_normal();
        xs.push(x0);
        xs.push(x1);
        ys.push(if upper { 0 } else { 1 });
    }
    Dataset {
        x: Tensor::from_vec(xs, &[n, 2]).unwrap(),
        y: Tensor::from_vec_i32(ys, &[n]).unwrap(),
        classes: 2,
    }
}

/// `k`-arm spiral classification (the classic hard nonlinear toy task).
pub fn spiral(n: usize, k: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let per = n / k;
    let total = per * k;
    let mut xs = Vec::with_capacity(total * 2);
    let mut ys = Vec::with_capacity(total);
    for c in 0..k {
        for i in 0..per {
            let r = i as f32 / per as f32;
            let theta =
                c as f32 * 2.0 * std::f32::consts::PI / k as f32 + r * 4.0 + noise * rng.next_normal();
            xs.push(r * theta.cos());
            xs.push(r * theta.sin());
            ys.push(c as i32);
        }
    }
    Dataset {
        x: Tensor::from_vec(xs, &[total, 2]).unwrap(),
        y: Tensor::from_vec_i32(ys, &[total]).unwrap(),
        classes: k,
    }
}

/// Synthetic MNIST-like images: `n` examples of `side×side` grayscale
/// "digits" built from class-conditional stroke templates plus pixel noise.
/// Returns features flattened to `[n, side*side]` in `[0,1]`.
///
/// This is the stand-in for MNIST (no network in the build environment —
/// see DESIGN.md substitutions): same shape, same scale, 10 classes, and a
/// learnable class-conditional signal so loss curves behave like the real
/// thing.
pub fn synthetic_mnist(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let classes = 10usize;
    let d = side * side;
    // Build 10 smooth random templates with distinct spatial structure.
    let mut templates = vec![vec![0.0f32; d]; classes];
    for (c, tpl) in templates.iter_mut().enumerate() {
        // Sum of a few class-salted Gabor-ish bumps.
        let mut trng = Rng::new(seed ^ (0xABCD + c as u64 * 7919));
        for _ in 0..4 {
            let cx = trng.next_f32() * side as f32;
            let cy = trng.next_f32() * side as f32;
            let sx = 1.0 + 2.0 * trng.next_f32();
            let sy = 1.0 + 2.0 * trng.next_f32();
            for yy in 0..side {
                for xx in 0..side {
                    let dx = (xx as f32 - cx) / sx;
                    let dy = (yy as f32 - cy) / sy;
                    tpl[yy * side + xx] += (-(dx * dx + dy * dy) / 2.0).exp();
                }
            }
        }
        // Normalize to [0, 1].
        let max = tpl.iter().cloned().fold(f32::MIN, f32::max).max(1e-6);
        for v in tpl.iter_mut() {
            *v /= max;
        }
    }
    let mut xs = Vec::with_capacity(n * d);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        for j in 0..d {
            let v = templates[c][j] + 0.15 * rng.next_normal();
            xs.push(v.clamp(0.0, 1.0));
        }
        ys.push(c as i32);
    }
    Dataset {
        x: Tensor::from_vec(xs, &[n, d]).unwrap(),
        y: Tensor::from_vec_i32(ys, &[n]).unwrap(),
        classes,
    }
}

/// Linear regression data `y = x·w* + b* + noise` with known ground truth.
/// Returns targets of shape `[n, 1]`; `classes == 0` marks regression.
pub fn regression_linear(n: usize, d: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..d).map(|_| rng.next_normal()).collect();
    let b = rng.next_normal();
    let mut xs = Vec::with_capacity(n * d);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let mut dot = b;
        for wj in w.iter().take(d) {
            let x = rng.next_normal();
            xs.push(x);
            dot += wj * x;
        }
        ys.push(dot + noise * rng.next_normal());
    }
    Dataset {
        x: Tensor::from_vec(xs, &[n, d]).unwrap(),
        y: Tensor::from_vec(ys, &[n, 1]).unwrap(),
        classes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shapes_and_labels() {
        let ds = gaussian_blobs(90, 5, 3, 0.5, 1);
        assert_eq!(ds.x.dims(), &[90, 5]);
        assert_eq!(ds.y.dims(), &[90]);
        assert_eq!(ds.classes, 3);
        assert!(ds.y.iter().all(|v| (0.0..3.0).contains(&v)));
    }

    #[test]
    fn blobs_are_separable_by_center_distance() {
        let ds = gaussian_blobs(300, 2, 3, 0.1, 2);
        // mean intra-class distance << inter-class center distance
        let xv = ds.x.to_vec();
        let yv = ds.y.to_vec();
        let mut centers = vec![[0.0f32; 2]; 3];
        let mut counts = [0usize; 3];
        for i in 0..300 {
            let c = yv[i] as usize;
            centers[c][0] += xv[i * 2];
            centers[c][1] += xv[i * 2 + 1];
            counts[c] += 1;
        }
        for c in 0..3 {
            centers[c][0] /= counts[c] as f32;
            centers[c][1] /= counts[c] as f32;
        }
        let d01 = ((centers[0][0] - centers[1][0]).powi(2)
            + (centers[0][1] - centers[1][1]).powi(2))
        .sqrt();
        assert!(d01 > 0.5, "centers should be distinct, got {d01}");
    }

    #[test]
    fn moons_balanced() {
        let ds = two_moons(100, 0.05, 3);
        let ones = ds.y.iter().filter(|&v| v == 1.0).count();
        assert_eq!(ones, 50);
    }

    #[test]
    fn spiral_shapes() {
        let ds = spiral(99, 3, 0.01, 4);
        assert_eq!(ds.len(), 99);
        assert_eq!(ds.classes, 3);
    }

    #[test]
    fn synthetic_mnist_in_unit_range() {
        let ds = synthetic_mnist(50, 8, 5);
        assert_eq!(ds.x.dims(), &[50, 64]);
        assert!(ds.x.iter().all(|v| (0.0..=1.0).contains(&v)));
        assert_eq!(ds.classes, 10);
    }

    #[test]
    fn regression_has_learnable_signal() {
        let ds = regression_linear(200, 8, 0.01, 6);
        assert_eq!(ds.y.dims(), &[200, 1]);
        assert_eq!(ds.classes, 0);
        // target variance must dominate noise
        let yv = ds.y.to_vec();
        let mean = yv.iter().sum::<f32>() / yv.len() as f32;
        let var = yv.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / yv.len() as f32;
        assert!(var > 0.5, "var={var}");
    }

    #[test]
    fn split_partitions() {
        let ds = gaussian_blobs(100, 2, 2, 0.3, 7);
        let (tr, te) = ds.split(0.8);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = two_moons(20, 0.1, 9);
        let b = two_moons(20, 0.1, 9);
        assert_eq!(a.x.to_vec(), b.x.to_vec());
    }
}
