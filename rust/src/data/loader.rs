//! Mini-batch loader with per-epoch shuffling.

use super::{Dataset, Rng};
use crate::tensor::Tensor;

/// One mini-batch of features and labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
}

/// Shuffling mini-batch iterator over a [`Dataset`].
///
/// Indices are reshuffled each epoch via [`DataLoader::reset`]. The last
/// partial batch is yielded unless `drop_last` is set.
pub struct DataLoader {
    dataset: Dataset,
    batch_size: usize,
    drop_last: bool,
    indices: Vec<usize>,
    cursor: usize,
    rng: Rng,
    shuffle: bool,
}

impl DataLoader {
    /// Build a loader; `shuffle=false` yields examples in dataset order.
    pub fn new(dataset: Dataset, batch_size: usize, shuffle: bool, seed: u64) -> DataLoader {
        assert!(batch_size > 0, "batch_size must be positive");
        let n = dataset.len();
        let mut loader = DataLoader {
            dataset,
            batch_size,
            drop_last: false,
            indices: (0..n).collect(),
            cursor: 0,
            rng: Rng::new(seed),
            shuffle,
        };
        if shuffle {
            loader.rng.shuffle(&mut loader.indices);
        }
        loader
    }

    /// Drop the trailing partial batch.
    pub fn drop_last(mut self) -> DataLoader {
        self.drop_last = true;
        self
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        let n = self.dataset.len();
        if self.drop_last {
            n / self.batch_size
        } else {
            n.div_ceil(self.batch_size)
        }
    }

    /// Restart the epoch (reshuffles when shuffling is on).
    pub fn reset(&mut self) {
        self.cursor = 0;
        if self.shuffle {
            self.rng.shuffle(&mut self.indices);
        }
    }

    /// The underlying dataset.
    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// Gather rows of `t` (first axis) at `idx` into a contiguous tensor.
    fn gather_rows(t: &Tensor, idx: &[usize]) -> Tensor {
        let row: usize = t.dims()[1..].iter().product();
        let src = t.contiguous();
        let s = src.contiguous_data().unwrap();
        let mut data = Vec::with_capacity(idx.len() * row);
        for &i in idx {
            data.extend_from_slice(&s[i * row..(i + 1) * row]);
        }
        let mut dims = t.dims().to_vec();
        dims[0] = idx.len();
        Tensor::from_vec(data, &dims)
            .unwrap()
            .with_dtype(t.dtype())
    }
}

impl Iterator for DataLoader {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let n = self.dataset.len();
        if self.cursor >= n {
            return None;
        }
        let end = (self.cursor + self.batch_size).min(n);
        if self.drop_last && end - self.cursor < self.batch_size {
            return None;
        }
        let idx = &self.indices[self.cursor..end];
        let batch = Batch {
            x: Self::gather_rows(&self.dataset.x, idx),
            y: Self::gather_rows(&self.dataset.y, idx),
        };
        self.cursor = end;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;

    #[test]
    fn batch_shapes_and_count() {
        let ds = gaussian_blobs(10, 3, 2, 0.5, 1);
        let loader = DataLoader::new(ds, 4, false, 0);
        let batches: Vec<Batch> = loader.collect();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].x.dims(), &[4, 3]);
        assert_eq!(batches[2].x.dims(), &[2, 3]); // partial tail
    }

    #[test]
    fn drop_last_removes_partial() {
        let ds = gaussian_blobs(10, 3, 2, 0.5, 1);
        let loader = DataLoader::new(ds, 4, false, 0).drop_last();
        assert_eq!(loader.batches_per_epoch(), 2);
        assert_eq!(loader.count(), 2);
    }

    #[test]
    fn unshuffled_preserves_order() {
        let ds = gaussian_blobs(6, 2, 2, 0.5, 1);
        let first_x = ds.x.row(0).unwrap().to_vec();
        let mut loader = DataLoader::new(ds, 2, false, 0);
        let b = loader.next().unwrap();
        assert_eq!(b.x.row(0).unwrap().to_vec(), first_x);
    }

    #[test]
    fn shuffled_covers_all_examples() {
        let ds = gaussian_blobs(20, 1, 2, 0.0, 1);
        let loader = DataLoader::new(ds.clone(), 6, true, 42);
        let mut seen: Vec<f32> = loader.flat_map(|b| b.x.to_vec()).collect();
        let mut all = ds.x.to_vec();
        seen.sort_by(f32::total_cmp);
        all.sort_by(f32::total_cmp);
        assert_eq!(seen, all);
    }

    #[test]
    fn reset_reshuffles_deterministically() {
        let ds = gaussian_blobs(8, 1, 2, 0.0, 1);
        let mut l1 = DataLoader::new(ds.clone(), 8, true, 5);
        let e1: Vec<f32> = l1.next().unwrap().x.to_vec();
        l1.reset();
        let e2: Vec<f32> = l1.next().unwrap().x.to_vec();
        assert_ne!(e1, e2, "second epoch should differ");
        // identical construction replays the same stream
        let mut l2 = DataLoader::new(ds, 8, true, 5);
        let f1: Vec<f32> = l2.next().unwrap().x.to_vec();
        assert_eq!(e1, f1);
    }

    #[test]
    fn labels_keep_dtype() {
        let ds = gaussian_blobs(4, 2, 2, 0.5, 1);
        let mut loader = DataLoader::new(ds, 2, false, 0);
        let b = loader.next().unwrap();
        assert_eq!(b.y.dtype(), crate::DType::I32);
    }
}
