//! Data substrate: RNG, synthetic datasets, and a shuffling mini-batch
//! loader. The paper's examples "train small models" (§5); these datasets
//! are the realistic small workloads that exercise that path without
//! external downloads.

mod dataset;
mod loader;
mod rng;

pub use dataset::{gaussian_blobs, regression_linear, spiral, synthetic_mnist, two_moons, Dataset};
pub use loader::{Batch, DataLoader};
pub use rng::Rng;
