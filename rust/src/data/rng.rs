//! Deterministic pseudo-random number generation.
//!
//! A small, fast, dependency-free PCG-XSH-RR 64/32 generator plus a
//! Box-Muller normal sampler. Everything stochastic in the engine (init,
//! dropout, datasets, shuffling) flows through this type, so runs are
//! reproducible from a single seed — a prerequisite for the paper's §5
//! "consistent loss descent" checks.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output. Small, fast, and good
/// enough statistical quality for ML workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second output of the last Box-Muller draw.
    spare_normal: Option<f32>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded generator; same seed ⇒ same stream.
    pub fn new(seed: u64) -> Rng {
        let mut rng = Rng {
            state: 0,
            inc: (seed << 1) | 1,
            spare_normal: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa-ish bits for an unbiased dyadic uniform.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift method
    /// (with rejection for exactness).
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal sample (Box-Muller, cached pair).
    pub fn next_normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let mut u = self.next_f32();
        while u <= f32::MIN_POSITIVE {
            u = self.next_f32();
        }
        let v = self.next_f32();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher-Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG with a decorrelated stream (for per-worker seeds).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Rng::new(2);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..10000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_mean_variance() {
        let mut rng = Rng::new(6);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }
}
