//! Element datatypes.
//!
//! The paper's engine supports dense tensors of 32-bit floats (§7) with
//! integer/boolean auxiliaries for labels and masks. We model exactly that:
//! `F32` is the compute dtype; `I32` carries class labels / indices; `Bool`
//! carries comparison results and dropout masks. All dtypes are stored
//! widened to `f32` in a single buffer type (see [`crate::tensor::Storage`]),
//! which keeps the kernel surface minimal — the same minimalism argument the
//! paper makes for its engine.

/// Element type tag attached to every tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float — the primary compute dtype.
    F32,
    /// 32-bit signed integer (labels, indices). Stored exactly in f32 up to
    /// 2^24, which covers every index/label the engine produces.
    I32,
    /// Boolean (0.0 / 1.0). Produced by comparisons, consumed by masking.
    Bool,
}

impl DType {
    /// Size in bytes of one element *as stored* (everything is f32-backed).
    pub const fn size_of(self) -> usize {
        4
    }

    /// Human-readable name, matching NumPy spelling where possible.
    pub const fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::Bool => "bool",
        }
    }

    /// Result dtype for an arithmetic op over two operands.
    ///
    /// Bool promotes to the other operand's dtype; I32 + F32 promotes to
    /// F32 (NumPy-style value-preserving promotion, restricted to the three
    /// dtypes the engine supports).
    pub fn promote(self, other: DType) -> DType {
        use DType::*;
        match (self, other) {
            (F32, _) | (_, F32) => F32,
            (I32, _) | (_, I32) => I32,
            (Bool, Bool) => Bool,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promotion_is_commutative_and_value_preserving() {
        let all = [DType::F32, DType::I32, DType::Bool];
        for &a in &all {
            for &b in &all {
                assert_eq!(a.promote(b), b.promote(a));
            }
        }
        assert_eq!(DType::F32.promote(DType::I32), DType::F32);
        assert_eq!(DType::I32.promote(DType::Bool), DType::I32);
        assert_eq!(DType::Bool.promote(DType::Bool), DType::Bool);
    }

    #[test]
    fn names_match_numpy() {
        assert_eq!(DType::F32.name(), "float32");
        assert_eq!(DType::I32.name(), "int32");
        assert_eq!(DType::Bool.name(), "bool");
    }

    #[test]
    fn storage_size() {
        assert_eq!(DType::F32.size_of(), 4);
    }
}
