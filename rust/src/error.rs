//! Error type shared across the whole engine.

use thiserror::Error;

/// Library-wide error enumeration.
///
/// Every fallible public API in MiniTensor returns [`Result<T>`]. The
/// variants mirror the failure classes the paper's engine must detect:
/// shape/broadcast mismatches (§3.1), autograd misuse (§3.2), and runtime
/// (artifact/PJRT) failures for the AOT backend.
#[derive(Error, Debug)]
pub enum Error {
    /// Two shapes could not be broadcast together (NumPy/PyTorch rules).
    #[error("cannot broadcast shapes {lhs:?} and {rhs:?}")]
    BroadcastMismatch { lhs: Vec<usize>, rhs: Vec<usize> },

    /// An op received a tensor of the wrong rank or dimension sizes.
    #[error("shape mismatch in {op}: expected {expected}, got {got}")]
    ShapeMismatch {
        op: &'static str,
        expected: String,
        got: String,
    },

    /// Reshape target has a different number of elements.
    #[error("cannot reshape {numel} elements into {target:?}")]
    ReshapeNumel { numel: usize, target: Vec<usize> },

    /// Axis out of range for the tensor's rank.
    #[error("axis {axis} out of range for rank {rank}")]
    AxisOutOfRange { axis: isize, rank: usize },

    /// Index out of bounds.
    #[error("index {index} out of bounds for dimension of size {size}")]
    IndexOutOfBounds { index: usize, size: usize },

    /// backward() called on a non-scalar without an explicit seed.
    #[error("backward() requires a scalar output (got shape {shape:?}); pass an explicit gradient")]
    NonScalarBackward { shape: Vec<usize> },

    /// backward() called on a Var that does not require gradients.
    #[error("called backward() on a Var with requires_grad=false")]
    NoGradRequired,

    /// Mixed-dtype operation that the engine does not support.
    #[error("dtype mismatch in {op}: {lhs:?} vs {rhs:?}")]
    DTypeMismatch {
        op: &'static str,
        lhs: crate::DType,
        rhs: crate::DType,
    },

    /// An AOT artifact was missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failure (wraps the `xla` crate error).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Configuration parsing / validation failure.
    #[error("config error: {0}")]
    Config(String),

    /// Anything I/O.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Catch-all for invariant violations.
    #[error("{0}")]
    Msg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Shorthand constructor for free-form errors.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
