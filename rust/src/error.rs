//! Error type shared across the whole engine.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate builds
//! with zero dependencies so the offline vendor set is never a problem.

use std::fmt;

/// Library-wide error enumeration.
///
/// Every fallible public API in MiniTensor returns [`Result<T>`]. The
/// variants mirror the failure classes the paper's engine must detect:
/// shape/broadcast mismatches (§3.1), autograd misuse (§3.2), and runtime
/// (artifact/PJRT) failures for the AOT backend.
#[derive(Debug)]
pub enum Error {
    /// Two shapes could not be broadcast together (NumPy/PyTorch rules).
    BroadcastMismatch { lhs: Vec<usize>, rhs: Vec<usize> },

    /// An op received a tensor of the wrong rank or dimension sizes.
    ShapeMismatch {
        op: &'static str,
        expected: String,
        got: String,
    },

    /// Reshape target has a different number of elements.
    ReshapeNumel { numel: usize, target: Vec<usize> },

    /// Axis out of range for the tensor's rank.
    AxisOutOfRange { axis: isize, rank: usize },

    /// Index out of bounds.
    IndexOutOfBounds { index: usize, size: usize },

    /// backward() called on a non-scalar without an explicit seed.
    NonScalarBackward { shape: Vec<usize> },

    /// backward() called on a Var that does not require gradients.
    NoGradRequired,

    /// Mixed-dtype operation that the engine does not support.
    DTypeMismatch {
        op: &'static str,
        lhs: crate::DType,
        rhs: crate::DType,
    },

    /// An AOT artifact was missing or malformed.
    Artifact(String),

    /// PJRT / XLA runtime failure (wraps the `xla` crate error).
    Xla(String),

    /// Configuration parsing / validation failure.
    Config(String),

    /// The inference server's admission queue is saturated: the request
    /// was fast-rejected instead of queued (load shedding at the door).
    Overloaded { queue_depth: usize },

    /// A request's deadline expired before it completed: either the
    /// server shed it at dequeue instead of running stale work, or the
    /// caller's wait timed out.
    DeadlineExceeded,

    /// The serve worker executing this request's batch panicked (or was
    /// declared stuck by the watchdog). The request was admitted but not
    /// completed; the replica is rebuilt by the supervisor and the
    /// request is safe to retry.
    WorkerCrashed { worker: usize, detail: String },

    /// A fault-injection site fired with the `error` kind
    /// (`MINITENSOR_FAULTS` / `faults::arm`). Only ever produced while
    /// fault injection is armed.
    FaultInjected { site: &'static str },

    /// Anything I/O.
    Io(std::io::Error),

    /// Catch-all for invariant violations.
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BroadcastMismatch { lhs, rhs } => {
                write!(f, "cannot broadcast shapes {lhs:?} and {rhs:?}")
            }
            Error::ShapeMismatch { op, expected, got } => {
                write!(f, "shape mismatch in {op}: expected {expected}, got {got}")
            }
            Error::ReshapeNumel { numel, target } => {
                write!(f, "cannot reshape {numel} elements into {target:?}")
            }
            Error::AxisOutOfRange { axis, rank } => {
                write!(f, "axis {axis} out of range for rank {rank}")
            }
            Error::IndexOutOfBounds { index, size } => {
                write!(f, "index {index} out of bounds for dimension of size {size}")
            }
            Error::NonScalarBackward { shape } => write!(
                f,
                "backward() requires a scalar output (got shape {shape:?}); pass an explicit gradient"
            ),
            Error::NoGradRequired => {
                write!(f, "called backward() on a Var with requires_grad=false")
            }
            Error::DTypeMismatch { op, lhs, rhs } => {
                write!(f, "dtype mismatch in {op}: {lhs:?} vs {rhs:?}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Overloaded { queue_depth } => write!(
                f,
                "server overloaded: admission queue full ({queue_depth} requests); retry with backoff"
            ),
            Error::DeadlineExceeded => {
                write!(f, "request deadline exceeded before completion")
            }
            Error::WorkerCrashed { worker, detail } => {
                write!(f, "serve worker {worker} crashed: {detail}; safe to retry")
            }
            Error::FaultInjected { site } => {
                write!(f, "injected fault at site {site} (fault injection is armed)")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Shorthand constructor for free-form errors.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        let e = Error::BroadcastMismatch {
            lhs: vec![2, 3],
            rhs: vec![4],
        };
        assert_eq!(e.to_string(), "cannot broadcast shapes [2, 3] and [4]");
        assert_eq!(
            Error::msg("boom").to_string(),
            "boom"
        );
        assert!(Error::Config("bad".into()).to_string().contains("config"));
    }

    #[test]
    fn serving_errors_are_descriptive() {
        let e = Error::Overloaded { queue_depth: 64 };
        assert!(e.to_string().contains("overloaded"));
        assert!(e.to_string().contains("64"));
        assert!(Error::DeadlineExceeded.to_string().contains("deadline"));
        let e = Error::WorkerCrashed {
            worker: 3,
            detail: "boom".into(),
        };
        assert!(e.to_string().contains("worker 3"));
        assert!(e.to_string().contains("boom"));
        assert!(e.to_string().contains("retry"));
        let e = Error::FaultInjected { site: "pool.alloc" };
        assert!(e.to_string().contains("pool.alloc"));
        assert!(e.to_string().contains("injected"));
    }

    #[test]
    fn io_errors_chain_source() {
        use std::error::Error as _;
        let e: Error = std::io::Error::from(std::io::ErrorKind::NotFound).into();
        assert!(e.source().is_some());
        assert!(e.to_string().starts_with("io error:"));
    }
}
