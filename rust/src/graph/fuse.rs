//! Region partitioner and eager replay.
//!
//! [`collect_region`] cuts the recorded DAG into **fusable regions**; the
//! plan compiler ([`super::plan`]) strings the regions into a compiled,
//! cacheable step list and dispatches each region as one composed kernel
//! through the execution layer:
//!
//! - a region is a maximal elementwise (unary/binary/ternary) tree whose
//!   interior nodes have exactly one consumer; its frontier — leaves,
//!   shared nodes (consumed more than once), and reduce results — become
//!   the region's tensor inputs;
//! - shared nodes are materialized once and reused (compute-once beats
//!   recompute-per-consumer);
//! - a `Reduce` root fuses its private elementwise subtree as an epilogue
//!   (`exec::fused_reduce`) — no intermediate tensor, order-stable
//!   partials — and a `ReduceAxis` root does the same per row
//!   (`exec::fused_axis_reduce`); a reduce over an already-materialized
//!   tensor replays the exact eager path instead (same numerics, no
//!   copy);
//! - regions that would exceed [`exec::MAX_FUSED_INPUTS`] distinct inputs
//!   or [`kernel::MAX_STACK`] register rows degrade gracefully to
//!   single-op regions (still one dispatch per op, exactly like eager
//!   execution), counted in `runtime::stats` as `fusion_bailouts`.
//!
//! Everything is worklist-based (no recursion), memoized by node id, so
//! arbitrarily deep chains and DAG sharing both work.

use std::collections::{HashMap, HashSet};

use super::kernel::{self, Instr, Program};
use super::node::{NodeKind, NodeRef};
use crate::error::Result;
use crate::ops::exec;
use crate::runtime::stats;
use crate::tensor::Tensor;

/// Operands-before-consumers order over the DAG reachable from `root`
/// (iterative post-order DFS, like `Var::topo_order`).
pub(crate) fn topo_order(root: &NodeRef) -> Vec<NodeRef> {
    let mut visited: HashSet<usize> = HashSet::new();
    let mut order: Vec<NodeRef> = Vec::new();
    let mut stack: Vec<(NodeRef, bool)> = vec![(root.clone(), false)];
    while let Some((n, expanded)) = stack.pop() {
        if expanded {
            order.push(n);
            continue;
        }
        if !visited.insert(n.id) {
            continue;
        }
        stack.push((n.clone(), true));
        for c in n.children() {
            if !visited.contains(&c.id) {
                stack.push((c.clone(), false));
            }
        }
    }
    order
}

/// Consumer counts per node id (edges, not unique parents: a node used
/// twice by one binary op counts twice — it is still shared work).
pub(crate) fn count_uses(root: &NodeRef) -> HashMap<usize, usize> {
    let mut uses: HashMap<usize, usize> = HashMap::new();
    let mut visited: HashSet<usize> = HashSet::new();
    let mut stack: Vec<NodeRef> = vec![root.clone()];
    visited.insert(root.id);
    while let Some(n) = stack.pop() {
        for c in n.children() {
            *uses.entry(c.id).or_insert(0) += 1;
            if visited.insert(c.id) {
                stack.push(c.clone());
            }
        }
    }
    uses
}

/// A collected fusable region: compiled program + frontier input nodes
/// (first-seen order, deduplicated by id — `Load` indices match).
pub(crate) struct Region {
    pub program: Program,
    pub inputs: Vec<NodeRef>,
}

/// Collect the maximal region rooted at elementwise node `root`:
/// iterative postorder walk that stops at leaves, shared nodes, and
/// reduces (they become inputs). Deterministic and cache-independent, so
/// re-collection after materializing pending inputs yields the same
/// region.
///
/// Two resource caps guard the dispatch path, checked incrementally so a
/// pathological region bails in O(cap) work instead of walking its whole
/// subtree first: at most [`exec::MAX_FUSED_INPUTS`] distinct inputs
/// (the slice-table bound) and at most [`kernel::MAX_STACK`] value-stack
/// rows (the register-file bound — right-nested binary chains need depth
/// proportional to nesting). Either overflow degrades to a single-op
/// region ([`single_op_region`]): eager-equivalent cost, bounded
/// scratch, and the operand subtrees still fuse among themselves.
pub(crate) fn collect_region(root: &NodeRef, uses: &HashMap<usize, usize>) -> Region {
    enum Step {
        Visit(NodeRef),
        Emit(NodeRef),
    }
    debug_assert!(root.is_elementwise());
    let mut code: Vec<Instr> = Vec::new();
    let mut inputs: Vec<NodeRef> = Vec::new();
    let mut input_idx: HashMap<usize, usize> = HashMap::new();
    let mut depth = 0usize;
    let mut stack = vec![Step::Visit(root.clone())];
    while let Some(step) = stack.pop() {
        match step {
            Step::Visit(n) => {
                let shared = uses.get(&n.id).copied().unwrap_or(0) > 1;
                if n.id != root.id && (!n.is_elementwise() || shared) {
                    let idx = match input_idx.get(&n.id) {
                        Some(&i) => i,
                        None => {
                            if inputs.len() == exec::MAX_FUSED_INPUTS {
                                stats::record_fusion_bailout();
                                return single_op_region(root);
                            }
                            inputs.push(n.clone());
                            input_idx.insert(n.id, inputs.len() - 1);
                            inputs.len() - 1
                        }
                    };
                    code.push(Instr::Load(idx));
                    depth += 1;
                    if depth > kernel::MAX_STACK {
                        stats::record_fusion_bailout();
                        return single_op_region(root);
                    }
                } else {
                    match &n.kind {
                        NodeKind::Unary { x, .. } => {
                            stack.push(Step::Emit(n.clone()));
                            stack.push(Step::Visit(x.clone()));
                        }
                        NodeKind::Binary { a, b, .. } => {
                            stack.push(Step::Emit(n.clone()));
                            // `a` evaluates first (lower on the stack):
                            // LIFO — push b then a so a pops (and emits)
                            // first.
                            stack.push(Step::Visit(b.clone()));
                            stack.push(Step::Visit(a.clone()));
                        }
                        NodeKind::Where { c, a, b } => {
                            stack.push(Step::Emit(n.clone()));
                            // cond lowest on the value stack, then a, b.
                            stack.push(Step::Visit(b.clone()));
                            stack.push(Step::Visit(a.clone()));
                            stack.push(Step::Visit(c.clone()));
                        }
                        _ => unreachable!("region roots are elementwise"),
                    }
                }
            }
            Step::Emit(n) => match &n.kind {
                NodeKind::Unary { k, .. } => code.push(Instr::Un(*k)),
                NodeKind::Binary { k, .. } => {
                    code.push(Instr::Bin(*k));
                    depth -= 1;
                }
                NodeKind::Where { .. } => {
                    code.push(Instr::Where);
                    depth -= 2;
                }
                _ => unreachable!(),
            },
        }
    }
    debug_assert_eq!(depth, 1, "region tape must leave exactly one value");
    Region {
        program: Program::compile(code, inputs.len()),
        inputs,
    }
}

/// Degenerate one-op region (the resource-cap fallback): the node's
/// direct operands become the inputs, so evaluation proceeds exactly
/// like eager execution for this node while the operand subtrees still
/// fuse among themselves.
fn single_op_region(root: &NodeRef) -> Region {
    let (operands, tail): (Vec<&NodeRef>, Instr) = match &root.kind {
        NodeKind::Unary { k, x } => (vec![x], Instr::Un(*k)),
        NodeKind::Binary { k, a, b } => (vec![a, b], Instr::Bin(*k)),
        NodeKind::Where { c, a, b } => (vec![c, a, b], Instr::Where),
        _ => unreachable!("region roots are elementwise"),
    };
    let mut inputs: Vec<NodeRef> = Vec::new();
    let mut code: Vec<Instr> = Vec::new();
    for opnd in operands {
        let idx = match inputs.iter().position(|i| i.id == opnd.id) {
            Some(i) => i,
            None => {
                inputs.push(NodeRef::clone(opnd));
                inputs.len() - 1
            }
        };
        code.push(Instr::Load(idx));
    }
    code.push(tail);
    Region {
        program: Program::compile(code, inputs.len()),
        inputs,
    }
}

/// Evaluate the DAG rooted at `root` with single-pass kernel fusion,
/// through the compiled-program cache (see [`super::plan`]).
pub(crate) fn eval(root: &NodeRef) -> Result<Tensor> {
    super::plan::eval(root)
}

/// Reference evaluation: replay every node through the eager kernels in
/// topological order (memoized over the DAG). This is the bitwise
/// yardstick `eval` is tested against, and the path `Var::fused` uses to
/// recompute intermediates for the backward replay.
pub(crate) fn eval_eager(root: &NodeRef) -> Result<Tensor> {
    let mut cache: HashMap<usize, Tensor> = HashMap::new();
    eval_eager_cached(root, &mut cache)
}

/// [`eval_eager`] with an external memo table (shared by the VJP replay).
pub(crate) fn eval_eager_cached(
    root: &NodeRef,
    cache: &mut HashMap<usize, Tensor>,
) -> Result<Tensor> {
    for n in topo_order(root) {
        if cache.contains_key(&n.id) {
            continue;
        }
        let t = match &n.kind {
            NodeKind::Leaf(t) => t.clone(),
            NodeKind::Unary { k, x } => k.eval_eager(&cache[&x.id]),
            NodeKind::Binary { k, a, b } => k.eval_eager(&cache[&a.id], &cache[&b.id])?,
            NodeKind::Where { c, a, b } => {
                cache[&a.id].where_cond(&cache[&c.id], &cache[&b.id])?
            }
            NodeKind::Reduce { k, x } => k.eval_eager(&cache[&x.id]),
            NodeKind::ReduceAxis { k, x, keepdim } => {
                k.eval_eager_axis(&cache[&x.id], *keepdim)?
            }
            NodeKind::Nil => unreachable!("Nil exists only during drop"),
        };
        cache.insert(n.id, t);
    }
    Ok(cache[&root.id].clone())
}

/// Count the nodes reachable from `root` (diagnostics / tests).
pub(crate) fn node_count(root: &NodeRef) -> usize {
    topo_order(root).len()
}

/// Count the fused regions `eval` would dispatch for this DAG without
/// running any kernels: leaves are free; every materialization point
/// (root, shared node, reduce, elementwise region root) costs one
/// dispatch. Used by stats-minded callers and tests. Regions wider than
/// [`exec::MAX_FUSED_INPUTS`] degrade to per-op dispatch at eval time,
/// which this estimate does not model (it reports the ideal count).
pub(crate) fn region_count(root: &NodeRef) -> usize {
    let uses = count_uses(root);
    let mut regions = 0usize;
    for n in topo_order(root) {
        let shared = uses.get(&n.id).copied().unwrap_or(0) > 1;
        match &n.kind {
            NodeKind::Leaf(_) => {}
            NodeKind::Reduce { .. } | NodeKind::ReduceAxis { .. } => regions += 1,
            _ => {
                // Elementwise: a region root iff it is the DAG root or
                // consumed by a reduce/boundary... equivalently: counted
                // when shared or when its (unique) consumer cannot absorb
                // it. Conservatively: count nodes that `eval` would
                // materialize — root, shared elementwise nodes, and
                // elementwise nodes consumed only by reduces are covered
                // by the reduce itself (fused epilogue).
                let is_root = n.id == root.id;
                if is_root || shared {
                    regions += 1;
                }
            }
        }
    }
    regions
}

#[cfg(test)]
mod tests {
    use super::super::node::{BinaryKind, Node, ReduceOp, UnaryKind};
    use super::*;

    fn leaf(v: Vec<f32>, dims: &[usize]) -> NodeRef {
        Node::leaf(Tensor::from_vec(v, dims).unwrap())
    }

    #[test]
    fn fused_chain_matches_eager_bitwise() {
        let a = leaf(vec![1.0, -2.0, 3.0, -4.0], &[4]);
        let b = leaf(vec![0.5, 2.0, -1.5, 4.0], &[4]);
        let m = Node::binary(BinaryKind::Mul, &a, &b).unwrap();
        let s = Node::binary(BinaryKind::Add, &m, &a).unwrap();
        let r = Node::unary(UnaryKind::Relu, &s);
        let fused = eval(&r).unwrap();
        let eager = eval_eager(&r).unwrap();
        let (f, e) = (fused.to_vec(), eager.to_vec());
        for i in 0..4 {
            assert_eq!(f[i].to_bits(), e[i].to_bits(), "i={i}");
        }
        assert_eq!(fused.dims(), &[4]);
    }

    #[test]
    fn shared_subexpression_is_materialized_once_and_reused() {
        // c = tanh(a); y = c * c  — c is shared, so it becomes its own
        // region and the square reads it twice through one input slot.
        let a = leaf(vec![0.3, -0.7, 1.1], &[3]);
        let c = Node::unary(UnaryKind::Tanh, &a);
        let y = Node::binary(BinaryKind::Mul, &c, &c).unwrap();
        let fused = eval(&y).unwrap();
        let eager = eval_eager(&y).unwrap();
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
        assert_eq!(region_count(&y), 2);
    }

    #[test]
    fn nested_shared_nodes_evict_safely() {
        // c shared 3x (twice inside one region), d shared 2x: the
        // remaining-edge bookkeeping must evict each exactly after its
        // last consuming dispatch, never before — any premature eviction
        // would panic the executor's live-slot expect.
        let a = leaf((0..256).map(|i| i as f32 * 0.01 - 1.0).collect(), &[256]);
        let c = Node::unary(UnaryKind::Tanh, &a);
        let d = Node::binary(BinaryKind::Mul, &c, &c).unwrap();
        let e = Node::binary(BinaryKind::Add, &d, &c).unwrap();
        let f = Node::binary(BinaryKind::Mul, &e, &d).unwrap();
        let fused = eval(&f).unwrap();
        let eager = eval_eager(&f).unwrap();
        for (x, y) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn reduce_epilogue_matches_eager_bitwise() {
        let n = exec::REDUCE_CHUNK + 333; // multiple fixed chunks
        let av: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let bv: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
        let a = leaf(av, &[n]);
        let b = leaf(bv, &[n]);
        let m = Node::binary(BinaryKind::Mul, &a, &b).unwrap();
        let r = Node::unary(UnaryKind::Relu, &m);
        let s = Node::reduce(ReduceOp::Sum, &r);
        let fused = eval(&s).unwrap().item().unwrap();
        let eager = eval_eager(&s).unwrap().item().unwrap();
        assert_eq!(fused.to_bits(), eager.to_bits());
    }

    #[test]
    fn reduce_over_leaf_replays_eager_path() {
        // Non-contiguous leaf: the eager reduce takes the strided
        // iterator fold; the lazy eval must produce the same bits.
        let t = Tensor::arange(0.0, 64.0)
            .reshape(&[8, 8])
            .unwrap()
            .t()
            .unwrap();
        let l = Node::leaf(t);
        let s = Node::reduce(ReduceOp::Sum, &l);
        let fused = eval(&s).unwrap().item().unwrap();
        let eager = eval_eager(&s).unwrap().item().unwrap();
        assert_eq!(fused.to_bits(), eager.to_bits());
    }

    #[test]
    fn broadcast_inside_region() {
        let a = leaf(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let bias = leaf(vec![10.0, -20.0, 30.0], &[3]);
        let s = Node::binary(BinaryKind::Add, &a, &bias).unwrap();
        let y = Node::unary(UnaryKind::Relu, &s);
        let fused = eval(&y).unwrap();
        let eager = eval_eager(&y).unwrap();
        assert_eq!(fused.dims(), &[2, 3]);
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn deep_chain_does_not_overflow_stack() {
        // Deep enough that recursive evaluation *or* recursive Rc drop
        // would blow the 2 MiB default test-thread stack: both paths
        // must be worklist-based (eval loop + Node's iterative Drop).
        let mut n = leaf(vec![1.0; 8], &[8]);
        for _ in 0..50_000 {
            n = Node::unary(UnaryKind::AddScalar(0.001), &n);
        }
        let fused = eval(&n).unwrap();
        let eager = eval_eager(&n).unwrap();
        assert_eq!(fused.to_vec(), eager.to_vec());
        assert_eq!(node_count(&n), 50_001);
        drop(n); // exercises the iterative teardown explicitly
    }

    #[test]
    fn deep_binary_nesting_exceeding_stack_cap_degrades_gracefully() {
        // Right-nested adds of one shared leaf: distinct inputs stay at
        // 1, but tape stack depth grows with nesting — past MAX_STACK
        // the fuser must fall back to per-op regions, keeping worker
        // register scratch bounded while results stay bitwise-eager.
        let a = leaf(vec![0.5, -1.5, 2.5], &[3]);
        let mut acc = a.clone();
        for _ in 0..200 {
            acc = Node::binary(BinaryKind::Add, &a, &acc).unwrap();
        }
        let fused = eval(&acc).unwrap();
        let eager = eval_eager(&acc).unwrap();
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn wide_tree_exceeding_input_cap_degrades_gracefully() {
        // 20 distinct leaves summed pairwise: > MAX_FUSED_INPUTS distinct
        // inputs in the root region — must still evaluate correctly.
        let leaves: Vec<NodeRef> = (0..20)
            .map(|i| leaf(vec![i as f32 + 0.5; 4], &[4]))
            .collect();
        let mut acc = leaves[0].clone();
        for l in &leaves[1..] {
            acc = Node::binary(BinaryKind::Add, &acc, l).unwrap();
        }
        let fused = eval(&acc).unwrap();
        let eager = eval_eager(&acc).unwrap();
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
    }
}
