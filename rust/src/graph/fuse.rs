//! Region partitioner and fused evaluator.
//!
//! `eval` cuts the recorded DAG into **fusable regions** and dispatches
//! each region as one composed kernel through the execution layer:
//!
//! - a region is a maximal elementwise (unary/binary) tree whose interior
//!   nodes have exactly one consumer; its frontier — leaves, shared nodes
//!   (consumed more than once), and reduce results — become the region's
//!   tensor inputs;
//! - shared nodes are materialized once and reused (compute-once beats
//!   recompute-per-consumer);
//! - a `Reduce` root fuses its private elementwise subtree as an epilogue
//!   (`exec::fused_reduce`) — no intermediate tensor, order-stable
//!   partials; a reduce over an already-materialized tensor replays the
//!   exact eager `reduce_all` path instead (same numerics, no copy);
//! - regions that would exceed [`exec::MAX_FUSED_INPUTS`] distinct inputs
//!   degrade gracefully to single-op regions (still one dispatch per op,
//!   exactly like eager execution).
//!
//! Evaluation is worklist-based (no recursion), memoized by node id, so
//! arbitrarily deep chains and DAG sharing both work.

use std::collections::{HashMap, HashSet};

use super::kernel::{self, Instr, Program};
use super::node::{NodeKind, NodeRef};
use crate::error::Result;
use crate::ops::exec;
use crate::tensor::Tensor;

/// Operands-before-consumers order over the DAG reachable from `root`
/// (iterative post-order DFS, like `Var::topo_order`).
pub(crate) fn topo_order(root: &NodeRef) -> Vec<NodeRef> {
    let mut visited: HashSet<usize> = HashSet::new();
    let mut order: Vec<NodeRef> = Vec::new();
    let mut stack: Vec<(NodeRef, bool)> = vec![(root.clone(), false)];
    while let Some((n, expanded)) = stack.pop() {
        if expanded {
            order.push(n);
            continue;
        }
        if !visited.insert(n.id) {
            continue;
        }
        stack.push((n.clone(), true));
        for c in n.children() {
            if !visited.contains(&c.id) {
                stack.push((c.clone(), false));
            }
        }
    }
    order
}

/// Consumer counts per node id (edges, not unique parents: a node used
/// twice by one binary op counts twice — it is still shared work).
fn count_uses(root: &NodeRef) -> HashMap<usize, usize> {
    let mut uses: HashMap<usize, usize> = HashMap::new();
    let mut visited: HashSet<usize> = HashSet::new();
    let mut stack: Vec<NodeRef> = vec![root.clone()];
    visited.insert(root.id);
    while let Some(n) = stack.pop() {
        for c in n.children() {
            *uses.entry(c.id).or_insert(0) += 1;
            if visited.insert(c.id) {
                stack.push(c.clone());
            }
        }
    }
    uses
}

/// A collected fusable region: compiled program + frontier input nodes
/// (first-seen order, deduplicated by id — `Load` indices match) +
/// per-input edge counts (`Load` occurrences), which the evaluator uses
/// to evict materialized inputs once their last consumer has run.
struct Region {
    program: Program,
    inputs: Vec<NodeRef>,
    input_uses: Vec<usize>,
}

/// Collect the maximal region rooted at elementwise node `root`:
/// iterative postorder walk that stops at leaves, shared nodes, and
/// reduces (they become inputs). Deterministic and cache-independent, so
/// re-collection after materializing pending inputs yields the same
/// region.
///
/// Two resource caps guard the dispatch path, checked incrementally so a
/// pathological region bails in O(cap) work instead of walking its whole
/// subtree first: at most [`exec::MAX_FUSED_INPUTS`] distinct inputs
/// (the slice-table bound) and at most [`kernel::MAX_STACK`] value-stack
/// rows (the register-file bound — right-nested binary chains need depth
/// proportional to nesting). Either overflow degrades to a single-op
/// region ([`single_op_region`]): eager-equivalent cost, bounded
/// scratch, and the operand subtrees still fuse among themselves.
fn collect_region(root: &NodeRef, uses: &HashMap<usize, usize>) -> Region {
    enum Step {
        Visit(NodeRef),
        Emit(NodeRef),
    }
    debug_assert!(root.is_elementwise());
    let mut code: Vec<Instr> = Vec::new();
    let mut inputs: Vec<NodeRef> = Vec::new();
    let mut input_uses: Vec<usize> = Vec::new();
    let mut input_idx: HashMap<usize, usize> = HashMap::new();
    let mut depth = 0usize;
    let mut stack = vec![Step::Visit(root.clone())];
    while let Some(step) = stack.pop() {
        match step {
            Step::Visit(n) => {
                let shared = uses.get(&n.id).copied().unwrap_or(0) > 1;
                if n.id != root.id && (!n.is_elementwise() || shared) {
                    let idx = match input_idx.get(&n.id) {
                        Some(&i) => i,
                        None => {
                            if inputs.len() == exec::MAX_FUSED_INPUTS {
                                return single_op_region(root);
                            }
                            inputs.push(n.clone());
                            input_uses.push(0);
                            input_idx.insert(n.id, inputs.len() - 1);
                            inputs.len() - 1
                        }
                    };
                    input_uses[idx] += 1;
                    code.push(Instr::Load(idx));
                    depth += 1;
                    if depth > kernel::MAX_STACK {
                        return single_op_region(root);
                    }
                } else {
                    match &n.kind {
                        NodeKind::Unary { x, .. } => {
                            stack.push(Step::Emit(n.clone()));
                            stack.push(Step::Visit(x.clone()));
                        }
                        NodeKind::Binary { a, b, .. } => {
                            stack.push(Step::Emit(n.clone()));
                            // `a` evaluates first (lower on the stack):
                            // LIFO — push b then a so a pops (and emits)
                            // first.
                            stack.push(Step::Visit(b.clone()));
                            stack.push(Step::Visit(a.clone()));
                        }
                        _ => unreachable!("region roots are elementwise"),
                    }
                }
            }
            Step::Emit(n) => match &n.kind {
                NodeKind::Unary { k, .. } => code.push(Instr::Un(*k)),
                NodeKind::Binary { k, .. } => {
                    code.push(Instr::Bin(*k));
                    depth -= 1;
                }
                _ => unreachable!(),
            },
        }
    }
    debug_assert_eq!(depth, 1, "region tape must leave exactly one value");
    Region {
        program: Program::compile(code, inputs.len()),
        inputs,
        input_uses,
    }
}

/// Degenerate one-op region (the > MAX_FUSED_INPUTS fallback): the
/// node's direct operands become the inputs, so evaluation proceeds
/// exactly like eager execution for this node while the operand subtrees
/// still fuse among themselves.
fn single_op_region(root: &NodeRef) -> Region {
    match &root.kind {
        NodeKind::Unary { k, x } => Region {
            program: Program::compile(vec![Instr::Load(0), Instr::Un(*k)], 1),
            inputs: vec![x.clone()],
            input_uses: vec![1],
        },
        NodeKind::Binary { k, a, b } => {
            if a.id == b.id {
                Region {
                    program: Program::compile(
                        vec![Instr::Load(0), Instr::Load(0), Instr::Bin(*k)],
                        1,
                    ),
                    inputs: vec![a.clone()],
                    input_uses: vec![2],
                }
            } else {
                Region {
                    program: Program::compile(
                        vec![Instr::Load(0), Instr::Load(1), Instr::Bin(*k)],
                        2,
                    ),
                    inputs: vec![a.clone(), b.clone()],
                    input_uses: vec![1, 1],
                }
            }
        }
        _ => unreachable!("region roots are elementwise"),
    }
}

/// Region inputs that still need materialization (non-leaf, not cached).
fn pending_inputs(region: &Region, cache: &HashMap<usize, Tensor>) -> Vec<NodeRef> {
    region
        .inputs
        .iter()
        .filter(|n| !matches!(n.kind, NodeKind::Leaf(_)) && !cache.contains_key(&n.id))
        .cloned()
        .collect()
}

/// Resolve the region's input tensors (leaf tensors or cached results).
fn input_tensors<'a>(region: &'a Region, cache: &'a HashMap<usize, Tensor>) -> Vec<&'a Tensor> {
    region
        .inputs
        .iter()
        .map(|n| match &n.kind {
            NodeKind::Leaf(t) => t,
            _ => cache.get(&n.id).expect("pending inputs were materialized"),
        })
        .collect()
}

/// After a region's kernel has run, consume its input edges: decrement
/// each materialized input's remaining-consumer count and evict it from
/// the memo once no future dispatch can read it — the dropped storage
/// returns to the thread-local pool for reuse by later regions, so peak
/// memory tracks the *live* set like eager execution, not the whole DAG.
/// Safe because decrements only happen at dispatch, each region
/// dispatches exactly once, and the per-region edge counts sum to the
/// node's total consumer count.
fn consume_inputs(
    region: &Region,
    remaining: &mut HashMap<usize, usize>,
    cache: &mut HashMap<usize, Tensor>,
) {
    for (input, &cnt) in region.inputs.iter().zip(&region.input_uses) {
        if matches!(input.kind, NodeKind::Leaf(_)) {
            continue; // leaves are owned by the DAG, never evicted
        }
        if let Some(r) = remaining.get_mut(&input.id) {
            *r = r.saturating_sub(cnt);
            if *r == 0 {
                cache.remove(&input.id);
            }
        }
    }
}

/// Evaluate the DAG rooted at `root` with single-pass kernel fusion.
pub(crate) fn eval(root: &NodeRef) -> Result<Tensor> {
    let uses = count_uses(root);
    // Remaining consumer edges per node, decremented as dispatches
    // consume them (drives cache eviction in `consume_inputs`).
    let mut remaining: HashMap<usize, usize> = uses.clone();
    let mut cache: HashMap<usize, Tensor> = HashMap::new();
    // Regions are collected once per materialization point and memoized,
    // so a region with pending inputs is not re-walked after they
    // materialize. Entries are dropped once dispatched.
    let mut regions: HashMap<usize, Region> = HashMap::new();
    let mut stack: Vec<NodeRef> = vec![root.clone()];
    while let Some(n) = stack.last().cloned() {
        if cache.contains_key(&n.id) {
            stack.pop();
            continue;
        }
        match &n.kind {
            NodeKind::Leaf(t) => {
                cache.insert(n.id, t.clone());
                stack.pop();
            }
            NodeKind::Unary { .. } | NodeKind::Binary { .. } => {
                let region = regions
                    .entry(n.id)
                    .or_insert_with(|| collect_region(&n, &uses));
                let pending = pending_inputs(region, &cache);
                if pending.is_empty() {
                    let tensors = input_tensors(region, &cache);
                    let prog = &region.program;
                    let t = exec::fused_op(&tensors, &n.shape, n.dtype, prog.n_ops, |ins, out| {
                        prog.eval(ins, out)
                    })?;
                    drop(tensors);
                    let region = regions.remove(&n.id).expect("region just inserted");
                    consume_inputs(&region, &mut remaining, &mut cache);
                    cache.insert(n.id, t);
                    stack.pop();
                } else {
                    stack.extend(pending);
                }
            }
            NodeKind::Reduce { k, x } => {
                let private_elem = x.is_elementwise()
                    && uses.get(&x.id).copied().unwrap_or(0) <= 1;
                if private_elem {
                    // Fused epilogue over the private elementwise subtree.
                    let region = regions
                        .entry(n.id)
                        .or_insert_with(|| collect_region(x, &uses));
                    let pending = pending_inputs(region, &cache);
                    if pending.is_empty() {
                        let tensors = input_tensors(region, &cache);
                        let prog = &region.program;
                        let total = exec::fused_reduce(
                            &tensors,
                            &x.shape,
                            prog.n_ops + 1,
                            |ins, out| prog.eval(ins, out),
                            k.slice_kernel(),
                            |p, q| k.combine(p, q),
                        )?;
                        drop(tensors);
                        let v = k.finish(total.unwrap_or_else(|| k.identity()), x.shape.numel());
                        let region = regions.remove(&n.id).expect("region just inserted");
                        consume_inputs(&region, &mut remaining, &mut cache);
                        cache.insert(n.id, Tensor::scalar(v));
                        stack.pop();
                    } else {
                        stack.extend(pending);
                    }
                } else {
                    // Boundary input (leaf / shared / reduce result):
                    // materialize it, then replay the exact eager
                    // reduction (identical numerics for any layout).
                    let xt = match &x.kind {
                        NodeKind::Leaf(t) => Some(t.clone()),
                        _ => cache.get(&x.id).cloned(),
                    };
                    match xt {
                        Some(t) => {
                            cache.insert(n.id, k.eval_eager(&t));
                            // Consume the reduce→input edge directly (no
                            // region models it).
                            if !matches!(x.kind, NodeKind::Leaf(_)) {
                                if let Some(r) = remaining.get_mut(&x.id) {
                                    *r = r.saturating_sub(1);
                                    if *r == 0 {
                                        cache.remove(&x.id);
                                    }
                                }
                            }
                            stack.pop();
                        }
                        None => stack.push(x.clone()),
                    }
                }
            }
            NodeKind::Nil => unreachable!("Nil exists only during drop"),
        }
    }
    Ok(cache.remove(&root.id).expect("root was evaluated"))
}

/// Reference evaluation: replay every node through the eager kernels in
/// topological order (memoized over the DAG). This is the bitwise
/// yardstick `eval` is tested against, and the path `Var::fused` uses to
/// recompute intermediates for the backward replay.
pub(crate) fn eval_eager(root: &NodeRef) -> Result<Tensor> {
    let mut cache: HashMap<usize, Tensor> = HashMap::new();
    eval_eager_cached(root, &mut cache)
}

/// [`eval_eager`] with an external memo table (shared by the VJP replay).
pub(crate) fn eval_eager_cached(
    root: &NodeRef,
    cache: &mut HashMap<usize, Tensor>,
) -> Result<Tensor> {
    for n in topo_order(root) {
        if cache.contains_key(&n.id) {
            continue;
        }
        let t = match &n.kind {
            NodeKind::Leaf(t) => t.clone(),
            NodeKind::Unary { k, x } => k.eval_eager(&cache[&x.id]),
            NodeKind::Binary { k, a, b } => k.eval_eager(&cache[&a.id], &cache[&b.id])?,
            NodeKind::Reduce { k, x } => k.eval_eager(&cache[&x.id]),
            NodeKind::Nil => unreachable!("Nil exists only during drop"),
        };
        cache.insert(n.id, t);
    }
    Ok(cache[&root.id].clone())
}

/// Count the nodes reachable from `root` (diagnostics / tests).
pub(crate) fn node_count(root: &NodeRef) -> usize {
    topo_order(root).len()
}

/// Count the fused regions `eval` would dispatch for this DAG without
/// running any kernels: leaves are free; every materialization point
/// (root, shared node, reduce, elementwise region root) costs one
/// dispatch. Used by stats-minded callers and tests. Regions wider than
/// [`exec::MAX_FUSED_INPUTS`] degrade to per-op dispatch at eval time,
/// which this estimate does not model (it reports the ideal count).
pub(crate) fn region_count(root: &NodeRef) -> usize {
    let uses = count_uses(root);
    let mut regions = 0usize;
    for n in topo_order(root) {
        let shared = uses.get(&n.id).copied().unwrap_or(0) > 1;
        match &n.kind {
            NodeKind::Leaf(_) => {}
            NodeKind::Reduce { .. } => regions += 1,
            _ => {
                // Elementwise: a region root iff it is the DAG root or
                // consumed by a reduce/boundary... equivalently: counted
                // when shared or when its (unique) consumer cannot absorb
                // it. Conservatively: count nodes that `eval` would
                // materialize — root, shared elementwise nodes, and
                // elementwise nodes consumed only by reduces are covered
                // by the reduce itself (fused epilogue).
                let is_root = n.id == root.id;
                if is_root || shared {
                    regions += 1;
                }
            }
        }
    }
    regions
}

#[cfg(test)]
mod tests {
    use super::super::node::{BinaryKind, Node, ReduceOp, UnaryKind};
    use super::*;

    fn leaf(v: Vec<f32>, dims: &[usize]) -> NodeRef {
        Node::leaf(Tensor::from_vec(v, dims).unwrap())
    }

    #[test]
    fn fused_chain_matches_eager_bitwise() {
        let a = leaf(vec![1.0, -2.0, 3.0, -4.0], &[4]);
        let b = leaf(vec![0.5, 2.0, -1.5, 4.0], &[4]);
        let m = Node::binary(BinaryKind::Mul, &a, &b).unwrap();
        let s = Node::binary(BinaryKind::Add, &m, &a).unwrap();
        let r = Node::unary(UnaryKind::Relu, &s);
        let fused = eval(&r).unwrap();
        let eager = eval_eager(&r).unwrap();
        let (f, e) = (fused.to_vec(), eager.to_vec());
        for i in 0..4 {
            assert_eq!(f[i].to_bits(), e[i].to_bits(), "i={i}");
        }
        assert_eq!(fused.dims(), &[4]);
    }

    #[test]
    fn shared_subexpression_is_materialized_once_and_reused() {
        // c = tanh(a); y = c * c  — c is shared, so it becomes its own
        // region and the square reads it twice through one input slot.
        let a = leaf(vec![0.3, -0.7, 1.1], &[3]);
        let c = Node::unary(UnaryKind::Tanh, &a);
        let y = Node::binary(BinaryKind::Mul, &c, &c).unwrap();
        let fused = eval(&y).unwrap();
        let eager = eval_eager(&y).unwrap();
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
        assert_eq!(region_count(&y), 2);
    }

    #[test]
    fn nested_shared_nodes_evict_safely() {
        // c shared 3x (twice inside one region), d shared 2x: the
        // remaining-edge bookkeeping must evict each exactly after its
        // last consuming dispatch, never before — any premature eviction
        // would panic input_tensors' expect.
        let a = leaf((0..256).map(|i| i as f32 * 0.01 - 1.0).collect(), &[256]);
        let c = Node::unary(UnaryKind::Tanh, &a);
        let d = Node::binary(BinaryKind::Mul, &c, &c).unwrap();
        let e = Node::binary(BinaryKind::Add, &d, &c).unwrap();
        let f = Node::binary(BinaryKind::Mul, &e, &d).unwrap();
        let fused = eval(&f).unwrap();
        let eager = eval_eager(&f).unwrap();
        for (x, y) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn reduce_epilogue_matches_eager_bitwise() {
        let n = exec::REDUCE_CHUNK + 333; // multiple fixed chunks
        let av: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let bv: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).cos()).collect();
        let a = leaf(av, &[n]);
        let b = leaf(bv, &[n]);
        let m = Node::binary(BinaryKind::Mul, &a, &b).unwrap();
        let r = Node::unary(UnaryKind::Relu, &m);
        let s = Node::reduce(ReduceOp::Sum, &r);
        let fused = eval(&s).unwrap().item().unwrap();
        let eager = eval_eager(&s).unwrap().item().unwrap();
        assert_eq!(fused.to_bits(), eager.to_bits());
    }

    #[test]
    fn reduce_over_leaf_replays_eager_path() {
        // Non-contiguous leaf: the eager reduce takes the strided
        // iterator fold; the lazy eval must produce the same bits.
        let t = Tensor::arange(0.0, 64.0)
            .reshape(&[8, 8])
            .unwrap()
            .t()
            .unwrap();
        let l = Node::leaf(t);
        let s = Node::reduce(ReduceOp::Sum, &l);
        let fused = eval(&s).unwrap().item().unwrap();
        let eager = eval_eager(&s).unwrap().item().unwrap();
        assert_eq!(fused.to_bits(), eager.to_bits());
    }

    #[test]
    fn broadcast_inside_region() {
        let a = leaf(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let bias = leaf(vec![10.0, -20.0, 30.0], &[3]);
        let s = Node::binary(BinaryKind::Add, &a, &bias).unwrap();
        let y = Node::unary(UnaryKind::Relu, &s);
        let fused = eval(&y).unwrap();
        let eager = eval_eager(&y).unwrap();
        assert_eq!(fused.dims(), &[2, 3]);
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn deep_chain_does_not_overflow_stack() {
        // Deep enough that recursive evaluation *or* recursive Rc drop
        // would blow the 2 MiB default test-thread stack: both paths
        // must be worklist-based (eval loop + Node's iterative Drop).
        let mut n = leaf(vec![1.0; 8], &[8]);
        for _ in 0..50_000 {
            n = Node::unary(UnaryKind::AddScalar(0.001), &n);
        }
        let fused = eval(&n).unwrap();
        let eager = eval_eager(&n).unwrap();
        assert_eq!(fused.to_vec(), eager.to_vec());
        assert_eq!(node_count(&n), 50_001);
        drop(n); // exercises the iterative teardown explicitly
    }

    #[test]
    fn deep_binary_nesting_exceeding_stack_cap_degrades_gracefully() {
        // Right-nested adds of one shared leaf: distinct inputs stay at
        // 1, but tape stack depth grows with nesting — past MAX_STACK
        // the fuser must fall back to per-op regions, keeping worker
        // register scratch bounded while results stay bitwise-eager.
        let a = leaf(vec![0.5, -1.5, 2.5], &[3]);
        let mut acc = a.clone();
        for _ in 0..200 {
            acc = Node::binary(BinaryKind::Add, &a, &acc).unwrap();
        }
        let fused = eval(&acc).unwrap();
        let eager = eval_eager(&acc).unwrap();
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn wide_tree_exceeding_input_cap_degrades_gracefully() {
        // 20 distinct leaves summed pairwise: > MAX_FUSED_INPUTS distinct
        // inputs in the root region — must still evaluate correctly.
        let leaves: Vec<NodeRef> = (0..20)
            .map(|i| leaf(vec![i as f32 + 0.5; 4], &[4]))
            .collect();
        let mut acc = leaves[0].clone();
        for l in &leaves[1..] {
            acc = Node::binary(BinaryKind::Add, &acc, l).unwrap();
        }
        let fused = eval(&acc).unwrap();
        let eager = eval_eager(&acc).unwrap();
        for (f, e) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(f.to_bits(), e.to_bits());
        }
    }
}
