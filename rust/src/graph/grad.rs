//! Backward replay for fused forwards: given the recorded region DAG and
//! an output cotangent, propagate VJPs in reverse topological order using
//! the eager kernels (`Var::fused` wraps this as its pullback).
//!
//! Intermediates are recomputed eagerly (memoized over the DAG) rather
//! than saved by the fused forward — fusion's whole point is not to
//! materialize them; recomputing on the (rare, training-only) backward
//! keeps the forward allocation-free. The VJP rules mirror
//! `autograd::ops` rule for rule, so fused gradients match the gradients
//! the eager tape would produce for the same expression.

use std::collections::HashMap;

use super::fuse::{eval_eager_cached, topo_order};
use super::node::{NodeKind, NodeRef};
use crate::error::Result;
use crate::tensor::Tensor;

/// Accumulate `g` into `map[id]` (`x̄ += ḡ`).
fn accumulate(map: &mut HashMap<usize, Tensor>, id: usize, g: Tensor) {
    match map.remove(&id) {
        None => {
            map.insert(id, g);
        }
        Some(acc) => {
            map.insert(id, acc.add(&g).expect("cotangent shapes match"));
        }
    }
}

/// Propagate the scalar-or-tensor cotangent `seed` from `root` back to
/// every leaf, returning a map from **leaf node id** to its accumulated
/// cotangent. Leaves the expression never touches simply have no entry.
pub(crate) fn vjp(root: &NodeRef, seed: &Tensor) -> Result<HashMap<usize, Tensor>> {
    vjp_for(root, seed, None)
}

/// [`vjp`] restricted to the leaves in `live` (`None` = all): cotangents
/// are only computed along paths that reach a live leaf, so frozen
/// (`requires_grad = false`) inputs cost nothing on backward — matching
/// the eager tape, which skips constant branches. Forward values are
/// still replayed for the whole DAG because VJP rules read operand
/// *values* even on dead sides (e.g. `ḡ_a = ḡ ⊙ b` for a product).
pub(crate) fn vjp_for(
    root: &NodeRef,
    seed: &Tensor,
    live: Option<&std::collections::HashSet<usize>>,
) -> Result<HashMap<usize, Tensor>> {
    let order = topo_order(root);

    // A node needs a cotangent iff its subtree contains a live leaf
    // (children precede parents in `order`, so one forward scan works).
    // A select's condition is not differentiable — like an argmax, it
    // only routes values — so live leaves reachable *only* through a
    // `Where` condition never receive a cotangent.
    let mut needed: std::collections::HashSet<usize> = std::collections::HashSet::new();
    for n in &order {
        let wanted = match &n.kind {
            NodeKind::Leaf(_) => live.is_none_or(|l| l.contains(&n.id)),
            NodeKind::Where { a, b, .. } => {
                needed.contains(&a.id) || needed.contains(&b.id)
            }
            _ => n.children().iter().any(|c| needed.contains(&c.id)),
        };
        if wanted {
            needed.insert(n.id);
        }
    }
    if !needed.contains(&root.id) {
        return Ok(HashMap::new());
    }

    // Forward values for every node (eager replay, memoized).
    let mut vals: HashMap<usize, Tensor> = HashMap::new();
    eval_eager_cached(root, &mut vals)?;

    let mut cot: HashMap<usize, Tensor> = HashMap::new();
    let mut leaf_grads: HashMap<usize, Tensor> = HashMap::new();
    cot.insert(root.id, seed.clone());

    for n in order.iter().rev() {
        let Some(g) = cot.remove(&n.id) else {
            continue; // not reachable from the seed, or a dead branch
        };
        match &n.kind {
            NodeKind::Leaf(_) => accumulate(&mut leaf_grads, n.id, g),
            NodeKind::Unary { k, x } => {
                if needed.contains(&x.id) {
                    let gx = k.vjp(&vals[&x.id], &vals[&n.id], &g);
                    accumulate(&mut cot, x.id, gx);
                }
            }
            NodeKind::Binary { k, a, b } => {
                // Broadcast pullback per live side: sum the cotangent
                // over expanded axes; dead sides are never computed.
                if needed.contains(&a.id) {
                    let ga = k.vjp_a(&vals[&a.id], &vals[&b.id], &g)?;
                    accumulate(&mut cot, a.id, vals[&a.id].reduce_grad_to(&ga)?);
                }
                if needed.contains(&b.id) {
                    let gb = k.vjp_b(&vals[&a.id], &vals[&b.id], &g)?;
                    accumulate(&mut cot, b.id, vals[&b.id].reduce_grad_to(&gb)?);
                }
            }
            NodeKind::Where { c, a, b } => {
                // Gradient routes to whichever side each element selected;
                // the condition itself gets none (it only routes values).
                let mask = vals[&c.id].map(|v| f32::from(v != 0.0));
                if needed.contains(&a.id) {
                    let ga = g.mul(&mask)?;
                    accumulate(&mut cot, a.id, vals[&a.id].reduce_grad_to(&ga)?);
                }
                if needed.contains(&b.id) {
                    let gb = g.mul(&mask.map(|v| 1.0 - v))?;
                    accumulate(&mut cot, b.id, vals[&b.id].reduce_grad_to(&gb)?);
                }
            }
            NodeKind::Reduce { k, x } => {
                if needed.contains(&x.id) {
                    let gx = k.vjp(&vals[&x.id], &g);
                    accumulate(&mut cot, x.id, gx);
                }
            }
            NodeKind::ReduceAxis { k, x, keepdim } => {
                if needed.contains(&x.id) {
                    let gx = k.vjp_axis(&vals[&x.id], &g, *keepdim);
                    accumulate(&mut cot, x.id, gx);
                }
            }
            NodeKind::Nil => unreachable!("Nil exists only during drop"),
        }
    }
    Ok(leaf_grads)
}

#[cfg(test)]
mod tests {
    use super::super::node::{BinaryKind, Node, ReduceOp, UnaryKind};
    use super::*;

    #[test]
    fn vjp_of_fused_chain_matches_manual_derivative() {
        // y = sum(relu(a * b + a)); dy/da = (b + 1) * 1{a*b+a > 0},
        // dy/db = a * 1{a*b+a > 0}
        let av = vec![1.0f32, -2.0, 3.0, 0.5];
        let bv = vec![0.5f32, 2.0, -3.0, 1.0];
        let a = Node::leaf(Tensor::from_vec(av.clone(), &[4]).unwrap());
        let b = Node::leaf(Tensor::from_vec(bv.clone(), &[4]).unwrap());
        let m = Node::binary(BinaryKind::Mul, &a, &b).unwrap();
        let s = Node::binary(BinaryKind::Add, &m, &a).unwrap();
        let r = Node::unary(UnaryKind::Relu, &s);
        let y = Node::reduce(ReduceOp::Sum, &r);
        let grads = vjp(&y, &Tensor::scalar(1.0)).unwrap();
        let ga = grads[&a.id].to_vec();
        let gb = grads[&b.id].to_vec();
        for i in 0..4 {
            let active = f32::from(av[i] * bv[i] + av[i] > 0.0);
            assert!((ga[i] - (bv[i] + 1.0) * active).abs() < 1e-6, "da[{i}]");
            assert!((gb[i] - av[i] * active).abs() < 1e-6, "db[{i}]");
        }
    }

    #[test]
    fn vjp_broadcast_reduces_bias_grad() {
        // y = sum(x + bias) with x [2,3], bias [3]: dbias = per-column 2.
        let x = Node::leaf(Tensor::ones(&[2, 3]));
        let bias = Node::leaf(Tensor::from_vec(vec![0.1, 0.2, 0.3], &[3]).unwrap());
        let s = Node::binary(BinaryKind::Add, &x, &bias).unwrap();
        let y = Node::reduce(ReduceOp::Sum, &s);
        let grads = vjp(&y, &Tensor::scalar(1.0)).unwrap();
        assert_eq!(grads[&bias.id].dims(), &[3]);
        assert_eq!(grads[&bias.id].to_vec(), vec![2.0, 2.0, 2.0]);
        assert_eq!(grads[&x.id].to_vec(), vec![1.0; 6]);
    }

    #[test]
    fn vjp_shared_node_accumulates_both_paths() {
        // y = sum(c * c) with c = tanh(a): dy/da = 2 c (1 - c²)
        let a0 = Tensor::from_vec(vec![0.3f32, -0.8], &[2]).unwrap();
        let a = Node::leaf(a0.clone());
        let c = Node::unary(UnaryKind::Tanh, &a);
        let y0 = Node::binary(BinaryKind::Mul, &c, &c).unwrap();
        let y = Node::reduce(ReduceOp::Sum, &y0);
        let grads = vjp(&y, &Tensor::scalar(1.0)).unwrap();
        let ga = grads[&a.id].to_vec();
        for (i, &v) in a0.to_vec().iter().enumerate() {
            let t = v.tanh();
            assert!((ga[i] - 2.0 * t * (1.0 - t * t)).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn vjp_where_routes_by_condition_and_skips_cond() {
        // y = sum(where(c, a, b)): da = 1{c != 0}, db = 1{c == 0}, and the
        // condition leaf gets no gradient at all.
        let c = Node::leaf(Tensor::from_vec(vec![1.0, 0.0, 2.0, 0.0], &[4]).unwrap());
        let a = Node::leaf(Tensor::from_vec(vec![5.0, 6.0, 7.0, 8.0], &[4]).unwrap());
        let b = Node::leaf(Tensor::from_vec(vec![-1.0, -2.0, -3.0, -4.0], &[4]).unwrap());
        let w = Node::where_cond(&c, &a, &b).unwrap();
        let y = Node::reduce(ReduceOp::Sum, &w);
        let grads = vjp(&y, &Tensor::scalar(1.0)).unwrap();
        assert_eq!(grads[&a.id].to_vec(), vec![1.0, 0.0, 1.0, 0.0]);
        assert_eq!(grads[&b.id].to_vec(), vec![0.0, 1.0, 0.0, 1.0]);
        assert!(!grads.contains_key(&c.id), "condition is not differentiable");
    }

    #[test]
    fn vjp_axis_reduce_broadcasts_back_per_row() {
        // y = sum(sum_axis(x * 2, -1)): dx = 2 everywhere; mean_axis
        // scales by 1/k.
        let x = Node::leaf(Tensor::ones(&[2, 4]));
        let d = Node::unary(UnaryKind::MulScalar(2.0), &x);
        let r = Node::reduce_axis(ReduceOp::Sum, &d, false).unwrap();
        let y = Node::reduce(ReduceOp::Sum, &r);
        let grads = vjp(&y, &Tensor::scalar(1.0)).unwrap();
        assert_eq!(grads[&x.id].to_vec(), vec![2.0; 8]);

        let x2 = Node::leaf(Tensor::from_vec(vec![3.0, 1.0, 2.0, 0.0, 5.0, 4.0], &[2, 3]).unwrap());
        let m = Node::reduce_axis(ReduceOp::Max, &x2, true).unwrap();
        let y2 = Node::reduce(ReduceOp::Sum, &m);
        let grads = vjp(&y2, &Tensor::scalar(1.0)).unwrap();
        assert_eq!(
            grads[&x2.id].to_vec(),
            vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0],
            "max routes to the row extremum"
        );
    }

    #[test]
    fn vjp_unused_leaf_has_no_entry() {
        let a = Node::leaf(Tensor::ones(&[2]));
        let b = Node::leaf(Tensor::ones(&[2]));
        let y = Node::reduce(ReduceOp::Sum, &a);
        let grads = vjp(&y, &Tensor::scalar(1.0)).unwrap();
        assert!(grads.contains_key(&a.id));
        assert!(!grads.contains_key(&b.id));
    }
}
