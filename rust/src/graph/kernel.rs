//! Region → composed-kernel compiler and its block interpreter.
//!
//! A fused region is compiled to a tiny postorder **instruction tape**
//! over its inputs (a stack machine: `Load` pushes an input, `Un`
//! rewrites the top of stack, `Bin` folds the top two). The interpreter
//! evaluates the tape over [`FUSE_BLOCK`]-element register blocks held in
//! thread-local scratch, so per-instruction dispatch cost is amortized
//! over a whole block, every op body is the explicit 8-lane kernel from
//! [`crate::runtime::simd`] (`Un`/`Bin` through the kinds' `apply_block`,
//! `Where` through `select_ip`), and all intermediates live in L1 — one
//! pass over main memory per region, which is the entire point of fusion
//! (conceptually this *is* the composed `Fn(&[f32]) -> f32`, vectorized).

use std::cell::RefCell;
use std::mem::MaybeUninit;

use super::node::{BinaryKind, UnaryKind};
use crate::ops::exec::FUSE_BLOCK;

/// One stack-machine instruction of a compiled region.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Instr {
    /// Push input `j`'s current block.
    Load(usize),
    /// Apply a unary op to the top block in place.
    Un(UnaryKind),
    /// Fold the top block into the second-from-top: `a = op(a, b)`.
    Bin(BinaryKind),
    /// Ternary select folding the top three blocks (`cond`, `a`, `b` from
    /// bottom to top) into the `cond` slot: `c = c != 0 ? a : b`.
    Where,
}

/// Maximum register-file rows (stack depth) a fused region may use:
/// bounds thread-local [`REGS`] at `MAX_STACK * FUSE_BLOCK` f32s
/// (128 KiB). Deep *unary* chains need depth 1, but right-nested binary
/// chains need depth proportional to nesting — the fuser degrades such
/// regions to per-op dispatch instead of letting worker scratch grow
/// unboundedly.
pub(crate) const MAX_STACK: usize = 32;

/// A compiled fused region: the tape plus its static facts.
#[derive(Clone, Debug)]
pub(crate) struct Program {
    code: Vec<Instr>,
    n_inputs: usize,
    /// Peak value-stack depth the tape reaches (register rows needed).
    pub stack_depth: usize,
    /// Number of `Un`/`Bin` instructions (= graph ops folded).
    pub n_ops: usize,
}

thread_local! {
    /// Register file: `stack_depth` rows of FUSE_BLOCK f32s. Thread-local
    /// so pool workers evaluate allocation-free after warm-up.
    static REGS: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

impl Program {
    /// Wrap a postorder tape, computing stack depth and op count.
    /// Debug-asserts the tape is well formed (leaves exactly one value).
    pub fn compile(code: Vec<Instr>, n_inputs: usize) -> Program {
        let mut depth = 0usize;
        let mut stack_depth = 0usize;
        let mut n_ops = 0usize;
        for instr in &code {
            match instr {
                Instr::Load(j) => {
                    debug_assert!(*j < n_inputs, "Load index out of range");
                    depth += 1;
                    stack_depth = stack_depth.max(depth);
                }
                Instr::Un(_) => {
                    debug_assert!(depth >= 1);
                    n_ops += 1;
                }
                Instr::Bin(_) => {
                    debug_assert!(depth >= 2);
                    n_ops += 1;
                    depth -= 1;
                }
                Instr::Where => {
                    debug_assert!(depth >= 3);
                    n_ops += 1;
                    depth -= 2;
                }
            }
        }
        debug_assert_eq!(depth, 1, "program must leave exactly one value");
        Program {
            code,
            n_inputs,
            stack_depth,
            n_ops,
        }
    }

    /// Evaluate the tape over equal-length input blocks, initializing
    /// every element of `out` (the contract `exec::fused_op` relies on).
    /// Arbitrary lengths are handled by blocking at [`FUSE_BLOCK`]
    /// internally.
    pub fn eval(&self, ins: &[&[f32]], out: &mut [MaybeUninit<f32>]) {
        debug_assert_eq!(ins.len(), self.n_inputs);
        REGS.with(|r| {
            let mut regs = r.borrow_mut();
            let need = self.stack_depth * FUSE_BLOCK;
            if regs.len() < need {
                regs.resize(need, 0.0);
            }
            let n = out.len();
            let mut pos = 0usize;
            while pos < n {
                let len = FUSE_BLOCK.min(n - pos);
                let mut sp = 0usize;
                for instr in &self.code {
                    match *instr {
                        Instr::Load(j) => {
                            let dst = &mut regs[sp * FUSE_BLOCK..sp * FUSE_BLOCK + len];
                            dst.copy_from_slice(&ins[j][pos..pos + len]);
                            sp += 1;
                        }
                        Instr::Un(k) => {
                            let top = (sp - 1) * FUSE_BLOCK;
                            k.apply_block(&mut regs[top..top + len]);
                        }
                        Instr::Bin(k) => {
                            // a = op(a, b): split so `a` (second from
                            // top) and `b` (top) borrow disjointly.
                            let (lo, hi) = regs.split_at_mut((sp - 1) * FUSE_BLOCK);
                            let a0 = (sp - 2) * FUSE_BLOCK;
                            k.apply_block(&mut lo[a0..a0 + len], &hi[..len]);
                            sp -= 1;
                        }
                        Instr::Where => {
                            // c = select(c, a, b): split below `a` so the
                            // `c` row (third from top) borrows mutably,
                            // disjoint from the read-only a/b rows.
                            let (lo, hi) = regs.split_at_mut((sp - 2) * FUSE_BLOCK);
                            let c0 = (sp - 3) * FUSE_BLOCK;
                            let crow = &mut lo[c0..c0 + len];
                            let arow = &hi[..len];
                            let brow = &hi[FUSE_BLOCK..FUSE_BLOCK + len];
                            crate::runtime::simd::select_ip(crow, arow, brow);
                            sp -= 2;
                        }
                    }
                }
                debug_assert_eq!(sp, 1);
                for (o, &v) in out[pos..pos + len].iter_mut().zip(regs[..len].iter()) {
                    o.write(v);
                }
                pos += len;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Evaluate into an initialized buffer for test convenience.
    fn run(p: &Program, ins: &[&[f32]], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        let view = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut MaybeUninit<f32>, n)
        };
        p.eval(ins, view);
        out
    }

    #[test]
    fn tape_computes_relu_of_fma() {
        // relu(a * b + a)
        let p = Program::compile(
            vec![
                Instr::Load(0),
                Instr::Load(1),
                Instr::Bin(BinaryKind::Mul),
                Instr::Load(0),
                Instr::Bin(BinaryKind::Add),
                Instr::Un(UnaryKind::Relu),
            ],
            2,
        );
        assert_eq!(p.n_ops, 3);
        let a = [1.0f32, -2.0, 3.0];
        let b = [4.0f32, 5.0, -6.0];
        let got = run(&p, &[&a, &b], 3);
        for i in 0..3 {
            assert_eq!(got[i], (a[i] * b[i] + a[i]).max(0.0));
        }
    }

    #[test]
    fn blocks_larger_than_fuse_block() {
        let n = FUSE_BLOCK * 2 + 17;
        let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 100.0).collect();
        let p = Program::compile(
            vec![Instr::Load(0), Instr::Un(UnaryKind::MulScalar(2.0))],
            1,
        );
        let got = run(&p, &[&a], n);
        for i in 0..n {
            assert_eq!(got[i], a[i] * 2.0, "i={i}");
        }
    }

    #[test]
    fn where_folds_three_stack_rows() {
        // select(c, a*2, b) — checks operand order (c third from top).
        let p = Program::compile(
            vec![
                Instr::Load(0),
                Instr::Load(1),
                Instr::Un(UnaryKind::MulScalar(2.0)),
                Instr::Load(2),
                Instr::Where,
            ],
            3,
        );
        assert_eq!(p.n_ops, 2);
        assert_eq!(p.stack_depth, 3);
        let c = [1.0f32, 0.0, -2.0];
        let a = [10.0f32, 20.0, 30.0];
        let b = [-1.0f32, -2.0, -3.0];
        let got = run(&p, &[&c, &a, &b], 3);
        assert_eq!(got, vec![20.0, -2.0, 60.0]);
    }

    #[test]
    fn sub_and_div_are_order_sensitive_correct() {
        // (a - b) / b — checks Bin operand order (a below b on the stack).
        let p = Program::compile(
            vec![
                Instr::Load(0),
                Instr::Load(1),
                Instr::Bin(BinaryKind::Sub),
                Instr::Load(1),
                Instr::Bin(BinaryKind::Div),
            ],
            2,
        );
        let got = run(&p, &[&[9.0f32], &[2.0f32]], 1);
        assert_eq!(got[0], (9.0 - 2.0) / 2.0);
    }
}
