//! Lazy expression graphs with single-pass kernel fusion (LoopStack-style
//! fusion over the unified execution layer).
//!
//! Eagerly, a chain like `relu(a*b + c)` runs three kernels and writes
//! two full intermediate tensors — at large sizes the chain is memory-
//! bandwidth-bound, not compute-bound. [`Tensor::lazy`] instead records a
//! small expression DAG of [`LazyTensor`] handles; [`LazyTensor::eval`]
//! partitions the DAG into fusable regions and dispatches **each region
//! as one composed kernel** through `ops::exec::fused_op` /
//! `fused_reduce`: one pooled output allocation, one pass over memory,
//! intermediates living in L1 register blocks.
//!
//! ```
//! use minitensor::tensor::Tensor;
//! let a = Tensor::arange(0.0, 6.0);
//! let b = Tensor::arange(6.0, 12.0);
//! let y = a.lazy().mul(&b.lazy()).unwrap()   // record …
//!     .add(&a.lazy()).unwrap()
//!     .relu()
//!     .eval().unwrap();                       // … fuse + dispatch once
//! assert_eq!(y.to_vec(), a.mul(&b).unwrap().add(&a).unwrap().relu().to_vec());
//! ```
//!
//! Guarantees (pinned by unit, integration, and property tests):
//!
//! - **Bitwise parity with eager:** `eval()` equals the eager op chain
//!   bit for bit — the fused interpreter applies the *same scalar
//!   functions* in the same per-element order, and reductions fold the
//!   same fixed-partition partials (`exec::REDUCE_CHUNK`) the eager
//!   `sum`/`mean`/`max_all`/`min_all` fold.
//! - **Thread-count invariance:** results are bit-identical at any
//!   `MINITENSOR_NUM_THREADS` (elementwise partitioning never changes
//!   per-element arithmetic; reductions use the fixed partition).
//! - **Sharing:** a node consumed more than once is materialized once
//!   and reused, never recomputed per consumer.
//! - **Autograd:** `Var::fused` runs a fused forward and replays the
//!   region's VJP on backward (`grad::vjp`), so fused forwards remain
//!   differentiable.
//!
//! Opting out is just not calling `lazy()` — eager ops are untouched —
//! or calling [`LazyTensor::eval_eager`], which replays the recorded DAG
//! through the eager kernels (the reference path the tests compare
//! against).

pub(crate) mod fuse;
pub(crate) mod grad;
pub(crate) mod kernel;
pub(crate) mod node;

use crate::dtype::DType;
use crate::error::Result;
use crate::shape::Shape;
use crate::tensor::Tensor;

use node::{BinaryKind, Node, NodeRef, ReduceOp, UnaryKind};

/// Handle to one node of a recorded lazy expression DAG. Cloning is
/// cheap (shares the node); all ops record new nodes without running any
/// kernels until [`LazyTensor::eval`].
#[derive(Clone)]
pub struct LazyTensor {
    node: NodeRef,
}

impl LazyTensor {
    pub(crate) fn from_node(node: NodeRef) -> LazyTensor {
        LazyTensor { node }
    }

    pub(crate) fn node(&self) -> &NodeRef {
        &self.node
    }

    pub(crate) fn node_id(&self) -> usize {
        self.node.id
    }

    /// Inferred result shape.
    pub fn shape(&self) -> &Shape {
        &self.node.shape
    }

    /// Inferred dimension sizes.
    pub fn dims(&self) -> &[usize] {
        self.node.shape.dims()
    }

    /// Inferred element count.
    pub fn numel(&self) -> usize {
        self.node.shape.numel()
    }

    /// Inferred result dtype (same promotion rules as the eager ops).
    pub fn dtype(&self) -> DType {
        self.node.dtype
    }

    /// Name of the op this handle records ("leaf" for inputs).
    pub fn op_name(&self) -> &'static str {
        self.node.op_name()
    }

    /// Number of nodes in the recorded DAG reachable from this handle.
    pub fn node_count(&self) -> usize {
        fuse::node_count(&self.node)
    }

    /// The *ideal* number of fused kernels [`LazyTensor::eval`] would
    /// dispatch for this DAG (leaves are free; shared nodes add one
    /// region each). Regions exceeding the per-kernel input or
    /// stack-depth caps degrade to per-op dispatch at eval time, which
    /// this estimate does not model — for exact counts, diff
    /// [`crate::runtime::stats::snapshot`] around an `eval()`.
    pub fn region_count(&self) -> usize {
        fuse::region_count(&self.node)
    }

    // -- recording: binary elementwise (broadcasting) --------------------

    fn binary(&self, k: BinaryKind, other: &LazyTensor) -> Result<LazyTensor> {
        Ok(LazyTensor::from_node(Node::binary(
            k,
            &self.node,
            &other.node,
        )?))
    }

    /// Record elementwise addition with broadcasting.
    pub fn add(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Add, other)
    }

    /// Record elementwise subtraction with broadcasting.
    pub fn sub(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Sub, other)
    }

    /// Record the elementwise (Hadamard) product with broadcasting.
    pub fn mul(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Mul, other)
    }

    /// Record elementwise division with broadcasting.
    pub fn div(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Div, other)
    }

    /// Record the elementwise maximum.
    pub fn maximum(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Max, other)
    }

    /// Record the elementwise minimum.
    pub fn minimum(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Min, other)
    }

    // -- recording: unary elementwise ------------------------------------

    fn unary(&self, k: UnaryKind) -> LazyTensor {
        LazyTensor::from_node(Node::unary(k, &self.node))
    }

    /// Record elementwise negation.
    pub fn neg(&self) -> LazyTensor {
        self.unary(UnaryKind::Neg)
    }

    /// Record ReLU.
    pub fn relu(&self) -> LazyTensor {
        self.unary(UnaryKind::Relu)
    }

    /// Record the elementwise exponential.
    pub fn exp(&self) -> LazyTensor {
        self.unary(UnaryKind::Exp)
    }

    /// Record the elementwise natural log.
    pub fn log(&self) -> LazyTensor {
        self.unary(UnaryKind::Log)
    }

    /// Record the elementwise square root.
    pub fn sqrt(&self) -> LazyTensor {
        self.unary(UnaryKind::Sqrt)
    }

    /// Record the elementwise square.
    pub fn square(&self) -> LazyTensor {
        self.unary(UnaryKind::Square)
    }

    /// Record the elementwise absolute value.
    pub fn abs(&self) -> LazyTensor {
        self.unary(UnaryKind::Abs)
    }

    /// Record the logistic sigmoid.
    pub fn sigmoid(&self) -> LazyTensor {
        self.unary(UnaryKind::Sigmoid)
    }

    /// Record the hyperbolic tangent.
    pub fn tanh(&self) -> LazyTensor {
        self.unary(UnaryKind::Tanh)
    }

    /// Record GELU (tanh approximation, like the eager op).
    pub fn gelu(&self) -> LazyTensor {
        self.unary(UnaryKind::Gelu)
    }

    /// Record adding a scalar constant.
    pub fn add_scalar(&self, s: f32) -> LazyTensor {
        self.unary(UnaryKind::AddScalar(s))
    }

    /// Record multiplying by a scalar constant.
    pub fn mul_scalar(&self, s: f32) -> LazyTensor {
        self.unary(UnaryKind::MulScalar(s))
    }

    // -- recording: full reductions --------------------------------------

    /// Record the sum of all elements (fused as an order-stable epilogue
    /// — no intermediate tensor, bit-identical at any thread count).
    pub fn sum(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Sum, &self.node))
    }

    /// Record the mean of all elements.
    pub fn mean(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Mean, &self.node))
    }

    /// Record the maximum of all elements.
    pub fn max_all(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Max, &self.node))
    }

    /// Record the minimum of all elements.
    pub fn min_all(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Min, &self.node))
    }

    // -- evaluation ------------------------------------------------------

    /// Evaluate the recorded DAG with single-pass kernel fusion: one
    /// exec-layer dispatch and one pooled output allocation per fused
    /// region. Bitwise-equal to [`LazyTensor::eval_eager`].
    pub fn eval(&self) -> Result<Tensor> {
        fuse::eval(&self.node)
    }

    /// Reference evaluation: replay every recorded op through the eager
    /// kernels (one dispatch and one intermediate per op). This is the
    /// opt-out and the yardstick the fusion tests compare against.
    pub fn eval_eager(&self) -> Result<Tensor> {
        fuse::eval_eager(&self.node)
    }
}

impl std::fmt::Debug for LazyTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LazyTensor(op={}, shape={}, dtype={}, nodes={})",
            self.op_name(),
            self.shape(),
            self.dtype(),
            self.node_count()
        )
    }
}

impl Tensor {
    /// Enter the lazy expression graph: wrap this tensor as a leaf. Ops
    /// on the returned handle record instead of executing; call
    /// [`LazyTensor::eval`] to fuse and run. The tensor is captured by
    /// cheap storage-sharing clone — no copy.
    pub fn lazy(&self) -> LazyTensor {
        LazyTensor::from_node(Node::leaf(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stats;

    #[test]
    fn record_then_eval_matches_eager_chain() {
        let a = Tensor::arange(-8.0, 8.0);
        let b = Tensor::arange(0.0, 16.0);
        let y = a
            .lazy()
            .mul(&b.lazy())
            .unwrap()
            .add(&a.lazy())
            .unwrap()
            .relu()
            .eval()
            .unwrap();
        let want = a.mul(&b).unwrap().add(&a).unwrap().relu();
        let (yv, wv) = (y.to_vec(), want.to_vec());
        for i in 0..yv.len() {
            assert_eq!(yv[i].to_bits(), wv[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn three_op_chain_is_one_dispatch_one_alloc() {
        let a = Tensor::arange(0.0, 256.0);
        let b = Tensor::arange(256.0, 512.0);
        let c = Tensor::arange(-128.0, 128.0);
        let expr = a
            .lazy()
            .mul(&b.lazy())
            .unwrap()
            .add(&c.lazy())
            .unwrap()
            .relu();
        let before = stats::snapshot();
        let y = expr.eval().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 1, "one exec-layer dispatch");
        assert_eq!(d.output_allocs, 1, "one output allocation");
        assert_eq!(d.fused_kernels, 1);
        assert_eq!(d.fused_ops, 3);
        // And the eager chain costs 3 dispatches / 3 allocations.
        let before = stats::snapshot();
        let want = a.mul(&b).unwrap().add(&c).unwrap().relu();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 3);
        assert_eq!(d.output_allocs, 3);
        assert_eq!(d.fused_kernels, 0);
        assert_eq!(y.to_vec(), want.to_vec());
    }

    #[test]
    fn fused_sum_epilogue_is_one_dispatch_zero_allocs() {
        let a = Tensor::arange(0.0, 100_000.0).mul_scalar(1e-4);
        let expr = a.lazy().square().add_scalar(1.0).sum();
        let before = stats::snapshot();
        let y = expr.eval().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 1, "reduce fused into the region");
        assert_eq!(d.output_allocs, 0, "scalar output needs no pool buffer");
        let want = a.square().add_scalar(1.0).sum();
        assert_eq!(
            y.item().unwrap().to_bits(),
            want.item().unwrap().to_bits(),
            "bitwise-equal to the eager reduction"
        );
    }

    #[test]
    fn dtype_propagates_like_eager() {
        let i = Tensor::from_vec_i32(vec![1, -2, 3], &[3]).unwrap();
        let y = i.lazy().neg().eval().unwrap();
        assert_eq!(y.dtype(), DType::I32);
        let f = Tensor::from_vec(vec![0.5, 0.5, 0.5], &[3]).unwrap();
        let p = i.lazy().add(&f.lazy()).unwrap();
        assert_eq!(p.dtype(), DType::F32);
        assert_eq!(p.eval().unwrap().dtype(), DType::F32);
        assert_eq!(i.lazy().sum().eval().unwrap().dtype(), DType::F32);
    }

    #[test]
    fn record_time_shape_errors_match_eager() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4]);
        assert!(a.lazy().add(&b.lazy()).is_err());
        assert!(a.add(&b).is_err());
    }

    #[test]
    fn eval_of_leaf_is_free() {
        let a = Tensor::arange(0.0, 10.0);
        let before = stats::snapshot();
        let y = a.lazy().eval().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 0);
        assert_eq!(d.output_allocs, 0);
        assert!(y.shares_storage(&a), "leaf eval shares storage");
    }

    #[test]
    fn debug_and_introspection() {
        let a = Tensor::zeros(&[4]);
        let e = a.lazy().relu().add_scalar(1.0);
        assert_eq!(e.op_name(), "add_scalar");
        assert_eq!(e.dims(), &[4]);
        assert_eq!(e.numel(), 4);
        assert_eq!(e.node_count(), 3);
        assert_eq!(e.region_count(), 1);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("add_scalar"), "{dbg}");
    }
}
