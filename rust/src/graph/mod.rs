//! Lazy expression graphs with single-pass kernel fusion (LoopStack-style
//! fusion over the unified execution layer).
//!
//! Eagerly, a chain like `relu(a*b + c)` runs three kernels and writes
//! two full intermediate tensors — at large sizes the chain is memory-
//! bandwidth-bound, not compute-bound. [`Tensor::lazy`] instead records a
//! small expression DAG of [`LazyTensor`] handles; [`LazyTensor::eval`]
//! partitions the DAG into fusable regions and dispatches **each region
//! as one composed kernel** through `ops::exec::fused_op` /
//! `fused_reduce`: one pooled output allocation, one pass over memory,
//! intermediates living in L1 register blocks.
//!
//! ```
//! use minitensor::tensor::Tensor;
//! let a = Tensor::arange(0.0, 6.0);
//! let b = Tensor::arange(6.0, 12.0);
//! let y = a.lazy().mul(&b.lazy()).unwrap()   // record …
//!     .add(&a.lazy()).unwrap()
//!     .relu()
//!     .eval().unwrap();                       // … fuse + dispatch once
//! assert_eq!(y.to_vec(), a.mul(&b).unwrap().add(&a).unwrap().relu().to_vec());
//! ```
//!
//! Guarantees (pinned by unit, integration, and property tests):
//!
//! - **Bitwise parity with eager:** `eval()` equals the eager op chain
//!   bit for bit — the fused interpreter applies the *same scalar
//!   functions* in the same per-element order, full reductions fold the
//!   same fixed-partition partials (`exec::REDUCE_CHUNK`) the eager
//!   `sum`/`mean`/`max_all`/`min_all` fold, and last-axis reductions
//!   apply the same per-row slice kernels the eager `reduce_axis(-1)`
//!   fast path applies.
//! - **Thread-count invariance:** results are bit-identical at any
//!   `MINITENSOR_NUM_THREADS` (elementwise partitioning never changes
//!   per-element arithmetic; reductions use the fixed partition or are
//!   row-local).
//! - **Sharing:** a node consumed more than once is materialized once
//!   and reused, never recomputed per consumer.
//! - **Autograd:** `Var::fused` runs a fused forward and replays the
//!   region's VJP on backward (`grad::vjp`), so fused forwards remain
//!   differentiable.
//!
//! Repeated evaluation is cheap: every `eval()` goes through a bounded
//! per-thread **program cache** ([`plan`]) keyed by the DAG's structural
//! signature, so a serving loop that rebuilds the same expression every
//! request compiles it once and re-dispatches the cached instruction
//! tapes (`MINITENSOR_PROGRAM_CACHE` sets the capacity; hits and misses
//! are counted in [`crate::runtime::stats`]).
//!
//! Fusion is also the **default `nn::` hot path**: `Sequential` fuses
//! Dense→activation chains and the losses build fused expressions
//! internally (see [`nn_fusion_enabled`]; `MINITENSOR_NO_FUSION=1` is
//! the escape hatch). For hand-written tensor code, opting out is just
//! not calling `lazy()` — eager ops are untouched — or calling
//! [`LazyTensor::eval_eager`], which replays the recorded DAG through
//! the eager kernels (the reference path the tests compare against).

pub(crate) mod fuse;
pub(crate) mod grad;
pub(crate) mod kernel;
pub(crate) mod node;
pub(crate) mod plan;

pub use plan::{
    program_cache_capacity, program_cache_clear, program_cache_len, set_program_cache_capacity,
    DEFAULT_CACHE_CAP,
};

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::dtype::DType;
use crate::error::Result;
use crate::shape::Shape;
use crate::tensor::Tensor;

use node::{BinaryKind, Node, NodeRef, ReduceOp, UnaryKind};

/// `nn::` fusion-by-default switch; 0 = unresolved (read the
/// `MINITENSOR_NO_FUSION` env var on first use), 1 = on, 2 = off.
static NN_FUSION: AtomicUsize = AtomicUsize::new(0);

/// Whether `nn::` forwards (Dense→activation chains, the fused losses)
/// build lazy expressions internally. **On by default**; opt out with
/// `MINITENSOR_NO_FUSION=1` (or `true`) or [`set_nn_fusion_enabled`].
/// Results are bitwise-identical either way — the switch only trades
/// fused dispatches for the eager op-per-kernel path.
pub fn nn_fusion_enabled() -> bool {
    match NN_FUSION.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let off = std::env::var("MINITENSOR_NO_FUSION")
                .map(|v| {
                    let v = v.trim();
                    v == "1" || v.eq_ignore_ascii_case("true")
                })
                .unwrap_or(false);
            let resolved = if off { 2 } else { 1 };
            // compare_exchange, not store: a concurrent setter must not
            // be clobbered by this lazy default resolution.
            match NN_FUSION.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => !off,
                Err(current) => current == 1,
            }
        }
    }
}

/// Override the `nn::` fusion default for the whole process (see
/// [`nn_fusion_enabled`]).
pub fn set_nn_fusion_enabled(on: bool) {
    NN_FUSION.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// The fusion switch is process-global: unit tests that flip it
/// serialize here so a toggle in one test thread can't be observed
/// mid-assertion by another (results are bitwise-identical either way,
/// so only tests that *assert on the flag or on dispatch counts* need
/// the lock).
#[cfg(test)]
pub(crate) fn nn_fusion_test_lock() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::{Mutex, OnceLock};
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Handle to one node of a recorded lazy expression DAG. Cloning is
/// cheap (shares the node); all ops record new nodes without running any
/// kernels until [`LazyTensor::eval`].
#[derive(Clone)]
pub struct LazyTensor {
    node: NodeRef,
}

impl LazyTensor {
    pub(crate) fn from_node(node: NodeRef) -> LazyTensor {
        LazyTensor { node }
    }

    pub(crate) fn node(&self) -> &NodeRef {
        &self.node
    }

    pub(crate) fn node_id(&self) -> usize {
        self.node.id
    }

    /// Inferred result shape.
    pub fn shape(&self) -> &Shape {
        &self.node.shape
    }

    /// Inferred dimension sizes.
    pub fn dims(&self) -> &[usize] {
        self.node.shape.dims()
    }

    /// Inferred element count.
    pub fn numel(&self) -> usize {
        self.node.shape.numel()
    }

    /// Inferred result dtype (same promotion rules as the eager ops).
    pub fn dtype(&self) -> DType {
        self.node.dtype
    }

    /// Name of the op this handle records ("leaf" for inputs).
    pub fn op_name(&self) -> &'static str {
        self.node.op_name()
    }

    /// Number of nodes in the recorded DAG reachable from this handle.
    pub fn node_count(&self) -> usize {
        fuse::node_count(&self.node)
    }

    /// The *ideal* number of fused kernels [`LazyTensor::eval`] would
    /// dispatch for this DAG (leaves are free; shared nodes add one
    /// region each). Regions exceeding the per-kernel input or
    /// stack-depth caps degrade to per-op dispatch at eval time, which
    /// this estimate does not model — for exact counts, diff
    /// [`crate::runtime::stats::snapshot`] around an `eval()`.
    pub fn region_count(&self) -> usize {
        fuse::region_count(&self.node)
    }

    // -- recording: binary elementwise (broadcasting) --------------------

    fn binary(&self, k: BinaryKind, other: &LazyTensor) -> Result<LazyTensor> {
        Ok(LazyTensor::from_node(Node::binary(
            k,
            &self.node,
            &other.node,
        )?))
    }

    /// Record elementwise addition with broadcasting.
    pub fn add(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Add, other)
    }

    /// Record elementwise subtraction with broadcasting.
    pub fn sub(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Sub, other)
    }

    /// Record the elementwise (Hadamard) product with broadcasting.
    pub fn mul(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Mul, other)
    }

    /// Record elementwise division with broadcasting.
    pub fn div(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Div, other)
    }

    /// Record the elementwise maximum.
    pub fn maximum(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Max, other)
    }

    /// Record the elementwise minimum.
    pub fn minimum(&self, other: &LazyTensor) -> Result<LazyTensor> {
        self.binary(BinaryKind::Min, other)
    }

    // -- recording: unary elementwise ------------------------------------

    fn unary(&self, k: UnaryKind) -> LazyTensor {
        LazyTensor::from_node(Node::unary(k, &self.node))
    }

    /// Record elementwise negation.
    pub fn neg(&self) -> LazyTensor {
        self.unary(UnaryKind::Neg)
    }

    /// Record ReLU.
    pub fn relu(&self) -> LazyTensor {
        self.unary(UnaryKind::Relu)
    }

    /// Record the elementwise exponential.
    pub fn exp(&self) -> LazyTensor {
        self.unary(UnaryKind::Exp)
    }

    /// Record the elementwise natural log.
    pub fn log(&self) -> LazyTensor {
        self.unary(UnaryKind::Log)
    }

    /// Record the elementwise square root.
    pub fn sqrt(&self) -> LazyTensor {
        self.unary(UnaryKind::Sqrt)
    }

    /// Record the elementwise square.
    pub fn square(&self) -> LazyTensor {
        self.unary(UnaryKind::Square)
    }

    /// Record the elementwise absolute value.
    pub fn abs(&self) -> LazyTensor {
        self.unary(UnaryKind::Abs)
    }

    /// Record the logistic sigmoid.
    pub fn sigmoid(&self) -> LazyTensor {
        self.unary(UnaryKind::Sigmoid)
    }

    /// Record the hyperbolic tangent.
    pub fn tanh(&self) -> LazyTensor {
        self.unary(UnaryKind::Tanh)
    }

    /// Record GELU (tanh approximation, like the eager op).
    pub fn gelu(&self) -> LazyTensor {
        self.unary(UnaryKind::Gelu)
    }

    /// Record adding a scalar constant.
    pub fn add_scalar(&self, s: f32) -> LazyTensor {
        self.unary(UnaryKind::AddScalar(s))
    }

    /// Record multiplying by a scalar constant.
    pub fn mul_scalar(&self, s: f32) -> LazyTensor {
        self.unary(UnaryKind::MulScalar(s))
    }

    /// Record clamping into `[lo, hi]` (the bounds ride along as tape
    /// immediates — no mask tensors).
    pub fn clamp(&self, lo: f32, hi: f32) -> LazyTensor {
        self.unary(UnaryKind::Clamp(lo, hi))
    }

    /// Record leaky ReLU with negative-side slope `alpha` (an immediate).
    pub fn leaky_relu(&self, alpha: f32) -> LazyTensor {
        self.unary(UnaryKind::LeakyRelu(alpha))
    }

    // -- recording: ternary select ----------------------------------------

    /// Record the ternary select `cond != 0 ? self : other`
    /// (broadcasting all three) — one `where_cond` instruction in the
    /// fused tape, mirroring the eager [`Tensor::where_cond`] signature
    /// and matching it bit for bit.
    pub fn where_cond(&self, cond: &LazyTensor, other: &LazyTensor) -> Result<LazyTensor> {
        Ok(LazyTensor::from_node(Node::where_cond(
            &cond.node,
            &self.node,
            &other.node,
        )?))
    }

    // -- recording: full reductions --------------------------------------

    /// Record the sum of all elements (fused as an order-stable epilogue
    /// — no intermediate tensor, bit-identical at any thread count).
    pub fn sum(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Sum, &self.node))
    }

    /// Record the mean of all elements.
    pub fn mean(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Mean, &self.node))
    }

    /// Record the maximum of all elements.
    pub fn max_all(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Max, &self.node))
    }

    /// Record the minimum of all elements.
    pub fn min_all(&self) -> LazyTensor {
        LazyTensor::from_node(Node::reduce(ReduceOp::Min, &self.node))
    }

    // -- recording: last-axis reductions ----------------------------------

    fn reduce_axis(&self, k: ReduceOp, axis: isize, keepdim: bool) -> Result<LazyTensor> {
        let ax = self.node.shape.normalize_axis(axis)?;
        let rank = self.node.shape.dims().len();
        if ax + 1 != rank {
            return Err(crate::error::Error::msg(format!(
                "lazy {}: only the last axis fuses (got axis {ax} of rank {rank})",
                k.axis_name()
            )));
        }
        Ok(LazyTensor::from_node(Node::reduce_axis(
            k, &self.node, keepdim,
        )?))
    }

    /// Record a sum along the **last axis**: a private elementwise
    /// pipeline ending here fuses into one per-row dispatch with one
    /// pooled output, bitwise-equal to the eager `sum_axis(-1, keepdim)`
    /// (shared pipeline nodes still materialize once, as always).
    pub fn sum_axis(&self, axis: isize, keepdim: bool) -> Result<LazyTensor> {
        self.reduce_axis(ReduceOp::Sum, axis, keepdim)
    }

    /// Record a mean along the **last axis** (see [`LazyTensor::sum_axis`]).
    pub fn mean_axis(&self, axis: isize, keepdim: bool) -> Result<LazyTensor> {
        self.reduce_axis(ReduceOp::Mean, axis, keepdim)
    }

    /// Record a maximum along the **last axis** (see [`LazyTensor::sum_axis`]).
    pub fn max_axis(&self, axis: isize, keepdim: bool) -> Result<LazyTensor> {
        self.reduce_axis(ReduceOp::Max, axis, keepdim)
    }

    /// Record a minimum along the **last axis** (see [`LazyTensor::sum_axis`]).
    pub fn min_axis(&self, axis: isize, keepdim: bool) -> Result<LazyTensor> {
        self.reduce_axis(ReduceOp::Min, axis, keepdim)
    }

    // -- evaluation ------------------------------------------------------

    /// Evaluate the recorded DAG with single-pass kernel fusion: one
    /// exec-layer dispatch and one pooled output allocation per fused
    /// region. Bitwise-equal to [`LazyTensor::eval_eager`].
    pub fn eval(&self) -> Result<Tensor> {
        fuse::eval(&self.node)
    }

    /// Reference evaluation: replay every recorded op through the eager
    /// kernels (one dispatch and one intermediate per op). This is the
    /// opt-out and the yardstick the fusion tests compare against.
    pub fn eval_eager(&self) -> Result<Tensor> {
        fuse::eval_eager(&self.node)
    }
}

impl std::fmt::Debug for LazyTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LazyTensor(op={}, shape={}, dtype={}, nodes={})",
            self.op_name(),
            self.shape(),
            self.dtype(),
            self.node_count()
        )
    }
}

impl Tensor {
    /// Enter the lazy expression graph: wrap this tensor as a leaf. Ops
    /// on the returned handle record instead of executing; call
    /// [`LazyTensor::eval`] to fuse and run. The tensor is captured by
    /// cheap storage-sharing clone — no copy.
    pub fn lazy(&self) -> LazyTensor {
        LazyTensor::from_node(Node::leaf(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stats;

    #[test]
    fn record_then_eval_matches_eager_chain() {
        let a = Tensor::arange(-8.0, 8.0);
        let b = Tensor::arange(0.0, 16.0);
        let y = a
            .lazy()
            .mul(&b.lazy())
            .unwrap()
            .add(&a.lazy())
            .unwrap()
            .relu()
            .eval()
            .unwrap();
        let want = a.mul(&b).unwrap().add(&a).unwrap().relu();
        let (yv, wv) = (y.to_vec(), want.to_vec());
        for i in 0..yv.len() {
            assert_eq!(yv[i].to_bits(), wv[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn three_op_chain_is_one_dispatch_one_alloc() {
        let a = Tensor::arange(0.0, 256.0);
        let b = Tensor::arange(256.0, 512.0);
        let c = Tensor::arange(-128.0, 128.0);
        let expr = a
            .lazy()
            .mul(&b.lazy())
            .unwrap()
            .add(&c.lazy())
            .unwrap()
            .relu();
        let before = stats::snapshot();
        let y = expr.eval().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 1, "one exec-layer dispatch");
        assert_eq!(d.output_allocs, 1, "one output allocation");
        assert_eq!(d.fused_kernels, 1);
        assert_eq!(d.fused_ops, 3);
        // And the eager chain costs 3 dispatches / 3 allocations.
        let before = stats::snapshot();
        let want = a.mul(&b).unwrap().add(&c).unwrap().relu();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 3);
        assert_eq!(d.output_allocs, 3);
        assert_eq!(d.fused_kernels, 0);
        assert_eq!(y.to_vec(), want.to_vec());
    }

    #[test]
    fn fused_sum_epilogue_is_one_dispatch_zero_allocs() {
        let a = Tensor::arange(0.0, 100_000.0).mul_scalar(1e-4);
        let expr = a.lazy().square().add_scalar(1.0).sum();
        let before = stats::snapshot();
        let y = expr.eval().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 1, "reduce fused into the region");
        assert_eq!(d.output_allocs, 0, "scalar output needs no pool buffer");
        let want = a.square().add_scalar(1.0).sum();
        assert_eq!(
            y.item().unwrap().to_bits(),
            want.item().unwrap().to_bits(),
            "bitwise-equal to the eager reduction"
        );
    }

    #[test]
    fn dtype_propagates_like_eager() {
        let i = Tensor::from_vec_i32(vec![1, -2, 3], &[3]).unwrap();
        let y = i.lazy().neg().eval().unwrap();
        assert_eq!(y.dtype(), DType::I32);
        let f = Tensor::from_vec(vec![0.5, 0.5, 0.5], &[3]).unwrap();
        let p = i.lazy().add(&f.lazy()).unwrap();
        assert_eq!(p.dtype(), DType::F32);
        assert_eq!(p.eval().unwrap().dtype(), DType::F32);
        assert_eq!(i.lazy().sum().eval().unwrap().dtype(), DType::F32);
    }

    #[test]
    fn record_time_shape_errors_match_eager() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4]);
        assert!(a.lazy().add(&b.lazy()).is_err());
        assert!(a.add(&b).is_err());
    }

    #[test]
    fn eval_of_leaf_is_free() {
        let a = Tensor::arange(0.0, 10.0);
        let before = stats::snapshot();
        let y = a.lazy().eval().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 0);
        assert_eq!(d.output_allocs, 0);
        assert!(y.shares_storage(&a), "leaf eval shares storage");
    }

    #[test]
    fn lazy_row_pipeline_matches_eager_chain() {
        // Softmax-shaped pipeline over lazy axis reduces: bitwise-equal
        // to the same eager op chain.
        let t = Tensor::arange(0.0, 24.0).mul_scalar(0.3).reshape(&[4, 6]).unwrap();
        let l = t.lazy();
        let m = l.max_axis(-1, true).unwrap();
        let e = l.sub(&m).unwrap().exp();
        let s = e.sum_axis(-1, true).unwrap();
        let p = e.div(&s).unwrap().eval().unwrap();
        let em = t.max_axis(-1, true).unwrap();
        let ee = t.sub(&em).unwrap().exp();
        let es = ee.sum_axis(-1, true).unwrap();
        let want = ee.div(&es).unwrap();
        let (pv, wv) = (p.to_vec(), want.to_vec());
        for i in 0..pv.len() {
            assert_eq!(pv[i].to_bits(), wv[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn lazy_axis_reduce_validates_axis() {
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.lazy().sum_axis(0, false).is_err(), "only last axis fuses");
        assert!(t.lazy().sum_axis(-1, false).is_ok());
        assert!(t.lazy().sum_axis(1, true).is_ok());
        assert!(t.lazy().sum_axis(5, false).is_err());
    }

    #[test]
    fn lazy_clamp_leaky_relu_where_match_eager() {
        let a = Tensor::arange(-6.0, 6.0);
        let b = Tensor::arange(0.0, 12.0);
        let cond = a.gt(&Tensor::zeros(&[12])).unwrap();
        let fused = a
            .lazy()
            .clamp(-2.5, 3.5)
            .leaky_relu(0.1)
            .where_cond(&cond.lazy(), &b.lazy())
            .unwrap()
            .eval()
            .unwrap();
        let want = a
            .clamp(-2.5, 3.5)
            .leaky_relu(0.1)
            .where_cond(&cond, &b)
            .unwrap();
        let (f, w) = (fused.to_vec(), want.to_vec());
        for i in 0..f.len() {
            assert_eq!(f[i].to_bits(), w[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn nn_fusion_toggle_round_trips() {
        let _guard = nn_fusion_test_lock();
        let initial = nn_fusion_enabled();
        set_nn_fusion_enabled(false);
        assert!(!nn_fusion_enabled());
        set_nn_fusion_enabled(true);
        assert!(nn_fusion_enabled());
        set_nn_fusion_enabled(initial);
    }

    #[test]
    fn debug_and_introspection() {
        let a = Tensor::zeros(&[4]);
        let e = a.lazy().relu().add_scalar(1.0);
        assert_eq!(e.op_name(), "add_scalar");
        assert_eq!(e.dims(), &[4]);
        assert_eq!(e.numel(), 4);
        assert_eq!(e.node_count(), 3);
        assert_eq!(e.region_count(), 1);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("add_scalar"), "{dbg}");
    }
}
