//! Expression-graph IR: the node kinds a [`super::LazyTensor`] records.
//!
//! Every op kind carries three synchronized definitions — the scalar
//! semantics the fused interpreter applies (`apply_block`, with a
//! test-only per-element `apply` that pins each arm against the eager
//! method bit for bit), the eager replay (`eval_eager`, literally the
//! `Tensor` method the eager engine runs), and the VJP used by
//! `Var::fused`. Both `apply_block` and the eager kernels dispatch the
//! *same* [`crate::runtime::simd`] op kinds (8-lane blocks, scalar-twin
//! tails), which is what makes fused evaluation bitwise-equal to the
//! eager op chain: identical f32 operations in identical per-element
//! order, just without the intermediate materializations.

use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::dtype::DType;
use crate::error::{Error, Result};
use crate::ops::kernels;
use crate::ops::unary::gelu_grad_scalar;
use crate::runtime::simd;
use crate::shape::Shape;
use crate::tensor::Tensor;

/// Unary elementwise ops (including scalar-parameterized ones).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum UnaryKind {
    Neg,
    Relu,
    Exp,
    Log,
    Sqrt,
    Square,
    Abs,
    Sigmoid,
    Tanh,
    Gelu,
    AddScalar(f32),
    MulScalar(f32),
    /// Clamp into `[lo, hi]` (two tape immediates).
    Clamp(f32, f32),
    /// Leaky ReLU with the negative-side slope as an immediate.
    LeakyRelu(f32),
}

impl UnaryKind {
    /// The 8-lane kernel kind for this op, when one exists. `Log` is the
    /// one holdout (libm `ln` has no vector twin here) and keeps a plain
    /// scalar loop.
    fn simd_op(self) -> Option<simd::UnOp> {
        Some(match self {
            UnaryKind::Neg => simd::UnOp::Neg,
            UnaryKind::Relu => simd::UnOp::Relu,
            UnaryKind::Exp => simd::UnOp::Exp,
            UnaryKind::Log => return None,
            UnaryKind::Sqrt => simd::UnOp::Sqrt,
            UnaryKind::Square => simd::UnOp::Square,
            UnaryKind::Abs => simd::UnOp::Abs,
            UnaryKind::Sigmoid => simd::UnOp::Sigmoid,
            UnaryKind::Tanh => simd::UnOp::Tanh,
            UnaryKind::Gelu => simd::UnOp::Gelu,
            UnaryKind::AddScalar(s) => simd::UnOp::AddScalar(s),
            UnaryKind::MulScalar(s) => simd::UnOp::MulScalar(s),
            UnaryKind::Clamp(lo, hi) => simd::UnOp::Clamp(lo, hi),
            UnaryKind::LeakyRelu(a) => simd::UnOp::LeakyRelu(a),
        })
    }

    /// Scalar semantics — by construction the same [`simd::un_s`] twin
    /// the eager funnel's tail/strided paths apply. Test-only: the hot
    /// path is `apply_block`; this is the per-element spec the unit tests
    /// pin both paths against.
    #[cfg(test)]
    pub fn apply(self, v: f32) -> f32 {
        match self.simd_op() {
            Some(op) => simd::un_s(op, v),
            None => v.ln(),
        }
    }

    /// In-place block form: the 8-lane kernel ([`simd::un_ip`]) for the
    /// known kinds — the same block kernel the eager `unary_simd` funnel
    /// runs, so fused tapes and eager chains stay bitwise-equal.
    #[inline]
    pub fn apply_block(self, dst: &mut [f32]) {
        match self.simd_op() {
            Some(op) => simd::un_ip(op, dst),
            None => {
                for v in dst.iter_mut() {
                    *v = v.ln();
                }
            }
        }
    }

    /// Replay through the eager kernel (the bitwise reference path).
    pub fn eval_eager(self, x: &Tensor) -> Tensor {
        match self {
            UnaryKind::Neg => x.neg(),
            UnaryKind::Relu => x.relu(),
            UnaryKind::Exp => x.exp(),
            UnaryKind::Log => x.log(),
            UnaryKind::Sqrt => x.sqrt(),
            UnaryKind::Square => x.square(),
            UnaryKind::Abs => x.abs(),
            UnaryKind::Sigmoid => x.sigmoid(),
            UnaryKind::Tanh => x.tanh(),
            UnaryKind::Gelu => x.gelu(),
            UnaryKind::AddScalar(s) => x.add_scalar(s),
            UnaryKind::MulScalar(s) => x.mul_scalar(s),
            UnaryKind::Clamp(lo, hi) => x.clamp(lo, hi),
            UnaryKind::LeakyRelu(a) => x.leaky_relu(a),
        }
    }

    /// Cotangent w.r.t. `x` given `(x, y, ḡ)` — mirrors the pullbacks in
    /// `autograd::ops` rule for rule.
    pub fn vjp(self, x: &Tensor, y: &Tensor, g: &Tensor) -> Tensor {
        match self {
            UnaryKind::Neg => g.neg(),
            UnaryKind::Relu => g.mul(&x.map(|v| f32::from(v > 0.0))).unwrap(),
            UnaryKind::Exp => g.mul(y).unwrap(),
            UnaryKind::Log => g.div(x).unwrap(),
            UnaryKind::Sqrt => g.div(&y.mul_scalar(2.0)).unwrap(),
            UnaryKind::Square => g.mul(&x.mul_scalar(2.0)).unwrap(),
            UnaryKind::Abs => g
                .mul(&x.map(|v| {
                    if v > 0.0 {
                        1.0
                    } else if v < 0.0 {
                        -1.0
                    } else {
                        0.0
                    }
                }))
                .unwrap(),
            UnaryKind::Sigmoid => {
                let one_minus = y.map(|v| 1.0 - v);
                g.mul(y).unwrap().mul(&one_minus).unwrap()
            }
            UnaryKind::Tanh => g.mul(&y.map(|t| 1.0 - t * t)).unwrap(),
            UnaryKind::Gelu => g.mul(&x.map(gelu_grad_scalar)).unwrap(),
            UnaryKind::AddScalar(_) => g.clone(),
            UnaryKind::MulScalar(s) => g.mul_scalar(s),
            UnaryKind::Clamp(lo, hi) => g
                .mul(&x.map(move |v| f32::from(v > lo && v < hi)))
                .unwrap(),
            UnaryKind::LeakyRelu(a) => g
                .mul(&x.map(move |v| if v > 0.0 { 1.0 } else { a }))
                .unwrap(),
        }
    }

    /// Op name for graph dumps and `Debug`.
    pub fn name(self) -> &'static str {
        match self {
            UnaryKind::Neg => "neg",
            UnaryKind::Relu => "relu",
            UnaryKind::Exp => "exp",
            UnaryKind::Log => "log",
            UnaryKind::Sqrt => "sqrt",
            UnaryKind::Square => "square",
            UnaryKind::Abs => "abs",
            UnaryKind::Sigmoid => "sigmoid",
            UnaryKind::Tanh => "tanh",
            UnaryKind::Gelu => "gelu",
            UnaryKind::AddScalar(_) => "add_scalar",
            UnaryKind::MulScalar(_) => "mul_scalar",
            UnaryKind::Clamp(..) => "clamp",
            UnaryKind::LeakyRelu(_) => "leaky_relu",
        }
    }

    /// Append this kind's structural-signature words (tag + immediate
    /// bits) — part of the program-cache key, so every immediate that
    /// changes the compiled tape must be encoded here.
    pub fn encode_sig(self, sig: &mut Vec<u64>) {
        let (tag, imms): (u64, [Option<f32>; 2]) = match self {
            UnaryKind::Neg => (0, [None, None]),
            UnaryKind::Relu => (1, [None, None]),
            UnaryKind::Exp => (2, [None, None]),
            UnaryKind::Log => (3, [None, None]),
            UnaryKind::Sqrt => (4, [None, None]),
            UnaryKind::Square => (5, [None, None]),
            UnaryKind::Abs => (6, [None, None]),
            UnaryKind::Sigmoid => (7, [None, None]),
            UnaryKind::Tanh => (8, [None, None]),
            UnaryKind::Gelu => (9, [None, None]),
            UnaryKind::AddScalar(s) => (10, [Some(s), None]),
            UnaryKind::MulScalar(s) => (11, [Some(s), None]),
            UnaryKind::Clamp(lo, hi) => (12, [Some(lo), Some(hi)]),
            UnaryKind::LeakyRelu(a) => (13, [Some(a), None]),
        };
        sig.push(tag);
        for imm in imms.into_iter().flatten() {
            sig.push(u64::from(imm.to_bits()));
        }
    }
}

/// Binary elementwise ops (broadcasting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum BinaryKind {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
}

impl BinaryKind {
    /// The 8-lane kernel kind for this op (every binary kind has one).
    fn simd_op(self) -> simd::BinOp {
        match self {
            BinaryKind::Add => simd::BinOp::Add,
            BinaryKind::Sub => simd::BinOp::Sub,
            BinaryKind::Mul => simd::BinOp::Mul,
            BinaryKind::Div => simd::BinOp::Div,
            BinaryKind::Max => simd::BinOp::Max,
            BinaryKind::Min => simd::BinOp::Min,
        }
    }

    /// Scalar semantics — by construction the same [`simd::bin_s`] twin
    /// the eager funnel's tail/strided paths apply (`Max`/`Min` are
    /// [`simd::max_s`]/[`simd::min_s`], what `maxps`/`minps` compute).
    /// Test-only: the hot path is `apply_block`; this is the per-element
    /// spec the unit tests pin both paths against.
    #[cfg(test)]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        simd::bin_s(self.simd_op(), a, b)
    }

    /// In-place block form: `dst[i] = apply(dst[i], rhs[i])` through the
    /// 8-lane kernel ([`simd::bin_ip`]) — the same block kernel the eager
    /// `binary_simd` funnel runs.
    #[inline]
    pub fn apply_block(self, dst: &mut [f32], rhs: &[f32]) {
        debug_assert_eq!(dst.len(), rhs.len());
        simd::bin_ip(self.simd_op(), dst, rhs);
    }

    /// Replay through the eager kernel (the bitwise reference path).
    pub fn eval_eager(self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        match self {
            BinaryKind::Add => a.add(b),
            BinaryKind::Sub => a.sub(b),
            BinaryKind::Mul => a.mul(b),
            BinaryKind::Div => a.div(b),
            BinaryKind::Max => a.maximum(b),
            BinaryKind::Min => a.minimum(b),
        }
    }

    /// Full-shape cotangent w.r.t. the **left** operand before broadcast
    /// reduction — mirrors `autograd::ops`; Max/Min use the standard
    /// subgradient (ties route to the side the forward selects). Split
    /// per side so the VJP replay can skip operands that don't require
    /// gradients without computing their cotangent at all.
    pub fn vjp_a(self, a: &Tensor, b: &Tensor, g: &Tensor) -> Result<Tensor> {
        match self {
            BinaryKind::Add | BinaryKind::Sub => Ok(g.clone()),
            BinaryKind::Mul => g.mul(b),
            BinaryKind::Div => g.div(b),
            BinaryKind::Max => g.mul(&a.ge(b)?), // 1.0 where a wins (ties -> a)
            BinaryKind::Min => g.mul(&b.ge(a)?), // 1.0 where a <= b
        }
    }

    /// Full-shape cotangent w.r.t. the **right** operand before
    /// broadcast reduction (see [`BinaryKind::vjp_a`]).
    pub fn vjp_b(self, a: &Tensor, b: &Tensor, g: &Tensor) -> Result<Tensor> {
        match self {
            BinaryKind::Add => Ok(g.clone()),
            BinaryKind::Sub => Ok(g.neg()),
            BinaryKind::Mul => g.mul(a),
            BinaryKind::Div => Ok(g.mul(a)?.div(&b.square())?.neg()),
            BinaryKind::Max => g.mul(&a.ge(b)?.map(|v| 1.0 - v)),
            BinaryKind::Min => g.mul(&b.ge(a)?.map(|v| 1.0 - v)),
        }
    }

    /// Op name for graph dumps and `Debug`.
    pub fn name(self) -> &'static str {
        match self {
            BinaryKind::Add => "add",
            BinaryKind::Sub => "sub",
            BinaryKind::Mul => "mul",
            BinaryKind::Div => "div",
            BinaryKind::Max => "maximum",
            BinaryKind::Min => "minimum",
        }
    }

    /// Structural-signature tag (program-cache key component).
    pub fn sig_tag(self) -> u64 {
        match self {
            BinaryKind::Add => 0,
            BinaryKind::Sub => 1,
            BinaryKind::Mul => 2,
            BinaryKind::Div => 3,
            BinaryKind::Max => 4,
            BinaryKind::Min => 5,
        }
    }
}

/// Full reductions (to a rank-0 scalar) a lazy expression may end in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum ReduceOp {
    Sum,
    Mean,
    Max,
    Min,
}

impl ReduceOp {
    /// Identity element of the underlying fold (what an empty reduction
    /// yields before [`ReduceOp::finish`]).
    pub fn identity(self) -> f32 {
        match self {
            ReduceOp::Sum | ReduceOp::Mean => 0.0,
            ReduceOp::Max => f32::NEG_INFINITY,
            ReduceOp::Min => f32::INFINITY,
        }
    }

    /// Contiguous-slice kernel producing one chunk partial — the same
    /// kernel the eager `reduce_all` uses over the same [`fixed
    /// partition`](crate::ops::exec::reduce_fixed), which is what keeps
    /// fused and eager reductions bitwise-equal.
    pub fn slice_kernel(self) -> fn(&[f32]) -> f32 {
        match self {
            ReduceOp::Sum | ReduceOp::Mean => kernels::sum,
            ReduceOp::Max => kernels::max,
            ReduceOp::Min => kernels::min,
        }
    }

    /// Fold two chunk partials (applied in ascending chunk order).
    pub fn combine(self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum | ReduceOp::Mean => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
        }
    }

    /// Finalize the folded total (`Mean` applies the same `* (1/n)` the
    /// eager `Tensor::mean` applies after its sum — and, with `n` = the
    /// row length, the same `* (1/k)` the eager `mean_axis` applies per
    /// row, so the fused axis epilogue reuses this rule).
    pub fn finish(self, total: f32, n: usize) -> f32 {
        match self {
            ReduceOp::Mean => total * (1.0 / n as f32),
            _ => total,
        }
    }

    /// Replay through the eager kernel (the bitwise reference path —
    /// also used directly when the reduce input is already a
    /// materialized tensor, so non-contiguous inputs take the exact
    /// eager code path).
    pub fn eval_eager(self, x: &Tensor) -> Tensor {
        match self {
            ReduceOp::Sum => x.sum(),
            ReduceOp::Mean => x.mean(),
            ReduceOp::Max => x.max_all(),
            ReduceOp::Min => x.min_all(),
        }
    }

    /// Replay a **last-axis** reduction through the eager kernel — the
    /// bitwise reference for the fused per-row epilogue, and the path
    /// taken when the reduce input is already materialized.
    pub fn eval_eager_axis(self, x: &Tensor, keepdim: bool) -> Result<Tensor> {
        match self {
            ReduceOp::Sum => x.sum_axis(-1, keepdim),
            ReduceOp::Mean => x.mean_axis(-1, keepdim),
            ReduceOp::Max => x.max_axis(-1, keepdim),
            ReduceOp::Min => x.min_axis(-1, keepdim),
        }
    }

    /// Cotangent w.r.t. a last-axis reduce input given the reduced
    /// cotangent `g` — mirrors `Var::sum_axis` / `Var::mean_axis`
    /// (unsqueeze the reduced axis, broadcast back, scale for Mean);
    /// Max/Min route each row's cotangent to the row's first extremum,
    /// like the full-reduction rule.
    pub fn vjp_axis(self, x: &Tensor, g: &Tensor, keepdim: bool) -> Tensor {
        let rank = x.dims().len();
        debug_assert!(rank >= 1, "axis reduce requires rank >= 1");
        match self {
            ReduceOp::Sum | ReduceOp::Mean => {
                let g2 = if keepdim {
                    g.clone()
                } else {
                    g.unsqueeze((rank - 1) as isize).expect("unsqueeze last axis")
                };
                let full = g2
                    .broadcast_to(x.dims())
                    .expect("cotangent broadcasts to input")
                    .contiguous();
                match self {
                    ReduceOp::Mean => full.mul_scalar(1.0 / x.dims()[rank - 1] as f32),
                    _ => full,
                }
            }
            ReduceOp::Max | ReduceOp::Min => {
                let k = x.dims()[rank - 1];
                let flat = x.contiguous();
                let xv = flat.contiguous_data().expect("contiguous input");
                let gv = g.to_vec();
                let mut grad = vec![0.0f32; xv.len()];
                if k > 0 {
                    for (r, row) in xv.chunks_exact(k).enumerate() {
                        // First-occurrence extremum with the same init and
                        // strict-compare tie-breaking as `kernels::argmax`
                        // (no per-row negated copy for Min).
                        let mut arg = 0usize;
                        let mut best = match self {
                            ReduceOp::Max => f32::NEG_INFINITY,
                            _ => f32::INFINITY,
                        };
                        for (i, &v) in row.iter().enumerate() {
                            let wins = match self {
                                ReduceOp::Max => v > best,
                                _ => v < best,
                            };
                            if wins {
                                best = v;
                                arg = i;
                            }
                        }
                        grad[r * k + arg] = gv[r];
                    }
                }
                Tensor::from_vec(grad, x.dims()).expect("grad shape matches input")
            }
        }
    }

    /// Cotangent w.r.t. the reduce input given the scalar `ḡ` — mirrors
    /// `Var::sum`/`mean`/`max_all` (Max/Min route to the first arg
    /// extremum, like `Var::max_all`).
    pub fn vjp(self, x: &Tensor, g: &Tensor) -> Tensor {
        let seed = g.item().expect("reduce cotangent is scalar");
        match self {
            ReduceOp::Sum => Tensor::full(x.dims(), seed),
            ReduceOp::Mean => Tensor::full(x.dims(), seed * (1.0 / x.numel() as f32)),
            ReduceOp::Max | ReduceOp::Min => {
                let flat = x.to_vec();
                let arg = match self {
                    ReduceOp::Max => kernels::argmax(&flat),
                    _ => {
                        let neg: Vec<f32> = flat.iter().map(|v| -v).collect();
                        kernels::argmax(&neg)
                    }
                };
                let mut grad = vec![0.0f32; flat.len()];
                if !grad.is_empty() {
                    grad[arg] = seed;
                }
                Tensor::from_vec(grad, x.dims()).expect("grad shape matches input")
            }
        }
    }

    /// Op name for graph dumps and `Debug`.
    pub fn name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Mean => "mean",
            ReduceOp::Max => "max_all",
            ReduceOp::Min => "min_all",
        }
    }

    /// Op name of the **last-axis** form ("sum_axis", …) for graph dumps
    /// and record-time errors.
    pub fn axis_name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum_axis",
            ReduceOp::Mean => "mean_axis",
            ReduceOp::Max => "max_axis",
            ReduceOp::Min => "min_axis",
        }
    }

    /// Structural-signature tag (program-cache key component).
    pub fn sig_tag(self) -> u64 {
        match self {
            ReduceOp::Sum => 0,
            ReduceOp::Mean => 1,
            ReduceOp::Max => 2,
            ReduceOp::Min => 3,
        }
    }
}

/// One recorded expression node.
pub(crate) enum NodeKind {
    /// Concrete tensor input.
    Leaf(Tensor),
    Unary { k: UnaryKind, x: NodeRef },
    Binary { k: BinaryKind, a: NodeRef, b: NodeRef },
    /// Ternary select `cond != 0 ? a : b` (the `where_cond` instruction).
    Where { c: NodeRef, a: NodeRef, b: NodeRef },
    Reduce { k: ReduceOp, x: NodeRef },
    /// Reduction along the **last axis** (rows stay independent, so the
    /// fused epilogue runs per row and stays thread-count-invariant).
    ReduceAxis { k: ReduceOp, x: NodeRef, keepdim: bool },
    /// Drop-stolen marker: the iterative [`Drop`] below replaces a
    /// node's kind with this while unlinking children, so a deep chain
    /// is torn down with an explicit worklist instead of `Rc` recursion.
    /// Never observable outside `Drop`.
    Nil,
}

/// A DAG node: kind plus the inferred output shape/dtype and a unique id
/// (creation order — ids are the keys of every evaluator-side map).
pub(crate) struct Node {
    pub kind: NodeKind,
    pub shape: Shape,
    pub dtype: DType,
    pub id: usize,
}

/// Shared handle; `LazyTensor` clones are cheap and alias the node.
pub(crate) type NodeRef = Rc<Node>;

static NEXT_ID: AtomicUsize = AtomicUsize::new(1);

fn next_id() -> usize {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

impl Node {
    pub fn leaf(t: Tensor) -> NodeRef {
        Rc::new(Node {
            shape: t.shape().clone(),
            dtype: t.dtype(),
            kind: NodeKind::Leaf(t),
            id: next_id(),
        })
    }

    /// Unary node: same shape and dtype as the input (the eager unary
    /// kernels preserve both).
    pub fn unary(k: UnaryKind, x: &NodeRef) -> NodeRef {
        Rc::new(Node {
            shape: x.shape.clone(),
            dtype: x.dtype,
            kind: NodeKind::Unary { k, x: Rc::clone(x) },
            id: next_id(),
        })
    }

    /// Binary node: broadcast shape, promoted dtype — errors now (at
    /// record time) exactly where the eager op would error.
    pub fn binary(k: BinaryKind, a: &NodeRef, b: &NodeRef) -> Result<NodeRef> {
        let shape = a.shape.broadcast(&b.shape)?;
        Ok(Rc::new(Node {
            shape,
            dtype: a.dtype.promote(b.dtype),
            kind: NodeKind::Binary {
                k,
                a: Rc::clone(a),
                b: Rc::clone(b),
            },
            id: next_id(),
        }))
    }

    /// Full reduction node: rank-0 scalar, F32 (like `Tensor::scalar`).
    pub fn reduce(k: ReduceOp, x: &NodeRef) -> NodeRef {
        Rc::new(Node {
            shape: Shape::scalar(),
            dtype: DType::F32,
            kind: NodeKind::Reduce { k, x: Rc::clone(x) },
            id: next_id(),
        })
    }

    /// Ternary select node: broadcast shape over all three operands,
    /// promoted value dtype — errors now (at record time) exactly where
    /// the eager `Tensor::where_cond` would error.
    pub fn where_cond(c: &NodeRef, a: &NodeRef, b: &NodeRef) -> Result<NodeRef> {
        let shape = c.shape.broadcast(&a.shape)?.broadcast(&b.shape)?;
        Ok(Rc::new(Node {
            shape,
            dtype: c.dtype.promote(a.dtype).promote(b.dtype),
            kind: NodeKind::Where {
                c: Rc::clone(c),
                a: Rc::clone(a),
                b: Rc::clone(b),
            },
            id: next_id(),
        }))
    }

    /// Last-axis reduction node: input dims with the last axis dropped
    /// (or kept as 1), F32 like the eager `reduce_axis`. Errors at record
    /// time on rank-0 inputs, where `Tensor::sum_axis(-1, _)` errors.
    pub fn reduce_axis(k: ReduceOp, x: &NodeRef, keepdim: bool) -> Result<NodeRef> {
        let rank = x.shape.dims().len();
        if rank == 0 {
            return Err(Error::msg(format!(
                "{}: rank must be >= 1",
                k.axis_name()
            )));
        }
        let mut dims = x.shape.dims().to_vec();
        if keepdim {
            dims[rank - 1] = 1;
        } else {
            dims.pop();
        }
        Ok(Rc::new(Node {
            shape: Shape::new(&dims),
            dtype: DType::F32,
            kind: NodeKind::ReduceAxis {
                k,
                x: Rc::clone(x),
                keepdim,
            },
            id: next_id(),
        }))
    }

    /// Operand nodes (empty for leaves).
    pub fn children(&self) -> Vec<&NodeRef> {
        match &self.kind {
            NodeKind::Leaf(_) | NodeKind::Nil => Vec::new(),
            NodeKind::Unary { x, .. }
            | NodeKind::Reduce { x, .. }
            | NodeKind::ReduceAxis { x, .. } => vec![x],
            NodeKind::Binary { a, b, .. } => vec![a, b],
            NodeKind::Where { c, a, b } => vec![c, a, b],
        }
    }

    /// True for nodes a fused region can absorb (unary/binary/ternary).
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self.kind,
            NodeKind::Unary { .. } | NodeKind::Binary { .. } | NodeKind::Where { .. }
        )
    }

    /// Op name ("leaf" for leaves).
    pub fn op_name(&self) -> &'static str {
        match &self.kind {
            NodeKind::Leaf(_) => "leaf",
            NodeKind::Unary { k, .. } => k.name(),
            NodeKind::Binary { k, .. } => k.name(),
            NodeKind::Where { .. } => "where_cond",
            NodeKind::Reduce { k, .. } => k.name(),
            NodeKind::ReduceAxis { k, .. } => k.axis_name(),
            NodeKind::Nil => "nil",
        }
    }
}

/// Move `kind`'s operand references into `out`, leaving [`NodeKind::Nil`]
/// behind (the drop worklist's unlink step).
fn take_children(kind: &mut NodeKind, out: &mut Vec<NodeRef>) {
    match std::mem::replace(kind, NodeKind::Nil) {
        NodeKind::Leaf(_) | NodeKind::Nil => {}
        NodeKind::Unary { x, .. }
        | NodeKind::Reduce { x, .. }
        | NodeKind::ReduceAxis { x, .. } => out.push(x),
        NodeKind::Binary { a, b, .. } => {
            out.push(a);
            out.push(b);
        }
        NodeKind::Where { c, a, b } => {
            out.push(c);
            out.push(a);
            out.push(b);
        }
    }
}

/// Iterative teardown: without this, dropping the root of a long
/// recorded chain recurses (`Rc<Node>` → `Node` → `Rc<Node>` → …) and a
/// deep-enough expression overflows the stack even though evaluation
/// itself is worklist-based. Stealing children into an explicit stack —
/// and only for nodes this handle uniquely owns (`Rc::into_inner`) —
/// makes teardown O(1) stack at any depth.
impl Drop for Node {
    fn drop(&mut self) {
        let mut stack: Vec<NodeRef> = Vec::new();
        take_children(&mut self.kind, &mut stack);
        while let Some(n) = stack.pop() {
            if let Some(mut node) = std::rc::Rc::into_inner(n) {
                take_children(&mut node.kind, &mut stack);
                // `node` drops here with its children already stolen.
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_semantics_match_eager_methods() {
        let xs = [-2.5f32, -0.3, 0.0, 0.7, 3.1];
        let t = Tensor::from_vec(xs.to_vec(), &[5]).unwrap();
        let unaries = [
            UnaryKind::Neg,
            UnaryKind::Relu,
            UnaryKind::Exp,
            UnaryKind::Sqrt,
            UnaryKind::Square,
            UnaryKind::Abs,
            UnaryKind::Sigmoid,
            UnaryKind::Tanh,
            UnaryKind::Gelu,
            UnaryKind::AddScalar(1.5),
            UnaryKind::MulScalar(-0.25),
            UnaryKind::Clamp(-1.0, 1.0),
            UnaryKind::LeakyRelu(0.01),
        ];
        for k in unaries {
            let eager = k.eval_eager(&t).to_vec();
            let scalar: Vec<f32> = xs.iter().map(|&v| k.apply(v)).collect();
            let mut block = xs.to_vec();
            k.apply_block(&mut block);
            for i in 0..xs.len() {
                assert_eq!(eager[i].to_bits(), scalar[i].to_bits(), "{:?}", k);
                assert_eq!(eager[i].to_bits(), block[i].to_bits(), "{:?} block", k);
            }
        }
    }

    #[test]
    fn binary_semantics_match_eager_methods() {
        let a = Tensor::from_vec(vec![1.0, -2.0, 0.5, 4.0], &[4]).unwrap();
        let b = Tensor::from_vec(vec![-3.0, 2.0, 0.5, -0.25], &[4]).unwrap();
        let kinds = [
            BinaryKind::Add,
            BinaryKind::Sub,
            BinaryKind::Mul,
            BinaryKind::Div,
            BinaryKind::Max,
            BinaryKind::Min,
        ];
        for k in kinds {
            let eager = k.eval_eager(&a, &b).unwrap().to_vec();
            let mut block = a.to_vec();
            k.apply_block(&mut block, &b.to_vec());
            for i in 0..4 {
                assert_eq!(eager[i].to_bits(), block[i].to_bits(), "{:?}", k);
                assert_eq!(
                    eager[i].to_bits(),
                    k.apply(a.to_vec()[i], b.to_vec()[i]).to_bits(),
                    "{:?} scalar",
                    k
                );
            }
        }
    }

    #[test]
    fn node_shape_dtype_inference() {
        let a = Node::leaf(Tensor::zeros(&[4, 1]));
        let b = Node::leaf(Tensor::zeros(&[3]));
        let m = Node::binary(BinaryKind::Mul, &a, &b).unwrap();
        assert_eq!(m.shape.dims(), &[4, 3]);
        let r = Node::reduce(ReduceOp::Sum, &m);
        assert_eq!(r.shape.numel(), 1);
        assert_eq!(r.dtype, DType::F32);
        let bad = Node::leaf(Tensor::zeros(&[5]));
        assert!(Node::binary(BinaryKind::Add, &a, &bad).is_err());
        assert!(m.is_elementwise());
        assert!(!r.is_elementwise());
        assert_eq!(r.op_name(), "sum");
        assert_eq!(a.op_name(), "leaf");
        assert_eq!(a.children().len(), 0);
        assert_eq!(m.children().len(), 2);
    }

    #[test]
    fn reduce_finish_and_identity() {
        assert_eq!(ReduceOp::Sum.finish(10.0, 4), 10.0);
        assert_eq!(ReduceOp::Mean.finish(10.0, 4), 2.5);
        assert_eq!(ReduceOp::Max.identity(), f32::NEG_INFINITY);
        assert_eq!(ReduceOp::Min.combine(3.0, -1.0), -1.0);
    }

    #[test]
    fn where_and_reduce_axis_nodes_infer_shapes() {
        let c = Node::leaf(Tensor::from_vec(vec![1.0, 0.0, 1.0], &[3]).unwrap());
        let a = Node::leaf(Tensor::zeros(&[2, 3]));
        let b = Node::leaf(Tensor::ones(&[3]));
        let w = Node::where_cond(&c, &a, &b).unwrap();
        assert_eq!(w.shape.dims(), &[2, 3]);
        assert!(w.is_elementwise());
        assert_eq!(w.op_name(), "where_cond");
        assert_eq!(w.children().len(), 3);

        let r = Node::reduce_axis(ReduceOp::Sum, &a, false).unwrap();
        assert_eq!(r.shape.dims(), &[2]);
        assert_eq!(r.op_name(), "sum_axis");
        let rk = Node::reduce_axis(ReduceOp::Max, &a, true).unwrap();
        assert_eq!(rk.shape.dims(), &[2, 1]);
        assert!(!rk.is_elementwise());
        let scalar = Node::leaf(Tensor::scalar(1.0));
        assert!(Node::reduce_axis(ReduceOp::Sum, &scalar, false).is_err());

        let bad = Node::leaf(Tensor::zeros(&[5]));
        assert!(Node::where_cond(&c, &a, &bad).is_err());
    }

    #[test]
    fn reduce_axis_eager_replay_and_vjp() {
        let x = Tensor::from_vec(vec![1.0, 5.0, 2.0, -4.0, 0.0, 3.0], &[2, 3]).unwrap();
        let s = ReduceOp::Sum.eval_eager_axis(&x, false).unwrap();
        assert_eq!(s.to_vec(), vec![8.0, -1.0]);
        let m = ReduceOp::Max.eval_eager_axis(&x, true).unwrap();
        assert_eq!(m.dims(), &[2, 1]);
        assert_eq!(m.to_vec(), vec![5.0, 3.0]);

        let g = Tensor::from_vec(vec![2.0, -1.0], &[2]).unwrap();
        let gs = ReduceOp::Sum.vjp_axis(&x, &g, false);
        assert_eq!(gs.to_vec(), vec![2.0, 2.0, 2.0, -1.0, -1.0, -1.0]);
        let gm = ReduceOp::Mean.vjp_axis(&x, &g, false);
        let third = 1.0f32 / 3.0;
        for (got, want) in gm.to_vec().iter().zip([
            2.0 * third,
            2.0 * third,
            2.0 * third,
            -third,
            -third,
            -third,
        ]) {
            assert!((got - want).abs() < 1e-6);
        }
        let gmax = ReduceOp::Max.vjp_axis(&x, &g, false);
        assert_eq!(gmax.to_vec(), vec![0.0, 2.0, 0.0, 0.0, 0.0, -1.0]);
        let gmin = ReduceOp::Min.vjp_axis(&x, &g, false);
        assert_eq!(gmin.to_vec(), vec![2.0, 0.0, 0.0, -1.0, 0.0, 0.0]);
    }
}
