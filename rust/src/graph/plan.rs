//! Compiled evaluation plans and the bounded per-thread program cache.
//!
//! Partitioning a DAG into fusable regions and building instruction
//! tapes is cheap, but it is pure overhead when a serving loop evaluates
//! the *same* expression every request. This module compiles the whole
//! DAG once into a [`Plan`] — an ordered list of region dispatches with
//! slot-based value flow — and memoizes it in a bounded LRU keyed by the
//! DAG's **structural signature**: op kinds, immediates, topology
//! (including sharing), and leaf shape/dtype classes — never leaf data.
//! A later `eval()` of a structurally identical expression (even one
//! rebuilt from scratch, over different tensors of the same shapes)
//! binds its leaves to the cached plan and skips region partitioning and
//! tape construction entirely.
//!
//! Cache behavior:
//!
//! - **per-thread** (like the engine stats and the `Rc`-based graph
//!   itself): no locks on the hot path, and a test or bench observes
//!   exactly its own hits/misses (`runtime::stats`:
//!   `program_cache_hits` / `program_cache_misses`).
//! - **bounded LRU**: capacity from `MINITENSOR_PROGRAM_CACHE` (default
//!   [`DEFAULT_CACHE_CAP`] plans; `0` disables caching), adjustable via
//!   [`set_program_cache_capacity`]. Eviction is a linear scan — caps
//!   are small and misses already pay a compile.
//! - **exact keys**: the signature is a full structural encoding (not a
//!   hash), so two different DAGs can never collide into the same plan.
//!
//! Execution reproduces the uncached evaluator exactly: the same
//! regions, dispatched through the same exec entry points, with slots
//! evicted after their last consumer so peak memory tracks the live set.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::fuse::{collect_region, topo_order};
use super::kernel::Program;
use super::node::{NodeKind, NodeRef, ReduceOp};
use crate::dtype::DType;
use crate::error::Result;
use crate::ops::exec;
use crate::runtime::{stats, trace};
use crate::shape::Shape;
use crate::tensor::Tensor;

/// Default capacity (compiled plans) of the per-thread program cache.
pub const DEFAULT_CACHE_CAP: usize = 64;

/// Where a step input lives at execution time.
#[derive(Clone, Copy)]
enum PlanInput {
    /// The i-th leaf of the current binding (first-seen topo order).
    Leaf(usize),
    /// The output slot of an earlier step.
    Slot(usize),
}

/// What a step dispatches.
enum StepKind {
    /// Fused elementwise region → tensor (`exec::fused_op`).
    Map { dtype: DType },
    /// Fused region + full-reduction epilogue → scalar
    /// (`exec::fused_reduce`).
    Reduce { k: ReduceOp },
    /// Fused region + per-row last-axis epilogue
    /// (`exec::fused_axis_reduce`).
    AxisReduce { k: ReduceOp, out_dims: Vec<usize> },
    /// Eager replay of a full reduction over one materialized input.
    EagerReduce { k: ReduceOp },
    /// Eager replay of a last-axis reduction over one materialized input.
    EagerAxisReduce { k: ReduceOp, keepdim: bool },
}

impl StepKind {
    /// Short label for the trace's per-step region spans.
    fn name(&self) -> &'static str {
        match self {
            StepKind::Map { .. } => "map",
            StepKind::Reduce { .. } => "reduce",
            StepKind::AxisReduce { .. } => "axis_reduce",
            StepKind::EagerReduce { .. } => "eager_reduce",
            StepKind::EagerAxisReduce { .. } => "eager_axis_reduce",
        }
    }
}

/// One compiled dispatch.
struct Step {
    /// Compiled region tape (`None` for the eager-replay step kinds).
    program: Option<Program>,
    inputs: Vec<PlanInput>,
    /// Shape of the virtual elementwise result the tape runs over (= the
    /// output shape for `Map`).
    virt: Shape,
    kind: StepKind,
}

/// A compiled, reusable evaluation plan: steps in dependency order (the
/// root's step is last), plus per-step eviction lists.
pub(crate) struct Plan {
    steps: Vec<Step>,
    /// Slots whose last consumer is step `i` — dropped right after it
    /// runs, so freed buffers return to the pool for later steps.
    evict_after: Vec<Vec<usize>>,
    n_leaves: usize,
    /// Regions the partitioner degraded to per-op dispatch while
    /// compiling this plan. Re-recorded into `runtime::stats` on every
    /// cache-hit execution, so `fusion_bailouts` counts degraded regions
    /// *dispatched* per eval, not merely compiled once.
    bailouts: u64,
}

/// Stable tag per dtype for the structural signature.
fn dtype_tag(d: DType) -> u64 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::Bool => 2,
    }
}

/// Structural signature of the DAG plus its leaf tensors in first-seen
/// topo order (the binding order [`Plan::execute`] expects), plus the
/// topo order itself so a cache miss can compile without re-walking the
/// DAG.
///
/// The encoding is uniquely decodable — every record starts with a tag
/// that fixes its field count (leaf records carry their rank) — so equal
/// signatures imply structurally identical DAGs; no hash collisions can
/// alias two different plans.
fn signature(root: &NodeRef) -> (Vec<u64>, Vec<Tensor>, Vec<NodeRef>) {
    let order = topo_order(root);
    let mut pos: HashMap<usize, usize> = HashMap::with_capacity(order.len());
    let mut sig: Vec<u64> = Vec::with_capacity(order.len() * 4);
    let mut leaves: Vec<Tensor> = Vec::new();
    for (i, n) in order.iter().enumerate() {
        pos.insert(n.id, i);
        match &n.kind {
            NodeKind::Leaf(t) => {
                sig.push(0);
                sig.push(dtype_tag(t.dtype()));
                sig.push(t.dims().len() as u64);
                sig.extend(t.dims().iter().map(|&d| d as u64));
                leaves.push(t.clone());
            }
            NodeKind::Unary { k, x } => {
                sig.push(1);
                k.encode_sig(&mut sig);
                sig.push(pos[&x.id] as u64);
            }
            NodeKind::Binary { k, a, b } => {
                sig.push(2);
                sig.push(k.sig_tag());
                sig.push(pos[&a.id] as u64);
                sig.push(pos[&b.id] as u64);
            }
            NodeKind::Where { c, a, b } => {
                sig.push(3);
                sig.push(pos[&c.id] as u64);
                sig.push(pos[&a.id] as u64);
                sig.push(pos[&b.id] as u64);
            }
            NodeKind::Reduce { k, x } => {
                sig.push(4);
                sig.push(k.sig_tag());
                sig.push(pos[&x.id] as u64);
            }
            NodeKind::ReduceAxis { k, x, keepdim } => {
                sig.push(5);
                sig.push(k.sig_tag());
                sig.push(u64::from(*keepdim));
                sig.push(pos[&x.id] as u64);
            }
            NodeKind::Nil => unreachable!("Nil exists only during drop"),
        }
    }
    (sig, leaves, order)
}

/// Working state of one [`compile`] walk.
struct Compiler {
    uses: HashMap<usize, usize>,
    bound: HashMap<usize, PlanInput>,
    regions: HashMap<usize, super::fuse::Region>,
    steps: Vec<Step>,
    stack: Vec<NodeRef>,
}

impl Compiler {
    /// Append `step` as node `n_id`'s materialization and bind its slot.
    fn emit(&mut self, n_id: usize, step: Step) {
        self.steps.push(step);
        self.bound.insert(n_id, PlanInput::Slot(self.steps.len() - 1));
    }

    /// Try to emit the fused region rooted at `region_root` as node
    /// `n_id`'s step (`make_kind` builds the step kind once the region's
    /// inputs are all bound): returns true when emitted, false after
    /// pushing the still-unbound inputs onto the walk stack.
    fn try_emit_region(
        &mut self,
        n_id: usize,
        region_root: &NodeRef,
        make_kind: impl FnOnce() -> StepKind,
    ) -> bool {
        // Borrow fields separately so the memoization closure captures a
        // plain local reference, not `self`.
        let uses = &self.uses;
        let region = self
            .regions
            .entry(n_id)
            .or_insert_with(|| collect_region(region_root, uses));
        let pending: Vec<NodeRef> = region
            .inputs
            .iter()
            .filter(|i| !self.bound.contains_key(&i.id))
            .cloned()
            .collect();
        if !pending.is_empty() {
            self.stack.extend(pending);
            return false;
        }
        let region = self.regions.remove(&n_id).expect("region just inserted");
        let inputs = region.inputs.iter().map(|i| self.bound[&i.id]).collect();
        self.emit(
            n_id,
            Step {
                program: Some(region.program),
                inputs,
                virt: region_root.shape.clone(),
                kind: make_kind(),
            },
        );
        true
    }
}

/// Compile the DAG into a plan: the same demand-driven walk the
/// pre-cache evaluator ran, except regions are *emitted as steps*
/// instead of dispatched — so a cached plan replays exactly the
/// dispatch sequence (and therefore the numerics) of an uncached eval.
fn compile(root: &NodeRef, order: &[NodeRef]) -> Plan {
    // Canonical leaf indices: first appearance in the `signature` topo
    // order (each node appears exactly once), which is the order the
    // leaf tensors were collected in — what makes a cached plan bind a
    // rebuilt graph's leaves correctly. Reusing `order` also yields the
    // consumer-edge counts in one pass instead of re-walking the DAG.
    let mut leaf_idx: HashMap<usize, usize> = HashMap::new();
    let mut uses: HashMap<usize, usize> = HashMap::new();
    for n in order {
        if matches!(n.kind, NodeKind::Leaf(_)) {
            let next = leaf_idx.len();
            leaf_idx.entry(n.id).or_insert(next);
        }
        for ch in n.children() {
            *uses.entry(ch.id).or_insert(0) += 1;
        }
    }
    let n_leaves = leaf_idx.len();

    let mut c = Compiler {
        uses,
        bound: HashMap::new(),
        regions: HashMap::new(),
        steps: Vec::new(),
        stack: vec![root.clone()],
    };
    while let Some(n) = c.stack.last().cloned() {
        if c.bound.contains_key(&n.id) {
            c.stack.pop();
            continue;
        }
        match &n.kind {
            NodeKind::Leaf(_) => {
                c.bound.insert(n.id, PlanInput::Leaf(leaf_idx[&n.id]));
                c.stack.pop();
            }
            NodeKind::Unary { .. } | NodeKind::Binary { .. } | NodeKind::Where { .. } => {
                if c.try_emit_region(n.id, &n, || StepKind::Map { dtype: n.dtype }) {
                    c.stack.pop();
                }
            }
            NodeKind::Reduce { k, x } => {
                let private_elem =
                    x.is_elementwise() && c.uses.get(&x.id).copied().unwrap_or(0) <= 1;
                if private_elem {
                    // Fused epilogue over the private elementwise subtree.
                    if c.try_emit_region(n.id, x, || StepKind::Reduce { k: *k }) {
                        c.stack.pop();
                    }
                } else if let Some(&input) = c.bound.get(&x.id) {
                    // Boundary input (leaf / shared / reduce result):
                    // replay the exact eager reduction over it.
                    c.emit(
                        n.id,
                        Step {
                            program: None,
                            inputs: vec![input],
                            virt: x.shape.clone(),
                            kind: StepKind::EagerReduce { k: *k },
                        },
                    );
                    c.stack.pop();
                } else {
                    c.stack.push(x.clone());
                }
            }
            NodeKind::ReduceAxis { k, x, keepdim } => {
                let private_elem =
                    x.is_elementwise() && c.uses.get(&x.id).copied().unwrap_or(0) <= 1;
                if private_elem {
                    let kind = || StepKind::AxisReduce {
                        k: *k,
                        out_dims: n.shape.dims().to_vec(),
                    };
                    if c.try_emit_region(n.id, x, kind) {
                        c.stack.pop();
                    }
                } else if let Some(&input) = c.bound.get(&x.id) {
                    c.emit(
                        n.id,
                        Step {
                            program: None,
                            inputs: vec![input],
                            virt: x.shape.clone(),
                            kind: StepKind::EagerAxisReduce {
                                k: *k,
                                keepdim: *keepdim,
                            },
                        },
                    );
                    c.stack.pop();
                } else {
                    c.stack.push(x.clone());
                }
            }
            NodeKind::Nil => unreachable!("Nil exists only during drop"),
        }
    }
    let (steps, bound) = (c.steps, c.bound);
    debug_assert!(
        matches!(bound.get(&root.id), Some(PlanInput::Slot(s)) if *s == steps.len() - 1),
        "root step must be emitted last"
    );

    // Last consumer per slot → eviction lists (the root slot is read by
    // no step and survives to be taken as the result).
    let mut last_read: Vec<Option<usize>> = vec![None; steps.len()];
    for (i, step) in steps.iter().enumerate() {
        for input in &step.inputs {
            if let PlanInput::Slot(s) = input {
                last_read[*s] = Some(i);
            }
        }
    }
    let mut evict_after: Vec<Vec<usize>> = vec![Vec::new(); steps.len()];
    for (s, lr) in last_read.iter().enumerate() {
        if let Some(i) = lr {
            evict_after[*i].push(s);
        }
    }

    Plan {
        steps,
        evict_after,
        n_leaves,
        bailouts: 0, // filled in by the caller from the stats delta
    }
}

impl Plan {
    /// Run the plan over a leaf binding (tensors in the `signature` leaf
    /// order). Dispatch-for-dispatch identical to an uncached eval of
    /// the same DAG.
    fn execute(&self, leaves: &[Tensor]) -> Result<Tensor> {
        debug_assert_eq!(leaves.len(), self.n_leaves, "leaf binding arity");
        let mut slots: Vec<Option<Tensor>> = Vec::new();
        slots.resize_with(self.steps.len(), || None);
        for (i, step) in self.steps.iter().enumerate() {
            let mut rsp = trace::span("graph", "region");
            rsp.arg_u("step", i as u64);
            rsp.arg_s("kind", step.kind.name());
            let t = {
                let ins: Vec<&Tensor> = step
                    .inputs
                    .iter()
                    .map(|pi| match pi {
                        PlanInput::Leaf(j) => &leaves[*j],
                        PlanInput::Slot(s) => slots[*s].as_ref().expect("slot is live"),
                    })
                    .collect();
                match &step.kind {
                    StepKind::Map { dtype } => {
                        let prog = step.program.as_ref().expect("map step has a program");
                        exec::fused_op(&ins, &step.virt, *dtype, prog.n_ops, |bufs, out| {
                            prog.eval(bufs, out)
                        })?
                    }
                    StepKind::Reduce { k } => {
                        let kk = *k;
                        let prog = step.program.as_ref().expect("reduce step has a program");
                        let total = exec::fused_reduce(
                            &ins,
                            &step.virt,
                            prog.n_ops + 1,
                            |bufs, out| prog.eval(bufs, out),
                            kk.slice_kernel(),
                            |p, q| kk.combine(p, q),
                        )?;
                        Tensor::scalar(
                            kk.finish(total.unwrap_or_else(|| kk.identity()), step.virt.numel()),
                        )
                    }
                    StepKind::AxisReduce { k, out_dims } => {
                        let kk = *k;
                        let prog = step
                            .program
                            .as_ref()
                            .expect("axis-reduce step has a program");
                        exec::fused_axis_reduce(
                            &ins,
                            &step.virt,
                            prog.n_ops + 1,
                            |bufs, out| prog.eval(bufs, out),
                            kk.slice_kernel(),
                            move |total, klen| kk.finish(total, klen),
                            kk.identity(),
                            out_dims,
                        )?
                    }
                    StepKind::EagerReduce { k } => k.eval_eager(ins[0]),
                    StepKind::EagerAxisReduce { k, keepdim } => {
                        k.eval_eager_axis(ins[0], *keepdim)?
                    }
                }
            };
            for &s in &self.evict_after[i] {
                slots[s] = None;
            }
            slots[i] = Some(t);
        }
        Ok(slots
            .last_mut()
            .and_then(Option::take)
            .expect("root step was executed"))
    }
}

/// The per-thread bounded LRU of compiled plans.
struct ProgramCache {
    map: HashMap<Vec<u64>, (Rc<Plan>, u64)>,
    tick: u64,
    cap: usize,
}

impl ProgramCache {
    fn new() -> ProgramCache {
        // Caches are per-thread but the invalid-value warning is
        // once-per-process (envvar deduplicates), so a 32-thread serve
        // run doesn't print it 32 times.
        let raw = std::env::var("MINITENSOR_PROGRAM_CACHE").ok();
        let cap = env_cache_cap(raw.as_deref()).unwrap_or(DEFAULT_CACHE_CAP);
        ProgramCache {
            map: HashMap::new(),
            tick: 0,
            cap,
        }
    }

    fn evict_lru(&mut self) {
        let victim = self
            .map
            .iter()
            .min_by_key(|(_, (_, t))| *t)
            .map(|(k, _)| k.clone());
        if let Some(k) = victim {
            self.map.remove(&k);
        }
    }

    fn get(&mut self, key: &[u64]) -> Option<Rc<Plan>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|e| {
            e.1 = tick;
            Rc::clone(&e.0)
        })
    }

    fn insert(&mut self, key: Vec<u64>, plan: Rc<Plan>) {
        if self.cap == 0 {
            return;
        }
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            self.evict_lru();
        }
        self.tick += 1;
        let tick = self.tick;
        self.map.insert(key, (plan, tick));
    }
}

/// Parse a raw `MINITENSOR_PROGRAM_CACHE` value. Any unsigned integer is
/// valid — `0` deliberately disables caching — while garbage warns once
/// on stderr and returns `None` (caller uses [`DEFAULT_CACHE_CAP`]).
fn env_cache_cap(raw: Option<&str>) -> Option<usize> {
    crate::runtime::envvar::parse::<usize>(
        "MINITENSOR_PROGRAM_CACHE",
        raw,
        |_| true,
        "an unsigned plan count (0 disables caching)",
    )
}

thread_local! {
    static CACHE: RefCell<ProgramCache> = RefCell::new(ProgramCache::new());
}

/// Drop every cached plan on this thread (benchmarks and tests that
/// measure the cold-compile path).
pub fn program_cache_clear() {
    CACHE.with(|c| c.borrow_mut().map.clear());
}

/// Number of plans currently cached on this thread.
pub fn program_cache_len() -> usize {
    CACHE.with(|c| c.borrow().map.len())
}

/// This thread's current program-cache capacity (for save/restore
/// around capacity experiments).
pub fn program_cache_capacity() -> usize {
    CACHE.with(|c| c.borrow().cap)
}

/// Override this thread's program-cache capacity (`0` disables caching
/// — every `eval()` compiles, which is exactly the pre-cache behavior).
/// The startup default is `MINITENSOR_PROGRAM_CACHE`, else
/// [`DEFAULT_CACHE_CAP`].
pub fn set_program_cache_capacity(cap: usize) {
    CACHE.with(|c| {
        let mut c = c.borrow_mut();
        c.cap = cap;
        while c.map.len() > cap {
            c.evict_lru();
        }
    });
}

/// Evaluate the DAG rooted at `root`: look the structural signature up
/// in the program cache (hit ⇒ skip region partitioning and tape
/// construction entirely), compile + memoize on miss, then execute the
/// plan over the current leaf binding.
pub(crate) fn eval(root: &NodeRef) -> Result<Tensor> {
    if let NodeKind::Leaf(t) = &root.kind {
        // Leaf eval is free: share storage, no dispatch, no cache entry.
        return Ok(t.clone());
    }
    let (sig, leaves, order) = signature(root);
    let mut esp = trace::span("graph", "eval");
    let cached = CACHE.with(|c| c.borrow_mut().get(&sig));
    let plan = match cached {
        Some(p) => {
            esp.arg_s("cache", "hit");
            stats::record_program_cache_hit();
            // Degraded regions dispatch per-op on every execution, so a
            // cached degraded plan keeps showing up in the counter.
            stats::record_fusion_bailouts(p.bailouts);
            p
        }
        None => {
            esp.arg_s("cache", "miss");
            stats::record_program_cache_miss();
            // `graph.compile` failpoint: a compile-path failure surfaces
            // as a structured error (or panic/delay) before any cache
            // entry exists, so a retry recompiles from scratch.
            crate::runtime::faults::fire("graph.compile")?;
            // collect_region records each cap degradation as it happens;
            // the delta pins this plan's count for cache-hit re-runs.
            let before = stats::snapshot().fusion_bailouts;
            let mut plan = {
                let _csp = trace::span("graph", "compile");
                compile(root, &order)
            };
            plan.bailouts = stats::snapshot().fusion_bailouts - before;
            let p = Rc::new(plan);
            CACHE.with(|c| c.borrow_mut().insert(sig, Rc::clone(&p)));
            p
        }
    };
    esp.arg_u("steps", plan.steps.len() as u64);
    plan.execute(&leaves)
}

#[cfg(test)]
mod tests {
    use super::super::node::{BinaryKind, Node, ReduceOp, UnaryKind};
    use super::*;

    #[test]
    fn env_cache_cap_accepts_zero_and_rejects_garbage() {
        // Pure resolution over raw values — no std::env mutation (the
        // test harness is multi-threaded).
        assert_eq!(env_cache_cap(None), None);
        assert_eq!(env_cache_cap(Some("128")), Some(128));
        assert_eq!(env_cache_cap(Some("0")), Some(0), "0 disables caching");
        // Invalid values fall back to the default (with a warning).
        assert_eq!(env_cache_cap(Some("many")), None);
        assert_eq!(env_cache_cap(Some("-1")), None);
        assert_eq!(env_cache_cap(Some("1e3")), None);
        let err = crate::runtime::envvar::parse_checked::<usize>(
            "MINITENSOR_PROGRAM_CACHE",
            Some("many"),
            |_| true,
            "an unsigned plan count (0 disables caching)",
        )
        .unwrap_err();
        assert!(err.contains("MINITENSOR_PROGRAM_CACHE"), "{err}");
    }

    fn leaf(v: Vec<f32>, dims: &[usize]) -> NodeRef {
        Node::leaf(Tensor::from_vec(v, dims).unwrap())
    }

    /// relu(a * b + a) over fresh nodes each call (same structure,
    /// different node ids — the cache must still hit).
    fn chain(a: &Tensor, b: &Tensor) -> NodeRef {
        let la = Node::leaf(a.clone());
        let lb = Node::leaf(b.clone());
        let m = Node::binary(BinaryKind::Mul, &la, &lb).unwrap();
        let s = Node::binary(BinaryKind::Add, &m, &la).unwrap();
        Node::unary(UnaryKind::Relu, &s)
    }

    #[test]
    fn structurally_equal_dags_share_one_signature() {
        let a = Tensor::arange(0.0, 8.0);
        let b = Tensor::arange(8.0, 16.0);
        let (s1, l1, _) = signature(&chain(&a, &b));
        let (s2, l2, _) = signature(&chain(&a, &b));
        assert_eq!(s1, s2);
        assert_eq!(l1.len(), 2);
        assert_eq!(l2.len(), 2);
        // Different immediate ⇒ different signature.
        let c = Node::unary(UnaryKind::AddScalar(1.0), &chain(&a, &b));
        let d = Node::unary(UnaryKind::AddScalar(2.0), &chain(&a, &b));
        assert_ne!(signature(&c).0, signature(&d).0);
        // Different leaf shape ⇒ different signature.
        let short = Tensor::arange(0.0, 4.0);
        assert_ne!(signature(&chain(&short, &short)).0, s1);
    }

    #[test]
    fn second_eval_hits_the_cache_and_matches_bitwise() {
        let a = Tensor::arange(-8.0, 8.0);
        let b = Tensor::arange(0.0, 16.0);
        program_cache_clear();
        let before = stats::snapshot();
        let y1 = eval(&chain(&a, &b)).unwrap();
        let d1 = stats::snapshot().delta(&before);
        assert_eq!(d1.program_cache_misses, 1);
        assert_eq!(d1.program_cache_hits, 0);
        let before = stats::snapshot();
        let y2 = eval(&chain(&a, &b)).unwrap();
        let d2 = stats::snapshot().delta(&before);
        assert_eq!(d2.program_cache_misses, 0, "no new tape builds");
        assert_eq!(d2.program_cache_hits, 1);
        assert_eq!(d2.exec_dispatches, 1, "cached plan still one dispatch");
        for (x, y) in y1.to_vec().iter().zip(y2.to_vec()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn cached_plan_reused_across_different_leaf_data() {
        // Same structure, new data: hit, and the result reflects the
        // *new* tensors (plans capture structure, never data).
        program_cache_clear();
        let a = Tensor::arange(0.0, 6.0);
        let b = Tensor::arange(6.0, 12.0);
        eval(&chain(&a, &b)).unwrap();
        let a2 = Tensor::arange(100.0, 106.0);
        let b2 = Tensor::arange(-6.0, 0.0);
        let before = stats::snapshot();
        let got = eval(&chain(&a2, &b2)).unwrap();
        assert_eq!(stats::snapshot().delta(&before).program_cache_hits, 1);
        let want = a2.mul(&b2).unwrap().add(&a2).unwrap().relu();
        for (x, y) in got.to_vec().iter().zip(want.to_vec()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn capacity_bounds_and_lru_eviction() {
        program_cache_clear();
        let old_cap = CACHE.with(|c| c.borrow().cap);
        set_program_cache_capacity(2);
        let a = Tensor::arange(0.0, 4.0);
        for s in [1.0f32, 2.0, 3.0] {
            let n = Node::unary(UnaryKind::AddScalar(s), &Node::leaf(a.clone()));
            eval(&n).unwrap();
        }
        assert_eq!(program_cache_len(), 2, "LRU stays at capacity");
        // The oldest entry (s = 1.0) was evicted: re-eval misses.
        let before = stats::snapshot();
        let n = Node::unary(UnaryKind::AddScalar(1.0), &Node::leaf(a.clone()));
        eval(&n).unwrap();
        assert_eq!(stats::snapshot().delta(&before).program_cache_misses, 1);
        // Capacity 0 disables caching entirely.
        set_program_cache_capacity(0);
        assert_eq!(program_cache_len(), 0);
        let before = stats::snapshot();
        let n = Node::unary(UnaryKind::AddScalar(9.0), &Node::leaf(a.clone()));
        eval(&n).unwrap();
        eval(&n).unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.program_cache_misses, 2);
        assert_eq!(d.program_cache_hits, 0);
        set_program_cache_capacity(old_cap);
    }

    #[test]
    fn plan_slots_evict_after_last_use() {
        // tanh(a) shared by two consumers: its slot must stay live for
        // both reads, then free — and the value must still be right.
        let a = leaf(vec![0.25, -0.75, 1.5], &[3]);
        let c = Node::unary(UnaryKind::Tanh, &a);
        let d = Node::binary(BinaryKind::Mul, &c, &c).unwrap();
        let e = Node::binary(BinaryKind::Add, &d, &c).unwrap();
        let fused = eval(&e).unwrap();
        let eager = super::super::fuse::eval_eager(&e).unwrap();
        for (x, y) in fused.to_vec().iter().zip(eager.to_vec()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn reduce_and_axis_reduce_steps_execute_through_plans() {
        let v: Vec<f32> = (0..60).map(|i| (i as f32) * 0.1 - 3.0).collect();
        let a = leaf(v, &[5, 12]);
        // Full reduce over a private subtree.
        let s = Node::reduce(ReduceOp::Sum, &Node::unary(UnaryKind::Square, &a));
        let fused = eval(&s).unwrap();
        let eager = super::super::fuse::eval_eager(&s).unwrap();
        assert_eq!(
            fused.item().unwrap().to_bits(),
            eager.item().unwrap().to_bits()
        );
        // Axis reduce over a private subtree, and over a raw leaf.
        for keepdim in [false, true] {
            let r = Node::reduce_axis(
                ReduceOp::Max,
                &Node::unary(UnaryKind::Abs, &a),
                keepdim,
            )
            .unwrap();
            let fused = eval(&r).unwrap();
            let eager = super::super::fuse::eval_eager(&r).unwrap();
            assert_eq!(fused.dims(), eager.dims());
            for (x, y) in fused.to_vec().iter().zip(eager.to_vec()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            let rl = Node::reduce_axis(ReduceOp::Mean, &a, keepdim).unwrap();
            let fused = eval(&rl).unwrap();
            let eager = super::super::fuse::eval_eager(&rl).unwrap();
            for (x, y) in fused.to_vec().iter().zip(eager.to_vec()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
