//! # MiniTensor — a lightweight, high-performance tensor operations library
//!
//! Reproduction of *"MiniTensor: A Lightweight, High-Performance Tensor
//! Operations Library"* (Sarkar, 2026) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **Layer 3 (this crate)** — the tensor engine and coordinator: dense
//!   n-dimensional tensors with broadcasting, bulk kernels (elementwise,
//!   reductions, matmul, convolution), a dynamic reverse-mode autograd tape,
//!   neural-network modules, optimizers, a data pipeline, and a coordinator
//!   that dispatches compute to either the native Rust kernels or
//!   AOT-compiled XLA executables.
//! - **Layer 2** — `python/compile/model.py`: the same model math in JAX,
//!   lowered once to HLO text by `python/compile/aot.py`.
//! - **Layer 1** — `python/compile/kernels/`: Pallas kernels for the compute
//!   hot-spots, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts via PJRT (the `xla` crate) and executes them from Rust.
//!
//! ## Lazy graphs & kernel fusion
//!
//! [`Tensor::lazy`] enters the lazy expression-graph subsystem
//! ([`graph`]): ops record a small DAG instead of executing, and
//! [`graph::LazyTensor::eval`] fuses each region of elementwise ops —
//! optionally ending in a full or last-axis reduction — into **one
//! composed kernel** dispatched once through the execution layer: one
//! output allocation, one pass over memory, intermediates in L1 blocks.
//! Compiled programs are memoized in a bounded per-thread cache keyed by
//! DAG structure, so repeated evaluation of the same expression (the
//! serving-loop case) skips partitioning and tape construction. Results
//! are bitwise-equal to the eager op chain and bit-identical at any
//! thread count; `Var::fused` keeps fused forwards differentiable, and
//! the `nn::` forwards and losses fuse by default
//! (`MINITENSOR_NO_FUSION=1` opts out).
//!
//! ## Execution layer & threading
//!
//! Every bulk kernel (elementwise, unary maps, reductions, softmax,
//! matmul, conv, pooling) dispatches through the unified execution layer
//! in [`ops::exec`]: one shared implementation of the contiguous /
//! bias-row / strided tier dispatch, pooled output buffers
//! ([`tensor::pool`]), and chunked data-parallel execution on the
//! persistent worker pool in [`runtime::parallel`].
//!
//! The worker count comes from, in priority order:
//! [`runtime::parallel::set_num_threads`] (also reachable as the
//! `train.threads` config key), the `MINITENSOR_NUM_THREADS` environment
//! variable, then all available cores. Elementwise, matmul, and conv
//! kernels keep their per-element accumulation order and are
//! thread-count-invariant (one thread reproduces the pre-pool serial
//! kernels bit-for-bit), and full reductions fold fixed
//! `REDUCE_CHUNK`-partition partials in order — bit-identical at any
//! thread count, matching the lazy graph's fused reduce epilogues.
//!
//! ## SIMD microkernels
//!
//! Beneath the exec tiers, [`runtime::simd`] provides an explicit
//! 8-lane f32 vector layer: each hot kernel is written once and
//! monomorphized into AVX2 (x86_64, runtime-detected with FMA), NEON
//! (aarch64), and `[f32; 8]` scalar backends. The contiguous
//! elementwise/unary tiers, the fused tape interpreter (including the
//! per-row softmax/reduce epilogues), and the SGEMM 4×16 FMA register
//! tile all dispatch through it.
//!
//! The determinism contract is **bitwise**: scalar ≡ SIMD ≡ any thread
//! count. Scalar blocks mirror the intrinsic semantics exactly and
//! reductions fold lanes in one fixed order, so `MINITENSOR_SIMD=off`
//! (or [`runtime::simd::set_simd_enabled`]) changes speed, never bits.
//! The transcendentals share polynomial kernels across all paths
//! (`fast_exp` ≈ 4e-6 max relative error; `tanh` ~2 ULP of libm) — the
//! approximation is a property of the kernel, not the ISA.
//!
//! ## Serving
//!
//! [`coordinator::InferenceServer`] is a continuous-batching,
//! multi-worker inference server: a dispatcher thread forms batches
//! under a size-or-deadline hybrid flush ([`coordinator::ServeConfig`]
//! is a validated builder) and hands them to N worker threads, each
//! owning a private model replica built on-thread through
//! [`coordinator::ModelFactory`] — safe Rust end to end, with every
//! worker pinning a warm per-thread program cache so repeated batch
//! shapes skip compilation. Admission control fast-rejects with
//! [`Error::Overloaded`] when the queue saturates, per-request
//! deadlines shed expired work with [`Error::DeadlineExceeded`], and
//! `drain`/`shutdown` answer everything admitted before stopping.
//! [`coordinator::ServeStats`] reports p50/p95/p99 latency from a
//! constant-memory log-bucketed histogram — plus a per-request
//! queue-vs-compute breakdown and the engine kernel counters the worker
//! pool executed; replies are byte-identical at any worker count.
//!
//! ## Robustness
//!
//! Replica failure is an expected input: every admitted request gets a
//! **definite** reply — a result or a structured [`Error`] variant
//! ([`Error::Overloaded`], [`Error::DeadlineExceeded`],
//! `Error::WorkerCrashed`), never a hang. A serve worker's forward runs
//! under `catch_unwind`; a panicking replica answers its batch with
//! `WorkerCrashed` (carrying the original panic message) and rebuilds
//! itself in place with exponential backoff, up to
//! `ServeConfig::restart_limit` attempts — then the server degrades
//! onto the surviving replicas, failing fast only when the last one is
//! gone. `ServeConfig::worker_timeout_ms` arms a watchdog that
//! confiscates and answers the batches of wedged workers and spawns
//! replacements. Health (`live`/`degraded`/`draining`) is on
//! `ServeStats` and on `GET /healthz` next to the crash/restart
//! counters. The failure modes are inducible on demand through
//! [`runtime::faults`] — named failpoints (`serve.worker.forward`,
//! `parallel.chunk`, `pool.alloc`, `graph.compile`) armed via
//! `MINITENSOR_FAULTS=site:kind:prob[:count]` or
//! [`runtime::faults::arm`], deterministic per-site injection streams,
//! one relaxed atomic load per disarmed visit (gated by
//! `benches/faults_overhead.rs`).
//!
//! ## Observability
//!
//! Three pillars. [`runtime::stats`] keeps per-thread counters on every
//! kernel dispatch (snapshot / delta / take-and-reset — the exact "what
//! did this thread just execute" view). [`runtime::trace`] is an
//! always-compiled, off-by-default timeline tracer: with
//! `MINITENSOR_TRACE=<path>` (or [`runtime::trace::enable`]) every exec
//! dispatch, worker-pool chunk, graph compile/cache/region step, and
//! serve request phase records a span into fixed-capacity per-thread
//! ring buffers, exported as Chrome trace-event JSON for
//! `chrome://tracing` / Perfetto. Disabled cost is one relaxed atomic
//! load per site; tracing never affects kernel math or determinism.
//! [`runtime::metrics`] is the always-on process-wide registry those
//! counters shard into: one naming scheme
//! (`minitensor_<subsystem>_<what>[_total]`) across exec, fusion,
//! program cache, buffer pool, worker pool, and the serve stack
//! (mirrored from [`coordinator::Metrics`]), exported as a typed
//! [`runtime::metrics::snapshot`], JSON, or Prometheus text — served
//! over HTTP by `ServeConfig::metrics_port` / `minitensor metrics`,
//! at < 2% eager-hot-path cost (gated by `benches/metrics_overhead.rs`).
//!
//! ## Feature flags
//!
//! - `xla` (default off): compiles the PJRT runtime ([`runtime::Engine`]),
//!   the `backend = xla` training path, and the AOT comparison benches.
//!   Requires the `xla` crate, which is not in the offline vendor set —
//!   see `rust/README.md`.
//!
//! ## Quickstart
//!
//! (`no_run`: cargo doesn't forward the PJRT rpath rustflags to doctest
//! executables; the identical code executes in `examples/quickstart.rs`.)
//!
//! ```no_run
//! use minitensor::prelude::*;
//!
//! // Eager tensor math with broadcasting (paper §3.1).
//! let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
//! let b = Tensor::from_vec(vec![10.0, 20.0], &[2]).unwrap();
//! let y = x.add(&b).unwrap(); // broadcasts b over rows
//! assert_eq!(y.to_vec(), vec![11.0, 22.0, 13.0, 24.0]);
//!
//! // Reverse-mode autodiff (paper §3.2): build a graph, call backward().
//! let w = Var::from_tensor(Tensor::ones(&[2, 2]), true);
//! let v = Var::from_tensor(x, false);
//! let loss = v.matmul(&w).unwrap().sum().unwrap();
//! loss.backward().unwrap();
//! assert!(w.grad().is_some());
//! ```

pub mod bench_util;
pub mod dtype;
pub mod error;
pub mod shape;

pub mod tensor;

pub mod ops;

pub mod graph;

pub mod autograd;

pub mod nn;

pub mod optim;

pub mod data;

pub mod baselines;

pub mod runtime;

pub mod coordinator;

pub use dtype::DType;
pub use error::{Error, Result};
pub use graph::LazyTensor;
pub use shape::Shape;
pub use tensor::Tensor;

pub use autograd::Var;

/// Convenience re-exports covering the common API surface.
pub mod prelude {
    pub use crate::autograd::{gradcheck, no_grad, Var};
    pub use crate::data::{DataLoader, Dataset, Rng};
    pub use crate::dtype::DType;
    pub use crate::error::{Error, Result};
    pub use crate::graph::LazyTensor;
    pub use crate::nn::{
        losses, Activation, BatchNorm1d, Conv2d, Dense, Dropout, Module, Sequential,
    };
    pub use crate::optim::{Adam, Optimizer, RmsProp, Sgd};
    pub use crate::shape::Shape;
    pub use crate::tensor::Tensor;
}
