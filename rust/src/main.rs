//! MiniTensor CLI: the L3 coordinator entry point.
//!
//! ```text
//! minitensor train [--config file.cfg] [key=value ...]
//! minitensor serve [--config file.cfg] [key=value ...]
//! minitensor trace <train|serve> [key=value ...]
//! minitensor metrics [--json]
//! minitensor chaos [key=value ...]
//! minitensor info  [--artifacts DIR]
//! minitensor bench-quick
//! ```

use minitensor::coordinator::{
    Config, InferenceServer, NativeModelFactory, ServeConfig, TrainConfig, Trainer,
};
use minitensor::data::Rng;
#[cfg(feature = "xla")]
use minitensor::runtime::Engine;
use minitensor::runtime::{parallel, trace};
use minitensor::tensor::Tensor;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let code = match cmd {
        "train" => cmd_train(rest),
        "serve" => cmd_serve(rest),
        "trace" => cmd_trace(rest),
        "metrics" => cmd_metrics(rest),
        "chaos" => cmd_chaos(rest),
        "info" => cmd_info(rest),
        "bench-quick" => cmd_bench_quick(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "minitensor — lightweight tensor engine (MiniTensor reproduction)

USAGE:
  minitensor train [--config FILE] [section.key=value ...]
  minitensor serve [--config FILE] [section.key=value ...]
  minitensor trace <train|serve> [section.key=value ...]
  minitensor metrics [--json]
  minitensor chaos [key=value ...]
  minitensor info  [--artifacts DIR]
  minitensor bench-quick

EXAMPLES:
  minitensor train train.steps=200 train.optimizer=adam
  minitensor train train.backend=xla train.artifacts_dir=artifacts
  minitensor serve serve.max_batch=16
  minitensor serve serve.workers=4 serve.max_wait_ms=2 serve.deadline_ms=50
  minitensor serve serve.metrics_port=9100        # live GET /metrics
  minitensor trace train
  MINITENSOR_TRACE=serve.json minitensor trace serve serve.workers=2
  minitensor metrics                              # one-shot Prometheus dump
  minitensor chaos chaos.prob=0.3 serve.workers=4 # fault-injection smoke
  minitensor info --artifacts artifacts

Any command also honors MINITENSOR_TRACE=<path>: tracing turns on and
the Chrome-trace JSON (chrome://tracing, ui.perfetto.dev) is written
there on exit. `trace` runs a bounded demo workload and always writes
a trace, defaulting to minitensor-<demo>.trace.json."
    );
}

/// Parse `--config FILE` plus bare `key=value` overrides.
fn load_config(args: &[String]) -> minitensor::Result<Config> {
    let mut cfg = Config::default();
    let mut overrides = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                let path = args
                    .get(i)
                    .ok_or_else(|| minitensor::Error::Config("--config needs a path".into()))?;
                cfg = Config::load(path)?;
            }
            kv if kv.contains('=') => overrides.push(kv.to_string()),
            other => {
                return Err(minitensor::Error::Config(format!(
                    "unexpected argument '{other}'"
                )))
            }
        }
        i += 1;
    }
    cfg.apply_overrides(&overrides)?;
    Ok(cfg)
}

fn cmd_train(args: &[String]) -> minitensor::Result<()> {
    let cfg = load_config(args)?;
    let tc = TrainConfig::from_config(&cfg)?;
    // Trainer::run owns applying train.threads; the banner only mirrors
    // the value it will take effect as.
    let threads = parallel::effective_threads(tc.threads);
    println!(
        "training: dataset={} hidden={:?} optimizer={} lr={} steps={} backend={} threads={threads}",
        tc.dataset, tc.hidden, tc.optimizer, tc.lr, tc.steps, tc.backend
    );
    let trainer = Trainer::new(tc);
    let report = trainer.run()?;
    println!("\nstep, loss");
    for (s, l) in &report.losses {
        println!("{s}, {l:.5}");
    }
    println!(
        "\nparams={}  initial_loss={:.4}  final_loss={:.4}  acc={}  steps/s={:.1}",
        report.num_parameters,
        report.initial_loss,
        report.final_loss,
        report
            .accuracy
            .map_or("n/a".to_string(), |a| format!("{:.3}", a)),
        report.steps_per_sec
    );
    print!("{}", trainer.metrics.report());
    // Engine-level counters: dispatches/allocations of every kernel
    // family plus lazy-graph fusion totals; the trace summary rides
    // along whenever MINITENSOR_TRACE (or `minitensor trace`) is active.
    print!("{}", minitensor::runtime::stats::report());
    if trace::enabled() {
        print!("{}", trace::summary());
    }
    flush_trace()?;
    Ok(())
}

/// If `MINITENSOR_TRACE=<path>` is set, write the Chrome trace there.
fn flush_trace() -> minitensor::Result<()> {
    if let Some((path, n)) = trace::flush_env()? {
        println!("trace: {n} spans -> {path} (chrome://tracing / ui.perfetto.dev)");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> minitensor::Result<()> {
    let cfg = load_config(args)?;
    let tc = TrainConfig::from_config(&cfg)?;
    let sc = ServeConfig::from_config(&cfg)?;
    let n_requests: usize = cfg.get_parse_or("serve.requests", 2000)?;

    // Build the model once to size it, then hand the server a factory so
    // every worker constructs and owns its own replica (identical
    // weights — the factory snapshots the prototype's parameters).
    println!("preparing model ({} steps on {})…", tc.steps, tc.dataset);
    let trainer = Trainer::new(tc.clone());
    let ds = trainer.dataset()?;
    let in_features = ds.x.dims()[1];
    let classes = ds.classes.max(2);
    let factory = NativeModelFactory::new(in_features, move || {
        Trainer::new(tc.clone()).build_model(in_features, classes)
    });

    println!(
        "serving {n_requests} synthetic requests (workers={} max_batch={} max_wait={:?} deadline={:?})…",
        sc.workers(),
        sc.max_batch(),
        sc.max_wait(),
        sc.deadline(),
    );
    let server = std::sync::Arc::new(InferenceServer::start(factory, sc)?);
    if let Some(addr) = server.metrics_addr() {
        println!("metrics: http://{addr}/metrics (Prometheus text)");
    }
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let s = server.clone();
            let mut trng = rng.fork(t as u64);
            let per = n_requests / 4;
            std::thread::spawn(move || {
                let mut errs = 0u64;
                for _ in 0..per {
                    let feats: Vec<f32> =
                        (0..in_features).map(|_| trng.next_f32()).collect();
                    if s.infer(feats).is_err() {
                        errs += 1; // overloaded or deadline-shed
                    }
                }
                errs
            })
        })
        .collect();
    let mut client_errs = 0u64;
    for t in threads {
        client_errs += t.join().expect("client thread");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "done: {} requests in {:.2}s ({:.0} req/s), {} batches (mean size {:.1}), p50={:.2}ms p95={:.2}ms p99={:.2}ms",
        stats.requests,
        elapsed,
        stats.requests as f64 / elapsed,
        stats.batches,
        stats.mean_batch_size,
        stats.p50_latency_ms,
        stats.p95_latency_ms,
        stats.p99_latency_ms
    );
    println!(
        "admission: rejected={} shed={} client_errors={client_errs}; per-worker batches {:?}",
        stats.rejected, stats.shed, stats.worker_batches
    );
    println!(
        "breakdown: mean queue {:.2}ms / mean compute {:.2}ms per request; \
         pool ran {} dispatches, {} simd blocks, {} fused kernels",
        stats.mean_queue_ms,
        stats.mean_compute_ms,
        stats.exec_dispatches,
        stats.simd_blocks,
        stats.fused_kernels
    );
    if trace::enabled() {
        print!("{}", trace::summary());
    }
    flush_trace()?;
    Ok(())
}

/// Run a bounded demo workload with tracing force-enabled and write the
/// Chrome trace (to `MINITENSOR_TRACE` if set, else a default path).
fn cmd_trace(args: &[String]) -> minitensor::Result<()> {
    let demo = args.first().map(String::as_str).unwrap_or("train");
    let rest = &args[1.min(args.len())..];
    let mut full: Vec<String> = match demo {
        // Bounded defaults come first so explicit overrides win.
        "train" => vec!["train.steps=30".into()],
        "serve" => vec!["train.steps=5".into(), "serve.requests=400".into()],
        other => {
            return Err(minitensor::Error::Config(format!(
                "unknown trace demo '{other}' (expected 'train' or 'serve')"
            )))
        }
    };
    full.extend(rest.iter().cloned());
    trace::enable();
    if demo == "train" {
        cmd_train(&full)?;
    } else {
        cmd_serve(&full)?;
    }
    // flush_trace inside the demo already covered the env-path case.
    if trace::env_path().is_none() {
        let out = format!("minitensor-{demo}.trace.json");
        let n = trace::write_chrome_trace(&out)?;
        println!("trace: {n} spans -> {out} (chrome://tracing / ui.perfetto.dev)");
    }
    Ok(())
}

/// One-shot registry dump: run a small representative workload (eager,
/// fused, pooled — enough to touch every built-in metric family), then
/// print the process-wide registry as Prometheus text (or JSON).
fn cmd_metrics(args: &[String]) -> minitensor::Result<()> {
    use minitensor::runtime::metrics;
    let json = match args {
        [] => false,
        [flag] if flag == "--json" => true,
        _ => {
            return Err(minitensor::Error::Config(
                "usage: minitensor metrics [--json]".into(),
            ))
        }
    };
    // Warm-up workload (stderr so stdout stays machine-parseable).
    eprintln!("running warm-up workload (eager add, fused chain, matmul)…");
    let mut rng = Rng::new(1);
    let a = Tensor::randn(&[100_000], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[100_000], 0.0, 1.0, &mut rng);
    for _ in 0..8 {
        std::hint::black_box(a.add(&b).unwrap());
        std::hint::black_box(
            a.lazy()
                .mul(&b.lazy())
                .unwrap()
                .add(&a.lazy())
                .unwrap()
                .relu()
                .eval()
                .unwrap(),
        );
    }
    let m = Tensor::randn(&[64, 64], 0.0, 1.0, &mut rng);
    std::hint::black_box(m.matmul(&m).unwrap());
    let snap = metrics::snapshot();
    if json {
        println!("{}", snap.to_json());
    } else {
        print!("{}", snap.prometheus_text());
    }
    Ok(())
}

/// Chaos smoke run: arm the `serve.worker.forward` panic failpoint,
/// drive a closed-loop load, and verify the fault-tolerance contract —
/// every request gets a *definite* reply (Ok or a structured error,
/// never a hang), crashed replicas are rebuilt, and the server answers
/// again after the faults are disarmed. Exits nonzero on any violation,
/// so CI can gate on it directly.
fn cmd_chaos(args: &[String]) -> minitensor::Result<()> {
    use minitensor::runtime::faults::{self, FaultKind};
    let cfg = load_config(args)?;
    let sc = ServeConfig::from_config(&cfg)?;
    let n_requests: usize = cfg.get_parse_or("chaos.requests", 200)?;
    let prob: f64 = cfg.get_parse_or("chaos.prob", 0.2)?;
    if !(0.0..=1.0).contains(&prob) {
        return Err(minitensor::Error::Config("chaos.prob must be in [0, 1]".into()));
    }

    let in_features = 8;
    let factory = NativeModelFactory::new(in_features, move || {
        let mut rng = Rng::new(7);
        minitensor::nn::Sequential::new()
            .add(minitensor::nn::Dense::new(in_features, 32, &mut rng))
            .add(minitensor::nn::Activation::Relu)
            .add(minitensor::nn::Dense::new(32, 4, &mut rng))
    });
    println!(
        "chaos: {n_requests} requests, serve.worker.forward:panic:{prob} \
         (workers={} max_batch={})",
        sc.workers(),
        sc.max_batch()
    );
    let server = std::sync::Arc::new(InferenceServer::start(factory, sc)?);
    faults::arm("serve.worker.forward", FaultKind::Panic, prob, None);

    // Closed loop: every reply must be definite. A hang shows up as the
    // per-request timeout (counted as a violation), not a wedged CLI.
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let s = server.clone();
            let per = n_requests / 4;
            std::thread::spawn(move || {
                let (mut ok, mut crashed, mut violations) = (0u64, 0u64, 0u64);
                for i in 0..per {
                    let feats = vec![(t * per + i) as f32 * 0.01; in_features];
                    match s.infer_timeout(feats, std::time::Duration::from_secs(30)) {
                        Ok(_) => ok += 1,
                        Err(minitensor::Error::WorkerCrashed { .. }) => crashed += 1,
                        Err(minitensor::Error::Overloaded { .. }) => {}
                        Err(e) => {
                            eprintln!("violation: indefinite/unexpected reply: {e}");
                            violations += 1;
                        }
                    }
                }
                (ok, crashed, violations)
            })
        })
        .collect();
    let (mut ok, mut crashed, mut violations) = (0u64, 0u64, 0u64);
    for t in threads {
        let (o, c, v) = t.join().expect("client thread");
        ok += o;
        crashed += c;
        violations += v;
    }
    faults::disarm("serve.worker.forward");

    // Recovery probe: with faults disarmed the server must answer again
    // (rebuilds may still be in their backoff window — retry briefly).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut recovered = false;
    while std::time::Instant::now() < deadline {
        if server.infer(vec![0.5; in_features]).is_ok() {
            recovered = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    while server.stats().worker_restarts < server.stats().worker_crashes
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    let stats = server.stats();
    println!(
        "replies: ok={ok} crashed={crashed} violations={violations} \
         (definite {}/{n_requests})",
        ok + crashed
    );
    println!(
        "recovery: crashes={} restarts={} timeouts={} replies_dropped={} \
         workers_alive={} health={}",
        stats.worker_crashes,
        stats.worker_restarts,
        stats.worker_timeouts,
        stats.replies_dropped,
        stats.workers_alive,
        stats.health
    );
    for (site, n) in faults::status() {
        println!("faults: {site} injected {n}");
    }
    if violations > 0 {
        return Err(minitensor::Error::msg(format!(
            "{violations} request(s) got an indefinite or unexpected reply"
        )));
    }
    if !recovered {
        return Err(minitensor::Error::msg(
            "server did not answer after faults were disarmed",
        ));
    }
    if stats.worker_restarts < stats.worker_crashes {
        return Err(minitensor::Error::msg(format!(
            "{} crash(es) but only {} restart(s) — replicas were not rebuilt",
            stats.worker_crashes, stats.worker_restarts
        )));
    }
    println!("chaos: PASS");
    Ok(())
}

fn cmd_info(args: &[String]) -> minitensor::Result<()> {
    let dir = args
        .iter()
        .position(|a| a == "--artifacts")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("artifacts");
    println!("minitensor v{}", env!("CARGO_PKG_VERSION"));
    println!(
        "exec layer: {} worker thread(s), simd={} lanes={} \
         (MINITENSOR_NUM_THREADS / MINITENSOR_SIMD to override)",
        parallel::num_threads(),
        minitensor::runtime::simd::path().name(),
        minitensor::runtime::simd::LANES
    );
    #[cfg(feature = "xla")]
    match Engine::cpu(dir) {
        Ok(engine) => {
            println!("pjrt platform: {}", engine.platform());
            println!("artifacts in {dir}:");
            for a in &engine.manifest().artifacts {
                println!(
                    "  {} ({}): {:?} -> {:?}",
                    a.name,
                    a.file.display(),
                    a.input_shapes,
                    a.output_shapes
                );
            }
        }
        Err(e) => println!("no artifacts loaded ({e})"),
    }
    #[cfg(not(feature = "xla"))]
    println!("built without the `xla` feature — no PJRT runtime (artifacts dir: {dir})");
    Ok(())
}

fn cmd_bench_quick() -> minitensor::Result<()> {
    use minitensor::bench_util::{bench, fmt_ns};
    println!(
        "threads: {}  simd: {} ({} lanes)",
        parallel::num_threads(),
        minitensor::runtime::simd::path().name(),
        minitensor::runtime::simd::LANES
    );
    let mut rng = Rng::new(1);
    let a = Tensor::randn(&[1_000_000], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[1_000_000], 0.0, 1.0, &mut rng);
    let s = bench("add 1e6", 50.0, 5, || {
        std::hint::black_box(a.add(&b).unwrap());
    });
    println!("elementwise add 1e6: {}", fmt_ns(s.median_ns));
    let m1 = Tensor::randn(&[256, 256], 0.0, 1.0, &mut rng);
    let m2 = Tensor::randn(&[256, 256], 0.0, 1.0, &mut rng);
    let s = bench("matmul 256", 100.0, 5, || {
        std::hint::black_box(m1.matmul(&m2).unwrap());
    });
    let gflops = 2.0 * 256f64.powi(3) / s.median_ns;
    println!("matmul 256³: {} ({gflops:.2} GFLOP/s)", fmt_ns(s.median_ns));
    let s = bench("fused 3-op 1e6", 50.0, 5, || {
        std::hint::black_box(
            a.lazy()
                .mul(&b.lazy())
                .unwrap()
                .add(&a.lazy())
                .unwrap()
                .relu()
                .eval()
                .unwrap(),
        );
    });
    println!("fused relu(a*b+a) 1e6: {}", fmt_ns(s.median_ns));
    print!("{}", minitensor::runtime::stats::report());
    if trace::enabled() {
        print!("{}", trace::summary());
    }
    flush_trace()?;
    Ok(())
}
