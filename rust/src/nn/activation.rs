//! Activation functions as modules (paper §3.3: ReLU, Sigmoid, Tanh,
//! GELU).

use super::Module;
use crate::autograd::Var;
use crate::error::Result;
use crate::graph::LazyTensor;

/// Parameter-free activation module.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Activation {
    Relu,
    Sigmoid,
    Tanh,
    Gelu,
    LeakyRelu(f32),
    /// Identity (useful as a configurable no-op).
    Identity,
}

impl Activation {
    /// Parse from a config string.
    pub fn parse(s: &str) -> Option<Activation> {
        match s.to_ascii_lowercase().as_str() {
            "relu" => Some(Activation::Relu),
            "sigmoid" => Some(Activation::Sigmoid),
            "tanh" => Some(Activation::Tanh),
            "gelu" => Some(Activation::Gelu),
            "leaky_relu" => Some(Activation::LeakyRelu(0.01)),
            "identity" | "none" => Some(Activation::Identity),
            _ => None,
        }
    }

    /// Apply directly to a `Var`.
    pub fn apply(&self, x: &Var) -> Var {
        match self {
            Activation::Relu => x.relu(),
            Activation::Sigmoid => x.sigmoid(),
            Activation::Tanh => x.tanh(),
            Activation::Gelu => x.gelu(),
            Activation::LeakyRelu(a) => x.leaky_relu(*a),
            Activation::Identity => x.clone(),
        }
    }

    /// Record this activation onto a lazy expression (`None` for
    /// Identity, which has nothing to fuse). The recorded unary applies
    /// the *same scalar function* as the eager `Var` op, so fused
    /// Dense→activation forwards are bitwise-equal to the eager pair.
    pub(crate) fn record_lazy(&self, x: &LazyTensor) -> Option<LazyTensor> {
        match self {
            Activation::Relu => Some(x.relu()),
            Activation::Sigmoid => Some(x.sigmoid()),
            Activation::Tanh => Some(x.tanh()),
            Activation::Gelu => Some(x.gelu()),
            Activation::LeakyRelu(a) => Some(x.leaky_relu(*a)),
            Activation::Identity => None,
        }
    }
}

impl Module for Activation {
    fn forward(&self, x: &Var, _train: bool) -> Result<Var> {
        Ok(self.apply(x))
    }

    fn parameters(&self) -> Vec<Var> {
        Vec::new()
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn apply_matches_tensor_ops() {
        let x = Var::from_tensor(
            Tensor::from_vec(vec![-1.0, 0.5], &[2]).unwrap(),
            false,
        );
        assert_eq!(
            Activation::Relu.apply(&x).data().to_vec(),
            vec![0.0, 0.5]
        );
        assert_eq!(
            Activation::Identity.apply(&x).data().to_vec(),
            vec![-1.0, 0.5]
        );
        let s = Activation::Sigmoid.apply(&x).data().to_vec();
        assert!((s[1] - 0.6225).abs() < 1e-3);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Activation::parse("ReLU"), Some(Activation::Relu));
        assert_eq!(Activation::parse("gelu"), Some(Activation::Gelu));
        assert_eq!(Activation::parse("none"), Some(Activation::Identity));
        assert_eq!(Activation::parse("bogus"), None);
    }

    #[test]
    fn no_parameters() {
        assert!(Activation::Tanh.parameters().is_empty());
    }
}
