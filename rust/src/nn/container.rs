//! Sequential container: chains modules, mirroring `torch.nn.Sequential`
//! — with **fusion by default**: adjacent Dense→activation pairs forward
//! as one fused region (matmul, then bias-add + nonlinearity in a single
//! exec dispatch with a single pooled output) instead of one kernel per
//! op. Outputs and gradients are bitwise-equal to the unfused chain;
//! `MINITENSOR_NO_FUSION=1` (or `graph::set_nn_fusion_enabled(false)`)
//! restores the op-per-kernel path.

use super::{Activation, Dense, Module};
use crate::autograd::Var;
use crate::error::Result;

/// An ordered chain of modules applied front to back.
pub struct Sequential {
    layers: Vec<Box<dyn Module>>,
}

impl Sequential {
    /// Empty container.
    pub fn new() -> Sequential {
        Sequential { layers: Vec::new() }
    }

    /// Builder-style push.
    pub fn add(mut self, layer: impl Module + 'static) -> Sequential {
        self.layers.push(Box::new(layer));
        self
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// True when the container has no layers.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }
}

impl Default for Sequential {
    fn default() -> Self {
        Sequential::new()
    }
}

impl Sequential {
    /// The fusion peephole: when layer `i` is a [`Dense`] and layer
    /// `i + 1` a fusable [`Activation`], return the pair.
    fn fusable_pair(&self, i: usize) -> Option<(&Dense, &Activation)> {
        let dense = self
            .layers
            .get(i)?
            .as_any()
            .and_then(|a| a.downcast_ref::<Dense>())?;
        let act = self
            .layers
            .get(i + 1)?
            .as_any()
            .and_then(|a| a.downcast_ref::<Activation>())?;
        Some((dense, act))
    }
}

impl Module for Sequential {
    fn forward(&self, x: &Var, train: bool) -> Result<Var> {
        let fuse = crate::graph::nn_fusion_enabled();
        let mut cur = x.clone();
        let mut i = 0;
        while i < self.layers.len() {
            if fuse {
                if let Some((dense, act)) = self.fusable_pair(i) {
                    if let Some(y) = dense.forward_fused(&cur, act)? {
                        cur = y;
                        i += 2;
                        continue;
                    }
                }
            }
            cur = self.layers[i].forward(&cur, train)?;
            i += 1;
        }
        Ok(cur)
    }

    fn parameters(&self) -> Vec<Var> {
        self.layers.iter().flat_map(|l| l.parameters()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::{Activation, Dense};
    use crate::tensor::Tensor;

    #[test]
    fn chains_layers() {
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 2, &mut rng));
        assert_eq!(model.len(), 3);
        let x = Var::from_tensor(Tensor::ones(&[3, 4]), false);
        let y = model.forward(&x, true).unwrap();
        assert_eq!(y.dims(), vec![3, 2]);
    }

    #[test]
    fn collects_all_parameters() {
        let mut rng = Rng::new(2);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Tanh)
            .add(Dense::new(8, 2, &mut rng));
        assert_eq!(model.parameters().len(), 4); // two weights + two biases
        assert_eq!(model.num_parameters(), 4 * 8 + 8 + 8 * 2 + 2);
    }

    #[test]
    fn empty_is_identity() {
        let model = Sequential::new();
        assert!(model.is_empty());
        let x = Var::from_tensor(Tensor::ones(&[2]), false);
        let y = model.forward(&x, true).unwrap();
        assert_eq!(y.data().to_vec(), vec![1.0, 1.0]);
    }

    #[test]
    fn fused_forward_matches_unfused_bitwise() {
        // Same model, fusion on vs off: outputs and every parameter
        // gradient must be bit-identical (the fused region applies the
        // same scalar ops in the same order).
        let mut rng = Rng::new(7);
        let model = Sequential::new()
            .add(Dense::new(5, 8, &mut rng))
            .add(Activation::Gelu)
            .add(Dense::new(8, 3, &mut rng))
            .add(Activation::LeakyRelu(0.05));
        let x = Var::from_tensor(Tensor::randn(&[4, 5], 0.0, 1.0, &mut rng), false);
        let _guard = crate::graph::nn_fusion_test_lock();
        let run = |fuse: bool| {
            crate::graph::set_nn_fusion_enabled(fuse);
            model.zero_grad();
            let y = model.forward(&x, true).unwrap();
            y.square().sum().unwrap().backward().unwrap();
            let grads: Vec<Vec<u32>> = model
                .parameters()
                .iter()
                .map(|p| p.grad().unwrap().to_vec().iter().map(|v| v.to_bits()).collect())
                .collect();
            let out: Vec<u32> = y.data().to_vec().iter().map(|v| v.to_bits()).collect();
            (out, grads)
        };
        let initial = crate::graph::nn_fusion_enabled();
        let (yf, gf) = run(true);
        let (ye, ge) = run(false);
        crate::graph::set_nn_fusion_enabled(initial);
        assert_eq!(yf, ye, "fused forward == eager forward, bit for bit");
        assert_eq!(gf, ge, "fused gradients == eager gradients, bit for bit");
    }

    #[test]
    fn identity_and_no_bias_pairs_fall_back_to_eager() {
        let mut rng = Rng::new(8);
        let model = Sequential::new()
            .add(Dense::new_no_bias(4, 4, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(4, 2, &mut rng))
            .add(Activation::Identity);
        let x = Var::from_tensor(Tensor::ones(&[2, 4]), false);
        let y = model.forward(&x, true).unwrap();
        assert_eq!(y.dims(), vec![2, 2]);
    }

    #[test]
    fn end_to_end_gradients() {
        let mut rng = Rng::new(3);
        let model = Sequential::new()
            .add(Dense::new(2, 4, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(4, 1, &mut rng));
        let x = Var::from_tensor(Tensor::ones(&[5, 2]), false);
        let loss = model.forward(&x, true).unwrap().square().sum().unwrap();
        loss.backward().unwrap();
        for p in model.parameters() {
            assert!(p.grad().is_some(), "missing grad for {p:?}");
        }
    }
}
