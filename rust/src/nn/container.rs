//! Sequential container: chains modules, mirroring `torch.nn.Sequential`.

use super::Module;
use crate::autograd::Var;
use crate::error::Result;

/// An ordered chain of modules applied front to back.
pub struct Sequential {
    layers: Vec<Box<dyn Module>>,
}

impl Sequential {
    /// Empty container.
    pub fn new() -> Sequential {
        Sequential { layers: Vec::new() }
    }

    /// Builder-style push.
    pub fn add(mut self, layer: impl Module + 'static) -> Sequential {
        self.layers.push(Box::new(layer));
        self
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// True when the container has no layers.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }
}

impl Default for Sequential {
    fn default() -> Self {
        Sequential::new()
    }
}

impl Module for Sequential {
    fn forward(&self, x: &Var, train: bool) -> Result<Var> {
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = layer.forward(&cur, train)?;
        }
        Ok(cur)
    }

    fn parameters(&self) -> Vec<Var> {
        self.layers.iter().flat_map(|l| l.parameters()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::{Activation, Dense};
    use crate::tensor::Tensor;

    #[test]
    fn chains_layers() {
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 2, &mut rng));
        assert_eq!(model.len(), 3);
        let x = Var::from_tensor(Tensor::ones(&[3, 4]), false);
        let y = model.forward(&x, true).unwrap();
        assert_eq!(y.dims(), vec![3, 2]);
    }

    #[test]
    fn collects_all_parameters() {
        let mut rng = Rng::new(2);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Tanh)
            .add(Dense::new(8, 2, &mut rng));
        assert_eq!(model.parameters().len(), 4); // two weights + two biases
        assert_eq!(model.num_parameters(), 4 * 8 + 8 + 8 * 2 + 2);
    }

    #[test]
    fn empty_is_identity() {
        let model = Sequential::new();
        assert!(model.is_empty());
        let x = Var::from_tensor(Tensor::ones(&[2]), false);
        let y = model.forward(&x, true).unwrap();
        assert_eq!(y.data().to_vec(), vec![1.0, 1.0]);
    }

    #[test]
    fn end_to_end_gradients() {
        let mut rng = Rng::new(3);
        let model = Sequential::new()
            .add(Dense::new(2, 4, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(4, 1, &mut rng));
        let x = Var::from_tensor(Tensor::ones(&[5, 2]), false);
        let loss = model.forward(&x, true).unwrap().square().sum().unwrap();
        loss.backward().unwrap();
        for p in model.parameters() {
            assert!(p.grad().is_some(), "missing grad for {p:?}");
        }
    }
}
