//! Convolutional layer module wrapping `ops::conv` (paper eq 6).

use super::{kaiming_uniform, Module};
use crate::autograd::Var;
use crate::data::Rng;
use crate::error::Result;
use crate::ops::conv::Conv2dSpec;
use crate::tensor::Tensor;

/// 2-D convolution layer, NCHW, square kernels.
pub struct Conv2d {
    /// Weight `[c_out, c_in, k, k]`.
    pub weight: Var,
    /// Optional bias `[c_out]`.
    pub bias: Option<Var>,
    spec: Conv2dSpec,
    c_in: usize,
    c_out: usize,
    k: usize,
}

impl Conv2d {
    /// Kaiming-initialized conv layer.
    pub fn new(
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        padding: usize,
        rng: &mut Rng,
    ) -> Conv2d {
        let fan_in = c_in * k * k;
        Conv2d {
            weight: Var::from_tensor(
                kaiming_uniform(&[c_out, c_in, k, k], fan_in, rng),
                true,
            ),
            bias: Some(Var::from_tensor(Tensor::zeros(&[c_out]), true)),
            spec: Conv2dSpec { stride, padding },
            c_in,
            c_out,
            k,
        }
    }

    /// Geometry of this layer.
    pub fn spec(&self) -> Conv2dSpec {
        self.spec
    }

    /// (c_in, c_out, kernel).
    pub fn geometry(&self) -> (usize, usize, usize) {
        (self.c_in, self.c_out, self.k)
    }
}

impl Module for Conv2d {
    fn forward(&self, x: &Var, _train: bool) -> Result<Var> {
        let y = x.conv2d(&self.weight, self.spec)?;
        match &self.bias {
            Some(b) => {
                // bias [c_out] broadcasts over [n, c_out, oh, ow]: reshape
                // to [c_out, 1, 1] so right-aligned broadcasting applies.
                let c = y.dims()[1];
                let b3 = b.reshape(&[c, 1, 1])?;
                y.add(&b3)
            }
            None => Ok(y),
        }
    }

    fn parameters(&self) -> Vec<Var> {
        let mut ps = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            ps.push(b.clone());
        }
        ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape_and_bias() {
        let mut rng = Rng::new(1);
        let conv = Conv2d::new(3, 8, 3, 1, 1, &mut rng);
        let x = Var::from_tensor(Tensor::zeros(&[2, 3, 16, 16]), false);
        let y = conv.forward(&x, true).unwrap();
        assert_eq!(y.dims(), vec![2, 8, 16, 16]);
        // zero input ⇒ output equals broadcast bias (zeros by default)
        assert!(y.data().allclose(&Tensor::zeros(&[2, 8, 16, 16]), 1e-6, 1e-6));

        conv.bias
            .as_ref()
            .unwrap()
            .set_data(Tensor::full(&[8], 0.5));
        let y2 = conv.forward(&x, true).unwrap();
        assert!(y2.data().allclose(&Tensor::full(&[2, 8, 16, 16], 0.5), 1e-6, 1e-6));
    }

    #[test]
    fn parameter_count() {
        let mut rng = Rng::new(2);
        let conv = Conv2d::new(3, 16, 5, 1, 2, &mut rng);
        assert_eq!(conv.num_parameters(), 16 * 3 * 5 * 5 + 16);
    }

    #[test]
    fn gradients_reach_weight_and_bias() {
        let mut rng = Rng::new(3);
        let conv = Conv2d::new(1, 2, 3, 1, 1, &mut rng);
        let x = Var::from_tensor(Tensor::randn(&[1, 1, 6, 6], 0.0, 1.0, &mut rng), true);
        conv.forward(&x, true)
            .unwrap()
            .square()
            .sum()
            .unwrap()
            .backward()
            .unwrap();
        assert!(conv.weight.grad().is_some());
        assert!(conv.bias.as_ref().unwrap().grad().is_some());
        assert!(x.grad().is_some());
    }

    #[test]
    fn gradcheck_small_conv() {
        let mut rng = Rng::new(4);
        let conv = Conv2d::new(1, 1, 3, 1, 1, &mut rng);
        let x0 = Tensor::randn(&[1, 1, 4, 4], 0.0, 1.0, &mut rng);
        let report = crate::autograd::gradcheck(
            |v| conv.forward(v, true)?.square().sum(),
            &x0,
            1e-2,
            2e-2,
        )
        .unwrap();
        assert!(report.pass, "{report:?}");
    }
}
