//! Dropout (paper §3.3): elementwise Bernoulli mask during training with
//! inverted scaling (`1/(1-p)`), identity at inference.

use std::cell::RefCell;

use super::Module;
use crate::autograd::Var;
use crate::data::Rng;
use crate::error::Result;
use crate::tensor::Tensor;

/// Inverted dropout layer.
pub struct Dropout {
    p: f32,
    rng: RefCell<Rng>,
}

impl Dropout {
    /// Drop probability `p ∈ [0, 1)`.
    pub fn new(p: f32, seed: u64) -> Dropout {
        assert!((0.0..1.0).contains(&p), "dropout p must be in [0,1)");
        Dropout {
            p,
            rng: RefCell::new(Rng::new(seed)),
        }
    }

    /// The drop probability.
    pub fn p(&self) -> f32 {
        self.p
    }
}

impl Module for Dropout {
    fn forward(&self, x: &Var, train: bool) -> Result<Var> {
        if !train || self.p == 0.0 {
            return Ok(x.clone());
        }
        let keep = 1.0 - self.p;
        let scale = 1.0 / keep;
        let mut rng = self.rng.borrow_mut();
        let dims = x.dims();
        let mask_data: Vec<f32> = (0..dims.iter().product::<usize>())
            .map(|_| if rng.next_f32() < keep { scale } else { 0.0 })
            .collect();
        let mask = Tensor::from_vec(mask_data, &dims)?;
        x.mul_mask(&mask)
    }

    fn parameters(&self) -> Vec<Var> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_mode_is_identity() {
        let d = Dropout::new(0.5, 1);
        let x = Var::from_tensor(Tensor::ones(&[100]), false);
        let y = d.forward(&x, false).unwrap();
        assert_eq!(y.data().to_vec(), vec![1.0; 100]);
    }

    #[test]
    fn train_mode_zeroes_and_scales() {
        let d = Dropout::new(0.5, 2);
        let x = Var::from_tensor(Tensor::ones(&[10000]), false);
        let y = d.forward(&x, true).unwrap().data();
        let zeros = y.iter().filter(|&v| v == 0.0).count();
        let kept = y.iter().filter(|&v| (v - 2.0).abs() < 1e-6).count();
        assert_eq!(zeros + kept, 10000);
        assert!((zeros as f32 / 10000.0 - 0.5).abs() < 0.05);
        // expectation preserved
        let mean = y.mean().item().unwrap();
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn p_zero_is_identity_even_in_train() {
        let d = Dropout::new(0.0, 3);
        let x = Var::from_tensor(Tensor::ones(&[10]), false);
        assert_eq!(d.forward(&x, true).unwrap().data().to_vec(), vec![1.0; 10]);
    }

    #[test]
    fn gradient_flows_through_mask() {
        let d = Dropout::new(0.5, 4);
        let x = Var::from_tensor(Tensor::ones(&[100]), true);
        let y = d.forward(&x, true).unwrap();
        y.sum().unwrap().backward().unwrap();
        let g = x.grad().unwrap();
        // gradient is exactly the mask
        assert!(g.iter().all(|v| v == 0.0 || (v - 2.0).abs() < 1e-6));
    }
}
