//! Embedding layer: a learnable lookup table with the scatter-add
//! pullback (the sparse-gradient pattern the paper's §7 "batched Rust
//! kernels" roadmap points at).

use super::Module;
use crate::autograd::Var;
use crate::data::Rng;
use crate::error::Result;
use crate::tensor::Tensor;

/// `Embedding(V, D)`: maps i32 token ids `[n]` to vectors `[n, D]`.
pub struct Embedding {
    /// Table `[vocab, dim]`.
    pub weight: Var,
    vocab: usize,
    dim: usize,
}

impl Embedding {
    /// N(0, 0.02) initialized table (the usual transformer init).
    pub fn new(vocab: usize, dim: usize, rng: &mut Rng) -> Embedding {
        Embedding {
            weight: Var::from_tensor(Tensor::randn(&[vocab, dim], 0.0, 0.02, rng), true),
            vocab,
            dim,
        }
    }

    /// Look up a batch of ids, recording the scatter-add pullback.
    pub fn lookup(&self, ids: &Tensor) -> Result<Var> {
        self.weight.gather_rows(ids, self.vocab)
    }

    /// (vocab, dim).
    pub fn geometry(&self) -> (usize, usize) {
        (self.vocab, self.dim)
    }
}

impl Module for Embedding {
    fn forward(&self, x: &Var, _train: bool) -> Result<Var> {
        self.lookup(&x.data())
    }

    fn parameters(&self) -> Vec<Var> {
        vec![self.weight.clone()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_shapes_and_values() {
        let mut rng = Rng::new(1);
        let emb = Embedding::new(10, 4, &mut rng);
        let ids = Tensor::from_vec_i32(vec![3, 3, 7], &[3]).unwrap();
        let out = emb.lookup(&ids).unwrap();
        assert_eq!(out.dims(), vec![3, 4]);
        // rows 0 and 1 identical (same id)
        let v = out.data();
        assert_eq!(v.row(0).unwrap().to_vec(), v.row(1).unwrap().to_vec());
        assert_eq!(emb.num_parameters(), 40);
    }

    #[test]
    fn gradient_scatters_to_used_rows_only() {
        let mut rng = Rng::new(2);
        let emb = Embedding::new(5, 2, &mut rng);
        let ids = Tensor::from_vec_i32(vec![1, 1, 4], &[3]).unwrap();
        let out = emb.lookup(&ids).unwrap();
        out.sum().unwrap().backward().unwrap();
        let g = emb.weight.grad().unwrap();
        assert_eq!(g.dims(), &[5, 2]);
        // row 1 used twice → grad 2; row 4 once → 1; others 0
        assert_eq!(g.row(0).unwrap().to_vec(), vec![0.0, 0.0]);
        assert_eq!(g.row(1).unwrap().to_vec(), vec![2.0, 2.0]);
        assert_eq!(g.row(4).unwrap().to_vec(), vec![1.0, 1.0]);
    }

    #[test]
    fn embedding_trains_to_separate_classes() {
        // Learn embeddings such that id 0 → positive, id 1 → negative.
        use crate::optim::{Optimizer, Sgd};
        let mut rng = Rng::new(3);
        let emb = Embedding::new(2, 1, &mut rng);
        let mut opt = Sgd::new(emb.parameters(), 0.5);
        let ids = Tensor::from_vec_i32(vec![0, 1], &[2]).unwrap();
        let target = Tensor::from_vec(vec![1.0, -1.0], &[2, 1]).unwrap();
        for _ in 0..100 {
            opt.zero_grad();
            let out = emb.lookup(&ids).unwrap();
            let loss = crate::nn::losses::mse(&out, &target).unwrap();
            loss.backward().unwrap();
            opt.step().unwrap();
        }
        let w = emb.weight.data();
        assert!(w.at(&[0, 0]).unwrap() > 0.8);
        assert!(w.at(&[1, 0]).unwrap() < -0.8);
    }
}
