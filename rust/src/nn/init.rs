//! Weight initialization schemes.

use crate::data::Rng;
use crate::tensor::Tensor;

/// Xavier/Glorot uniform: `U(-a, a)` with `a = √(6 / (fan_in + fan_out))`.
/// The default for tanh/sigmoid networks.
pub fn xavier_uniform(dims: &[usize], fan_in: usize, fan_out: usize, rng: &mut Rng) -> Tensor {
    let a = (6.0 / (fan_in + fan_out) as f32).sqrt();
    Tensor::rand(dims, -a, a, rng)
}

/// Kaiming/He uniform: `U(-a, a)` with `a = √(6 / fan_in)`, for ReLU
/// networks.
pub fn kaiming_uniform(dims: &[usize], fan_in: usize, rng: &mut Rng) -> Tensor {
    let a = (6.0 / fan_in as f32).sqrt();
    Tensor::rand(dims, -a, a, rng)
}

/// Plain Gaussian initialization.
pub fn normal_init(dims: &[usize], std: f32, rng: &mut Rng) -> Tensor {
    Tensor::randn(dims, 0.0, std, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xavier_bound() {
        let mut rng = Rng::new(1);
        let w = xavier_uniform(&[100, 50], 50, 100, &mut rng);
        let a = (6.0f32 / 150.0).sqrt();
        assert!(w.iter().all(|v| v.abs() <= a));
        // not degenerate
        let var = w.var_axis(0, false).unwrap().mean().item().unwrap();
        assert!(var > 0.0);
    }

    #[test]
    fn kaiming_bound() {
        let mut rng = Rng::new(2);
        let w = kaiming_uniform(&[64, 32], 32, &mut rng);
        let a = (6.0f32 / 32.0).sqrt();
        assert!(w.iter().all(|v| v.abs() <= a));
    }

    #[test]
    fn normal_std() {
        let mut rng = Rng::new(3);
        let w = normal_init(&[10000], 0.02, &mut rng);
        let v = w.to_vec();
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        let std = (v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32).sqrt();
        assert!((std - 0.02).abs() < 0.002);
    }
}
