//! Fully-connected (dense) layer, paper eq (5):
//! `Dense(x; W, b) = x Wᵀ + 1 bᵀ` with `W ∈ R^{d_out × d_in}`.

use super::{kaiming_uniform, Activation, Module};
use crate::autograd::Var;
use crate::data::Rng;
use crate::error::Result;
use crate::tensor::Tensor;

/// Dense / fully-connected layer.
pub struct Dense {
    /// Weight `[d_out, d_in]` (PyTorch layout — rows are output features).
    pub weight: Var,
    /// Optional bias `[d_out]`.
    pub bias: Option<Var>,
    d_in: usize,
    d_out: usize,
}

impl Dense {
    /// Kaiming-initialized dense layer with bias.
    pub fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> Dense {
        Dense {
            weight: Var::from_tensor(kaiming_uniform(&[d_out, d_in], d_in, rng), true),
            bias: Some(Var::from_tensor(Tensor::zeros(&[d_out]), true)),
            d_in,
            d_out,
        }
    }

    /// Dense layer without bias.
    pub fn new_no_bias(d_in: usize, d_out: usize, rng: &mut Rng) -> Dense {
        Dense {
            weight: Var::from_tensor(kaiming_uniform(&[d_out, d_in], d_in, rng), true),
            bias: None,
            d_in,
            d_out,
        }
    }

    /// Build from explicit tensors (tests / loading).
    pub fn from_tensors(weight: Tensor, bias: Option<Tensor>) -> Dense {
        let d_out = weight.dims()[0];
        let d_in = weight.dims()[1];
        Dense {
            weight: Var::from_tensor(weight, true),
            bias: bias.map(|b| Var::from_tensor(b, true)),
            d_in,
            d_out,
        }
    }

    /// Input feature count.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output feature count.
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Dense forward with the following activation **fused**: the bias
    /// add and the nonlinearity run as one lazy region — one exec
    /// dispatch, one pooled output — instead of two eager kernels, with
    /// `Var::fused` keeping the pair differentiable (the VJP replay
    /// applies the same pullback rules as the eager tape). Returns
    /// `Ok(None)` when there is nothing to fuse (no bias, or an Identity
    /// activation), in which case the caller should take the eager path.
    /// Outputs and gradients are bitwise-equal to the eager
    /// `forward` + `activation` pair — the fused kernel applies the same
    /// scalar functions in the same per-element order.
    pub fn forward_fused(&self, x: &Var, act: &Activation) -> Result<Option<Var>> {
        let Some(bias) = &self.bias else {
            return Ok(None);
        };
        if matches!(act, Activation::Identity) {
            return Ok(None);
        }
        let y = x.matmul_nt(&self.weight)?; // x Wᵀ (eq 1/5)
        let fused = Var::fused(&[&y, bias], |l| {
            let with_bias = l[0].add(&l[1])?;
            Ok(act
                .record_lazy(&with_bias)
                .expect("non-Identity activation records"))
        })?;
        Ok(Some(fused))
    }
}

impl Module for Dense {
    fn forward(&self, x: &Var, _train: bool) -> Result<Var> {
        let y = x.matmul_nt(&self.weight)?; // x Wᵀ (eq 1/5)
        match &self.bias {
            Some(b) => y.add(b), // broadcasts [d_out] over the batch
            None => Ok(y),
        }
    }

    fn parameters(&self) -> Vec<Var> {
        let mut ps = vec![self.weight.clone()];
        if let Some(b) = &self.bias {
            ps.push(b.clone());
        }
        ps
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::gradcheck;

    #[test]
    fn forward_matches_equation5() {
        // W = [[1,2],[3,4],[5,6]] (3 out, 2 in), b = [10, 20, 30]
        let w = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[3, 2]).unwrap();
        let b = Tensor::from_vec(vec![10., 20., 30.], &[3]).unwrap();
        let layer = Dense::from_tensors(w, Some(b));
        let x = Var::from_tensor(Tensor::from_vec(vec![1., 1.], &[1, 2]).unwrap(), false);
        let y = layer.forward(&x, true).unwrap();
        assert_eq!(y.data().to_vec(), vec![3. + 10., 7. + 20., 11. + 30.]);
    }

    #[test]
    fn parameter_count() {
        let mut rng = Rng::new(1);
        let layer = Dense::new(784, 128, &mut rng);
        assert_eq!(layer.num_parameters(), 784 * 128 + 128);
        let nb = Dense::new_no_bias(10, 5, &mut rng);
        assert_eq!(nb.num_parameters(), 50);
    }

    #[test]
    fn gradcheck_weight_and_input() {
        let mut rng = Rng::new(2);
        let layer = Dense::new(4, 3, &mut rng);
        let x0 = Tensor::randn(&[2, 4], 0.0, 1.0, &mut rng);

        // w.r.t. input
        let report = gradcheck(
            |v| layer.forward(v, true)?.square().sum(),
            &x0,
            1e-3,
            1e-2,
        )
        .unwrap();
        assert!(report.pass, "{report:?}");

        // w.r.t. weight: rebuild a layer around the probed weight tensor
        let bias = layer.bias.as_ref().unwrap().data();
        let x_fixed = x0.clone();
        let report_w = gradcheck(
            |w| {
                let l = Dense {
                    weight: w.clone(),
                    bias: Some(Var::from_tensor(bias.clone(), false)),
                    d_in: 4,
                    d_out: 3,
                };
                l.forward(&Var::from_tensor(x_fixed.clone(), false), true)?
                    .square()
                    .sum()
            },
            &layer.weight.data(),
            1e-3,
            1e-2,
        )
        .unwrap();
        assert!(report_w.pass, "{report_w:?}");
    }

    #[test]
    fn bias_grad_sums_over_batch() {
        let mut rng = Rng::new(3);
        let layer = Dense::new(2, 2, &mut rng);
        let x = Var::from_tensor(Tensor::ones(&[5, 2]), false);
        layer.forward(&x, true).unwrap().sum().unwrap().backward().unwrap();
        let gb = layer.bias.as_ref().unwrap().grad().unwrap();
        assert_eq!(gb.to_vec(), vec![5.0, 5.0]);
    }

    #[test]
    fn zero_grad_clears() {
        let mut rng = Rng::new(4);
        let layer = Dense::new(2, 2, &mut rng);
        let x = Var::from_tensor(Tensor::ones(&[1, 2]), false);
        layer.forward(&x, true).unwrap().sum().unwrap().backward().unwrap();
        assert!(layer.weight.grad().is_some());
        layer.zero_grad();
        assert!(layer.weight.grad().is_none());
    }
}
