//! Loss functions (paper §3.3): cross-entropy (eq 8), MSE, and binary
//! cross-entropy.
//!
//! MSE and BCE build **fused lazy expressions** by default (see
//! `graph::nn_fusion_enabled`): the whole elementwise pipeline plus the
//! mean epilogue runs as one or a few exec dispatches with no
//! intermediate loss tensors, and `Var::fused` keeps it differentiable.
//! Values and gradients are bitwise-equal to the eager op chains (same
//! scalar functions, same per-element order, same fixed-partition
//! reduction); `MINITENSOR_NO_FUSION=1` restores the eager path.

use crate::autograd::Var;
use crate::error::Result;
use crate::graph::nn_fusion_enabled;
use crate::tensor::Tensor;

/// Mean cross-entropy over logits `[b, C]` and integer labels `[b]`
/// (eq 8). Fused softmax + NLL; the pullback is `(softmax − onehot)/b`.
pub fn cross_entropy(logits: &Var, labels: &Tensor) -> Result<Var> {
    logits.cross_entropy(labels)
}

/// Mean squared error `L = 1/N Σ (x − x̂)²` — one fused
/// sub→square→mean dispatch by default.
pub fn mse(pred: &Var, target: &Tensor) -> Result<Var> {
    let t = Var::from_tensor(target.clone(), false);
    if nn_fusion_enabled() {
        return Var::fused(&[pred, &t], |l| Ok(l[0].sub(&l[1])?.square().mean()));
    }
    pred.sub(&t)?.square().mean()
}

/// Binary cross-entropy on probabilities `p ∈ (0,1)` against 0/1 targets,
/// with clamping for numerical safety (the clamp bounds are tape
/// immediates on the fused path — no mask tensors).
pub fn bce(prob: &Var, target: &Tensor) -> Result<Var> {
    let t = Var::from_tensor(target.clone(), false);
    let one_minus_t = Var::from_tensor(target.map(|v| 1.0 - v), false);
    if nn_fusion_enabled() {
        // −[t log p + (1−t) log(1−p)] — the clamped p is shared by both
        // branches, so it materializes once; everything else fuses into
        // the mean epilogue.
        return Var::fused(&[prob, &t, &one_minus_t], |l| {
            let p = l[0].clamp(1e-7, 1.0 - 1e-7);
            let pos = l[1].mul(&p.log())?;
            let neg_p = p.mul_scalar(-1.0).add_scalar(1.0);
            let neg = l[2].mul(&neg_p.log())?;
            Ok(pos.add(&neg)?.mean().mul_scalar(-1.0))
        });
    }
    let p = prob.clamp(1e-7, 1.0 - 1e-7);
    // −[t log p + (1−t) log(1−p)]
    let pos = t.mul(&p.log())?;
    let neg_p = p.mul_scalar(-1.0).add_scalar(1.0);
    let neg = one_minus_t.mul(&neg_p.log())?;
    Ok(pos.add(&neg)?.mean()?.mul_scalar(-1.0))
}

/// Classification accuracy of logits `[b, C]` against labels `[b]`
/// (metric, not differentiable).
pub fn accuracy(logits: &Tensor, labels: &Tensor) -> Result<f32> {
    let pred = logits.argmax_axis(1)?;
    let correct = pred
        .iter()
        .zip(labels.iter())
        .filter(|(p, y)| p == y)
        .count();
    Ok(correct as f32 / labels.numel() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::gradcheck;
    use crate::data::Rng;

    #[test]
    fn mse_zero_for_exact_prediction() {
        let t = Tensor::from_vec(vec![1.0, 2.0], &[2]).unwrap();
        let p = Var::from_tensor(t.clone(), true);
        let l = mse(&p, &t).unwrap();
        assert_eq!(l.item().unwrap(), 0.0);
    }

    #[test]
    fn mse_value_and_gradcheck() {
        let target = Tensor::from_vec(vec![0.0, 0.0], &[2]).unwrap();
        let p = Var::from_tensor(Tensor::from_vec(vec![1.0, 3.0], &[2]).unwrap(), true);
        let l = mse(&p, &target).unwrap();
        assert!((l.item().unwrap() - 5.0).abs() < 1e-6); // (1+9)/2

        let mut rng = Rng::new(1);
        let x0 = Tensor::randn(&[4, 3], 0.0, 1.0, &mut rng);
        let tgt = Tensor::randn(&[4, 3], 0.0, 1.0, &mut rng);
        let report = gradcheck(|v| mse(v, &tgt), &x0, 1e-3, 1e-2).unwrap();
        assert!(report.pass, "{report:?}");
    }

    #[test]
    fn cross_entropy_gradcheck() {
        let mut rng = Rng::new(2);
        let logits = Tensor::randn(&[5, 4], 0.0, 2.0, &mut rng);
        let labels = Tensor::from_vec_i32(vec![0, 1, 2, 3, 1], &[5]).unwrap();
        let report = gradcheck(|v| cross_entropy(v, &labels), &logits, 1e-3, 1e-2).unwrap();
        assert!(report.pass, "{report:?}");
    }

    #[test]
    fn bce_known_value() {
        // p = 0.5 everywhere ⇒ BCE = ln 2
        let p = Var::from_tensor(Tensor::full(&[4], 0.5), true);
        let t = Tensor::from_vec(vec![1.0, 0.0, 1.0, 0.0], &[4]).unwrap();
        let l = bce(&p, &t).unwrap();
        assert!((l.item().unwrap() - 2f32.ln()).abs() < 1e-5);
    }

    #[test]
    fn bce_gradcheck() {
        let p0 = Tensor::from_vec(vec![0.3, 0.7, 0.9, 0.2], &[4]).unwrap();
        let t = Tensor::from_vec(vec![0.0, 1.0, 1.0, 0.0], &[4]).unwrap();
        let report = gradcheck(|v| bce(v, &t), &p0, 1e-3, 1e-2).unwrap();
        assert!(report.pass, "{report:?}");
    }

    #[test]
    fn fused_losses_match_eager_bitwise() {
        // mse and bce, fusion on vs off: identical loss bits and
        // identical input-gradient bits (the fused expressions apply the
        // same scalar ops in the same order as the eager chains).
        let mut rng = Rng::new(9);
        let _guard = crate::graph::nn_fusion_test_lock();
        let initial = crate::graph::nn_fusion_enabled();
        let tgt = Tensor::randn(&[6, 4], 0.0, 1.0, &mut rng);
        let p0 = Tensor::randn(&[6, 4], 0.0, 1.0, &mut rng).sigmoid();
        let bt = tgt.map(|v| f32::from(v > 0.0));
        let run = |fuse: bool| {
            crate::graph::set_nn_fusion_enabled(fuse);
            let pm = Var::from_tensor(p0.clone(), true);
            let lm = mse(&pm, &tgt).unwrap();
            lm.backward().unwrap();
            let pb = Var::from_tensor(p0.clone(), true);
            let lb = bce(&pb, &bt).unwrap();
            lb.backward().unwrap();
            (
                lm.item().unwrap().to_bits(),
                pm.grad().unwrap().to_vec().iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                lb.item().unwrap().to_bits(),
                pb.grad().unwrap().to_vec().iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
            )
        };
        let fused = run(true);
        let eager = run(false);
        crate::graph::set_nn_fusion_enabled(initial);
        assert_eq!(fused.0, eager.0, "mse loss bits");
        assert_eq!(fused.1, eager.1, "mse grad bits");
        assert_eq!(fused.2, eager.2, "bce loss bits");
        assert_eq!(fused.3, eager.3, "bce grad bits");
    }

    #[test]
    fn accuracy_metric() {
        let logits = Tensor::from_vec(vec![2., 0., 1., 0., 3., 0.], &[2, 3]).unwrap();
        let labels = Tensor::from_vec_i32(vec![0, 1], &[2]).unwrap();
        assert_eq!(accuracy(&logits, &labels).unwrap(), 1.0);
        let wrong = Tensor::from_vec_i32(vec![1, 1], &[2]).unwrap();
        assert_eq!(accuracy(&logits, &wrong).unwrap(), 0.5);
    }
}
