//! Neural-network building blocks (paper §3.3): layers, activations,
//! normalization, dropout, losses, and initialization.

mod activation;
mod container;
mod conv;
mod dropout;
mod embedding;
mod init;
mod linear;
pub mod losses;
mod norm;
mod serialize;

pub use activation::Activation;
pub use container::Sequential;
pub use conv::Conv2d;
pub use dropout::Dropout;
pub use embedding::Embedding;
pub use init::{kaiming_uniform, normal_init, xavier_uniform};
pub use linear::Dense;
pub use norm::{BatchNorm1d, LayerNorm};
pub use serialize::{load_parameters, save_parameters};

use crate::autograd::Var;
use crate::error::Result;

/// A trainable component: forward pass over `Var`s plus parameter access.
///
/// Mirrors `torch.nn.Module`: parameters are shared `Var` handles, so an
/// optimizer holding the same handles sees gradients accumulated by
/// `backward()`.
pub trait Module {
    /// Forward pass. `train` toggles training-only behaviour (dropout,
    /// batch-norm statistics).
    fn forward(&self, x: &Var, train: bool) -> Result<Var>;

    /// All trainable parameters (leaf `Var`s with `requires_grad`).
    fn parameters(&self) -> Vec<Var>;

    /// Downcasting hook for container-level fusion peepholes:
    /// [`Sequential`] uses it to recognize Dense→activation pairs and
    /// fuse them into one dispatch (see `graph::nn_fusion_enabled`).
    /// Modules that never participate keep the `None` default.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }

    /// Total number of scalar parameters.
    fn num_parameters(&self) -> usize {
        self.parameters()
            .iter()
            .map(|p| p.data().numel())
            .sum()
    }

    /// Clear all parameter gradients.
    fn zero_grad(&self) {
        for p in self.parameters() {
            p.zero_grad();
        }
    }
}
