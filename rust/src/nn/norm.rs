//! Normalization layers (paper eq 7): BatchNorm over the batch axis with
//! learnable scale γ and shift β plus running statistics, and LayerNorm
//! over the feature axis.

use std::cell::RefCell;

use super::Module;
use crate::autograd::Var;
use crate::error::Result;
use crate::tensor::Tensor;

/// Batch normalization over `[b, d]` activations (eq 7):
/// `BN(x) = γ ⊙ (x − μ)/√(σ² + ε) + β`.
///
/// Training uses batch statistics (and updates the running averages);
/// inference uses the running averages. The normalization is expressed in
/// autograd primitives, so the pullback through μ and σ² is exact — no
/// hand-derived batchnorm backward needed.
pub struct BatchNorm1d {
    /// Learnable scale γ `[d]`.
    pub gamma: Var,
    /// Learnable shift β `[d]`.
    pub beta: Var,
    eps: f32,
    momentum: f32,
    running_mean: RefCell<Tensor>,
    running_var: RefCell<Tensor>,
    features: usize,
}

impl BatchNorm1d {
    /// BatchNorm over `features` channels with default ε=1e-5, momentum 0.1.
    pub fn new(features: usize) -> BatchNorm1d {
        BatchNorm1d {
            gamma: Var::from_tensor(Tensor::ones(&[features]), true),
            beta: Var::from_tensor(Tensor::zeros(&[features]), true),
            eps: 1e-5,
            momentum: 0.1,
            running_mean: RefCell::new(Tensor::zeros(&[features])),
            running_var: RefCell::new(Tensor::ones(&[features])),
            features,
        }
    }

    /// Current running mean (inference statistics).
    pub fn running_mean(&self) -> Tensor {
        self.running_mean.borrow().clone()
    }

    /// Current running variance.
    pub fn running_var(&self) -> Tensor {
        self.running_var.borrow().clone()
    }
}

impl Module for BatchNorm1d {
    fn forward(&self, x: &Var, train: bool) -> Result<Var> {
        if x.dims().len() != 2 || x.dims()[1] != self.features {
            return Err(crate::Error::ShapeMismatch {
                op: "batch_norm1d",
                expected: format!("[b, {}]", self.features),
                got: format!("{:?}", x.dims()),
            });
        }
        if train {
            // μ, σ² over the batch axis — recorded ops so grads are exact.
            let mu = x.mean_axis(0, true)?; // [1, d]
            let centered = x.sub(&mu)?;
            let var = centered.square().mean_axis(0, true)?; // [1, d]
            let inv_std = var.add_scalar(self.eps).sqrt().recip();
            let norm = centered.mul(&inv_std)?;

            // Update running stats (detached, unbiased variance).
            let b = x.dims()[0] as f32;
            let unbias = if b > 1.0 { b / (b - 1.0) } else { 1.0 };
            {
                let mut rm = self.running_mean.borrow_mut();
                *rm = rm
                    .mul_scalar(1.0 - self.momentum)
                    .add(&mu.data().squeeze().mul_scalar(self.momentum))?;
                let mut rv = self.running_var.borrow_mut();
                *rv = rv.mul_scalar(1.0 - self.momentum).add(
                    &var.data()
                        .squeeze()
                        .mul_scalar(self.momentum * unbias),
                )?;
            }

            norm.mul(&self.gamma)?.add(&self.beta)
        } else {
            // Inference: use running statistics as constants.
            let rm = self.running_mean.borrow().clone();
            let rv = self.running_var.borrow().clone();
            let inv_std = rv.add_scalar(self.eps).sqrt().recip();
            let scale = self.gamma.mul_mask(&inv_std)?;
            // y = γ/σ ⊙ x − γ/σ ⊙ μ + β
            let shifted = x.sub(&Var::from_tensor(rm, false))?;
            shifted.mul(&scale)?.add(&self.beta)
        }
    }

    fn parameters(&self) -> Vec<Var> {
        vec![self.gamma.clone(), self.beta.clone()]
    }
}

/// Layer normalization over the last axis with learnable γ, β.
pub struct LayerNorm {
    pub gamma: Var,
    pub beta: Var,
    eps: f32,
    features: usize,
}

impl LayerNorm {
    /// LayerNorm over `features`-sized last axis.
    pub fn new(features: usize) -> LayerNorm {
        LayerNorm {
            gamma: Var::from_tensor(Tensor::ones(&[features]), true),
            beta: Var::from_tensor(Tensor::zeros(&[features]), true),
            eps: 1e-5,
            features,
        }
    }
}

impl Module for LayerNorm {
    fn forward(&self, x: &Var, _train: bool) -> Result<Var> {
        let last = *x.dims().last().unwrap_or(&0);
        if last != self.features {
            return Err(crate::Error::ShapeMismatch {
                op: "layer_norm",
                expected: format!("last dim {}", self.features),
                got: format!("{:?}", x.dims()),
            });
        }
        let mu = x.mean_axis(-1, true)?;
        let centered = x.sub(&mu)?;
        let var = centered.square().mean_axis(-1, true)?;
        let inv_std = var.add_scalar(self.eps).sqrt().recip();
        centered.mul(&inv_std)?.mul(&self.gamma)?.add(&self.beta)
    }

    fn parameters(&self) -> Vec<Var> {
        vec![self.gamma.clone(), self.beta.clone()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn train_output_is_normalized() {
        let mut rng = Rng::new(1);
        let bn = BatchNorm1d::new(4);
        let x = Var::from_tensor(Tensor::randn(&[64, 4], 3.0, 2.0, &mut rng), false);
        let y = bn.forward(&x, true).unwrap().data();
        let mean = y.mean_axis(0, false).unwrap();
        let var = y.var_axis(0, false).unwrap();
        assert!(mean.allclose(&Tensor::zeros(&[4]), 1e-3, 1e-3), "{mean}");
        assert!(var.allclose(&Tensor::ones(&[4]), 1e-2, 1e-2), "{var}");
    }

    #[test]
    fn gamma_beta_affine() {
        let bn = BatchNorm1d::new(2);
        bn.gamma.set_data(Tensor::from_vec(vec![2.0, 2.0], &[2]).unwrap());
        bn.beta.set_data(Tensor::from_vec(vec![5.0, 5.0], &[2]).unwrap());
        let mut rng = Rng::new(2);
        let x = Var::from_tensor(Tensor::randn(&[32, 2], 0.0, 1.0, &mut rng), false);
        let y = bn.forward(&x, true).unwrap().data();
        let mean = y.mean_axis(0, false).unwrap();
        assert!(mean.allclose(&Tensor::full(&[2], 5.0), 1e-2, 1e-2));
    }

    #[test]
    fn running_stats_converge_to_data_stats() {
        let mut rng = Rng::new(3);
        let bn = BatchNorm1d::new(3);
        for _ in 0..200 {
            let x = Var::from_tensor(Tensor::randn(&[32, 3], 2.0, 1.5, &mut rng), false);
            bn.forward(&x, true).unwrap();
        }
        let rm = bn.running_mean();
        let rv = bn.running_var();
        assert!(rm.allclose(&Tensor::full(&[3], 2.0), 0.1, 0.15), "{rm}");
        assert!(rv.allclose(&Tensor::full(&[3], 2.25), 0.15, 0.3), "{rv}");
    }

    #[test]
    fn inference_uses_running_stats() {
        let bn = BatchNorm1d::new(1);
        // prime the running stats
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let x = Var::from_tensor(Tensor::randn(&[64, 1], 10.0, 1.0, &mut rng), false);
            bn.forward(&x, true).unwrap();
        }
        // a single far-off example at inference shouldn't be renormalized
        // by its own statistics
        let x = Var::from_tensor(Tensor::full(&[1, 1], 10.0), false);
        let y = bn.forward(&x, false).unwrap().data().item().unwrap();
        assert!(y.abs() < 0.5, "y={y}"); // ≈ (10-10)/1
    }

    #[test]
    fn batchnorm_gradients_flow() {
        let mut rng = Rng::new(5);
        let bn = BatchNorm1d::new(3);
        let x = Var::from_tensor(Tensor::randn(&[16, 3], 0.0, 1.0, &mut rng), true);
        let loss = bn.forward(&x, true).unwrap().square().sum().unwrap();
        loss.backward().unwrap();
        assert!(x.grad().is_some());
        assert!(bn.gamma.grad().is_some());
        assert!(bn.beta.grad().is_some());
    }

    #[test]
    fn shape_validation() {
        let bn = BatchNorm1d::new(3);
        let bad = Var::from_tensor(Tensor::zeros(&[4, 5]), false);
        assert!(bn.forward(&bad, true).is_err());
    }

    #[test]
    fn layernorm_rows_normalized() {
        let mut rng = Rng::new(6);
        let ln = LayerNorm::new(8);
        let x = Var::from_tensor(Tensor::randn(&[4, 8], -1.0, 3.0, &mut rng), false);
        let y = ln.forward(&x, true).unwrap().data();
        let mean = y.mean_axis(-1, false).unwrap();
        assert!(mean.allclose(&Tensor::zeros(&[4]), 1e-3, 1e-3));
        let var = y.var_axis(-1, false).unwrap();
        assert!(var.allclose(&Tensor::ones(&[4]), 1e-2, 1e-2));
    }

    #[test]
    fn layernorm_gradcheck() {
        let mut rng = Rng::new(7);
        let ln = LayerNorm::new(4);
        let x0 = Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng);
        let report = crate::autograd::gradcheck(
            |v| ln.forward(v, true)?.square().sum(),
            &x0,
            1e-2,
            2e-2,
        )
        .unwrap();
        assert!(report.pass, "{report:?}");
    }
}
