//! Parameter checkpointing: a tiny self-describing binary format
//! (magic + per-tensor rank/dims/data, little-endian f32), dependency-
//! free. Covers the "train, save, load, serve" workflow a downstream
//! user of the library needs.
//!
//! ```text
//! "MTCK" u32-version u32-count { u32-rank u32-dims[rank] f32-data[...] }*
//! ```

use std::io::{Read, Write};
use std::path::Path;

use crate::autograd::Var;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"MTCK";
const VERSION: u32 = 1;

/// Save parameters (in order) to a checkpoint file.
pub fn save_parameters(params: &[Var], path: impl AsRef<Path>) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(params.len() as u32).to_le_bytes())?;
    for p in params {
        let t = p.data().contiguous();
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.dims() {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for v in t.to_vec() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a checkpoint into existing parameters (shapes must match 1:1).
pub fn load_parameters(params: &[Var], path: impl AsRef<Path>) -> Result<()> {
    let tensors = read_checkpoint(path)?;
    if tensors.len() != params.len() {
        return Err(Error::msg(format!(
            "checkpoint has {} tensors, model has {} parameters",
            tensors.len(),
            params.len()
        )));
    }
    for (p, t) in params.iter().zip(tensors) {
        if p.data().dims() != t.dims() {
            return Err(Error::ShapeMismatch {
                op: "load_parameters",
                expected: format!("{:?}", p.data().dims()),
                got: format!("{:?}", t.dims()),
            });
        }
        p.set_data(t);
    }
    Ok(())
}

/// Read all tensors from a checkpoint file.
pub fn read_checkpoint(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::msg("not a MiniTensor checkpoint (bad magic)"));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::msg(format!("unsupported checkpoint version {version}")));
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = read_u32(&mut r)? as usize;
        if rank > 8 {
            return Err(Error::msg(format!("implausible tensor rank {rank}")));
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u32(&mut r)? as usize);
        }
        let numel: usize = dims.iter().product();
        let mut data = vec![0.0f32; numel];
        let mut buf = [0u8; 4];
        for v in data.iter_mut() {
            r.read_exact(&mut buf)?;
            *v = f32::from_le_bytes(buf);
        }
        out.push(Tensor::from_vec(data, &dims)?);
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::{Activation, Dense, Module, Sequential};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("minitensor_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_values() {
        let mut rng = Rng::new(1);
        let model = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 2, &mut rng));
        let path = tmpfile("roundtrip");
        save_parameters(&model.parameters(), &path).unwrap();

        let model2 = Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 2, &mut rng));
        // different init ⇒ different outputs before loading
        let x = crate::autograd::Var::from_tensor(Tensor::ones(&[1, 4]), false);
        let y1 = model.forward(&x, false).unwrap().data().to_vec();
        let y2_before = model2.forward(&x, false).unwrap().data().to_vec();
        assert_ne!(y1, y2_before);

        load_parameters(&model2.parameters(), &path).unwrap();
        let y2_after = model2.forward(&x, false).unwrap().data().to_vec();
        assert_eq!(y1, y2_after);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rng = Rng::new(2);
        let a = Dense::new(4, 8, &mut rng);
        let b = Dense::new(4, 9, &mut rng);
        let path = tmpfile("mismatch");
        save_parameters(&a.parameters(), &path).unwrap();
        assert!(load_parameters(&b.parameters(), &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn count_mismatch_rejected() {
        let mut rng = Rng::new(3);
        let a = Dense::new(2, 2, &mut rng);
        let path = tmpfile("count");
        save_parameters(&a.parameters(), &path).unwrap();
        let b = Dense::new_no_bias(2, 2, &mut rng);
        assert!(load_parameters(&b.parameters(), &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("magic");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
