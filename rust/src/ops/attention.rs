//! Native scaled-dot-product attention: `softmax(q kᵀ / √d) v`, forward
//! **and** backward.
//!
//! Both passes are compositions of execution-layer kernels — the
//! row-parallel fused `x·Wᵀ` product, the panel-parallel blocked SGEMM,
//! the row-parallel softmax, and chunk-parallel elementwise maps — so the
//! whole op (QK scores, softmax, V mix, and every gradient product) fans
//! out over the worker pool and is bit-identical at any
//! `MINITENSOR_NUM_THREADS` (each constituent kernel keeps per-element
//! accumulation order; the softmax pullback is row-local). The forward
//! saves the probability rows so the backward never re-runs the softmax,
//! and the 1/√d score scaling is fused into the softmax row kernel
//! (`softmax_scaled_lastdim`) — three dispatches total, no scaled-scores
//! intermediate, bitwise-equal to the unfused `mul_scalar` + `softmax`
//! pair. Every constituent kernel is instrumented, so `runtime::stats`
//! counts attention's launches through them.
//!
//! The XLA-AOT counterpart is the fused `attention_128x64` Pallas artifact
//! (see `python/compile/kernels/attention.py`), cross-checked in
//! `rust/tests/runtime_xla.rs`.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Single-head attention over `[seq_q, d]`, `[seq_k, d]`, `[seq_k, dv]`.
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
    attention_forward(q, k, v).map(|(out, _)| out)
}

/// Forward pass that also returns the softmax probability matrix
/// `P = softmax(q kᵀ / √d)` (`[seq_q, seq_k]`) — the residual
/// [`attention_backward`] consumes, saved exactly like the conv forward
/// saves its argmax indices.
pub fn attention_forward(q: &Tensor, k: &Tensor, v: &Tensor) -> Result<(Tensor, Tensor)> {
    if q.rank() != 2 || k.rank() != 2 || v.rank() != 2 {
        return Err(Error::ShapeMismatch {
            op: "attention",
            expected: "rank-2 q, k, v".into(),
            got: format!("{} {} {}", q.shape(), k.shape(), v.shape()),
        });
    }
    let d = q.dims()[1];
    if k.dims()[1] != d || v.dims()[0] != k.dims()[0] {
        return Err(Error::ShapeMismatch {
            op: "attention",
            expected: format!("k [n, {d}], v [n, dv]"),
            got: format!("{} {}", k.shape(), v.shape()),
        });
    }
    let scale = 1.0 / (d as f32).sqrt();
    // The 1/√d scaling runs inside the softmax row kernel (one dispatch,
    // no scaled-scores tensor) — bitwise-equal to mul_scalar + softmax.
    let scores = q.matmul_nt(k)?;
    let probs = crate::ops::softmax::softmax_scaled_lastdim(&scores, scale)?;
    let out = probs.matmul(v)?;
    Ok((out, probs))
}

/// Gradient of [`attention_forward`] w.r.t. `(q, k, v)` given the output
/// cotangent `grad_out` (`[seq_q, dv]`) and the saved `probs`.
///
/// With `P = softmax(S)`, `S = q kᵀ / √d`, `O = P v`:
///
/// ```text
/// v̄ = Pᵀ ḡ
/// P̄ = ḡ vᵀ
/// S̄ = (P̄ − rowsum(P̄ ⊙ P)) ⊙ P / √d     (row-local softmax pullback)
/// q̄ = S̄ k       k̄ = S̄ᵀ q
/// ```
///
/// Every product dispatches through the execution layer, so the gradients
/// inherit its determinism guarantee (bit-identical at any thread count).
pub fn attention_backward(
    grad_out: &Tensor,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
) -> Result<(Tensor, Tensor, Tensor)> {
    let d = q.dims()[1];
    let scale = 1.0 / (d as f32).sqrt();
    // v̄ = Pᵀ ḡ  [seq_k, dv]
    let dv = probs.t()?.matmul(grad_out)?;
    // P̄ = ḡ vᵀ  [seq_q, seq_k] — fused transpose via the x·Wᵀ kernel.
    let dp = grad_out.matmul_nt(v)?;
    // Softmax pullback, then undo the 1/√d scaling of the scores.
    let dot = dp.mul(probs)?.sum_axis(-1, true)?;
    let ds = dp.sub(&dot)?.mul(probs)?.mul_scalar(scale);
    // q̄ = S̄ k  [seq_q, d];  k̄ = S̄ᵀ q  [seq_k, d]
    let dq = ds.matmul(k)?;
    let dk = ds.t()?.matmul(q)?;
    Ok((dq, dk, dv))
}

impl Tensor {
    /// See [`attention`].
    pub fn attention(&self, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        attention(self, k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn uniform_keys_average_values() {
        let mut rng = Rng::new(1);
        let q = Tensor::randn(&[4, 8], 0.0, 1.0, &mut rng);
        let k = Tensor::ones(&[16, 8]);
        let v = Tensor::randn(&[16, 8], 0.0, 1.0, &mut rng);
        let out = q.attention(&k, &v).unwrap();
        let mean = v.mean_axis(0, false).unwrap();
        for i in 0..4 {
            assert!(out.row(i).unwrap().allclose(&mean, 1e-4, 1e-5));
        }
    }

    #[test]
    fn hard_attention_selects_matching_value() {
        let q = Tensor::eye(4).mul_scalar(30.0);
        let k = Tensor::eye(4).mul_scalar(30.0);
        let mut rng = Rng::new(2);
        let v = Tensor::randn(&[4, 4], 0.0, 1.0, &mut rng);
        let out = q.attention(&k, &v).unwrap();
        assert!(out.allclose(&v, 2e-2, 2e-2));
    }

    #[test]
    fn rows_are_convex_combinations() {
        // every output row lies inside the convex hull of V rows: check
        // min(V) <= out <= max(V) per column.
        let mut rng = Rng::new(3);
        let q = Tensor::randn(&[8, 16], 0.0, 1.0, &mut rng);
        let k = Tensor::randn(&[32, 16], 0.0, 1.0, &mut rng);
        let v = Tensor::randn(&[32, 16], 0.0, 1.0, &mut rng);
        let out = q.attention(&k, &v).unwrap();
        let vmin = v.min_axis(0, false).unwrap();
        let vmax = v.max_axis(0, false).unwrap();
        for i in 0..8 {
            let row = out.row(i).unwrap();
            for (x, (lo, hi)) in row.iter().zip(vmin.iter().zip(vmax.iter())) {
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn shape_validation() {
        let q = Tensor::zeros(&[4, 8]);
        let k = Tensor::zeros(&[16, 9]);
        let v = Tensor::zeros(&[16, 8]);
        assert!(q.attention(&k, &v).is_err());
        assert!(q.attention(&Tensor::zeros(&[8]), &v).is_err());
    }

    #[test]
    fn forward_saves_the_softmax_rows() {
        let mut rng = Rng::new(4);
        let q = Tensor::randn(&[3, 8], 0.0, 1.0, &mut rng);
        let k = Tensor::randn(&[5, 8], 0.0, 1.0, &mut rng);
        let v = Tensor::randn(&[5, 6], 0.0, 1.0, &mut rng);
        let (out, probs) = attention_forward(&q, &k, &v).unwrap();
        assert_eq!(probs.dims(), &[3, 5]);
        let scale = 1.0 / 8f32.sqrt();
        let expect = q.matmul_nt(&k).unwrap().mul_scalar(scale).softmax().unwrap();
        assert_eq!(probs.to_vec(), expect.to_vec());
        assert_eq!(out.to_vec(), probs.matmul(&v).unwrap().to_vec());
    }

    #[test]
    fn backward_matches_finite_difference() {
        // Probe dq, dk, dv against central differences of
        // L = Σ attention(q, k, v) (unit output cotangent).
        let mut rng = Rng::new(5);
        let q = Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng);
        let k = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);
        let v = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);
        let (out, probs) = attention_forward(&q, &k, &v).unwrap();
        let g = Tensor::ones(out.dims());
        let (dq, dk, dv) = attention_backward(&g, &q, &k, &v, &probs).unwrap();
        let eps = 1e-2;
        let loss = |q: &Tensor, k: &Tensor, v: &Tensor| {
            attention(q, k, v).unwrap().sum().item().unwrap()
        };
        for (which, base, an) in [("q", &q, &dq), ("k", &k, &dk), ("v", &v, &dv)] {
            let bv = base.to_vec();
            for probe in [0usize, 3, 7, 11] {
                let mut plus = bv.clone();
                plus[probe] += eps;
                let mut minus = bv.clone();
                minus[probe] -= eps;
                let tp = Tensor::from_vec(plus, base.dims()).unwrap();
                let tm = Tensor::from_vec(minus, base.dims()).unwrap();
                let (lp, lm) = match which {
                    "q" => (loss(&tp, &k, &v), loss(&tm, &k, &v)),
                    "k" => (loss(&q, &tp, &v), loss(&q, &tm, &v)),
                    _ => (loss(&q, &k, &tp), loss(&q, &k, &tm)),
                };
                let fd = (lp - lm) / (2.0 * eps);
                let got = an.to_vec()[probe];
                assert!(
                    (fd - got).abs() < 2e-2,
                    "d{which} probe {probe}: fd={fd} an={got}"
                );
            }
        }
    }

    #[test]
    fn backward_accepts_non_contiguous_views() {
        // Transposed-view q/k/v must produce the same grads as their
        // materialized copies (the exec tiers re-dispatch, values agree).
        let mut rng = Rng::new(6);
        let qt = Tensor::randn(&[4, 6], 0.0, 1.0, &mut rng).t().unwrap();
        let kt = Tensor::randn(&[4, 8], 0.0, 1.0, &mut rng).t().unwrap();
        let vt = Tensor::randn(&[4, 8], 0.0, 1.0, &mut rng).t().unwrap();
        assert!(!qt.is_contiguous());
        let (out, probs) = attention_forward(&qt, &kt, &vt).unwrap();
        let g = Tensor::ones(out.dims());
        let (dq, dk, dv) = attention_backward(&g, &qt, &kt, &vt, &probs).unwrap();
        let (qc, kc, vc) = (qt.contiguous(), kt.contiguous(), vt.contiguous());
        let (out_c, probs_c) = attention_forward(&qc, &kc, &vc).unwrap();
        let (dq_c, dk_c, dv_c) = attention_backward(&g, &qc, &kc, &vc, &probs_c).unwrap();
        assert_eq!(out.to_vec(), out_c.to_vec());
        assert_eq!(dq.to_vec(), dq_c.to_vec());
        assert_eq!(dk.to_vec(), dk_c.to_vec());
        assert_eq!(dv.to_vec(), dv_c.to_vec());
    }
}
