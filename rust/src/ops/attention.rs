//! Native scaled-dot-product attention: `softmax(q kᵀ / √d) v`.
//!
//! Composition of the blocked SGEMM and the row-softmax kernels; the
//! XLA-AOT counterpart is the fused `attention_128x64` Pallas artifact
//! (see `python/compile/kernels/attention.py`), cross-checked in
//! `rust/tests/runtime_xla.rs`.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Single-head attention over `[seq_q, d]`, `[seq_k, d]`, `[seq_k, d]`.
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
    if q.rank() != 2 || k.rank() != 2 || v.rank() != 2 {
        return Err(Error::ShapeMismatch {
            op: "attention",
            expected: "rank-2 q, k, v".into(),
            got: format!("{} {} {}", q.shape(), k.shape(), v.shape()),
        });
    }
    let d = q.dims()[1];
    if k.dims()[1] != d || v.dims()[0] != k.dims()[0] {
        return Err(Error::ShapeMismatch {
            op: "attention",
            expected: format!("k [n, {d}], v [n, dv]"),
            got: format!("{} {}", k.shape(), v.shape()),
        });
    }
    let scale = 1.0 / (d as f32).sqrt();
    let scores = q.matmul_nt(k)?.mul_scalar(scale);
    let probs = scores.softmax()?;
    probs.matmul(v)
}

impl Tensor {
    /// See [`attention`].
    pub fn attention(&self, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        attention(self, k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn uniform_keys_average_values() {
        let mut rng = Rng::new(1);
        let q = Tensor::randn(&[4, 8], 0.0, 1.0, &mut rng);
        let k = Tensor::ones(&[16, 8]);
        let v = Tensor::randn(&[16, 8], 0.0, 1.0, &mut rng);
        let out = q.attention(&k, &v).unwrap();
        let mean = v.mean_axis(0, false).unwrap();
        for i in 0..4 {
            assert!(out.row(i).unwrap().allclose(&mean, 1e-4, 1e-5));
        }
    }

    #[test]
    fn hard_attention_selects_matching_value() {
        let q = Tensor::eye(4).mul_scalar(30.0);
        let k = Tensor::eye(4).mul_scalar(30.0);
        let mut rng = Rng::new(2);
        let v = Tensor::randn(&[4, 4], 0.0, 1.0, &mut rng);
        let out = q.attention(&k, &v).unwrap();
        assert!(out.allclose(&v, 2e-2, 2e-2));
    }

    #[test]
    fn rows_are_convex_combinations() {
        // every output row lies inside the convex hull of V rows: check
        // min(V) <= out <= max(V) per column.
        let mut rng = Rng::new(3);
        let q = Tensor::randn(&[8, 16], 0.0, 1.0, &mut rng);
        let k = Tensor::randn(&[32, 16], 0.0, 1.0, &mut rng);
        let v = Tensor::randn(&[32, 16], 0.0, 1.0, &mut rng);
        let out = q.attention(&k, &v).unwrap();
        let vmin = v.min_axis(0, false).unwrap();
        let vmax = v.max_axis(0, false).unwrap();
        for i in 0..8 {
            let row = out.row(i).unwrap();
            for (x, (lo, hi)) in row.iter().zip(vmin.iter().zip(vmax.iter())) {
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn shape_validation() {
        let q = Tensor::zeros(&[4, 8]);
        let k = Tensor::zeros(&[16, 9]);
        let v = Tensor::zeros(&[16, 8]);
        assert!(q.attention(&k, &v).is_err());
        assert!(q.attention(&Tensor::zeros(&[8]), &v).is_err());
    }
}
