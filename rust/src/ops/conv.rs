//! 2-D convolution and pooling (paper §3.3, eq 6).
//!
//! Layout is NCHW. The forward lowers to im2col + SGEMM — the standard
//! reduction that turns the 6-nested conv loop into one large matrix
//! product handled by the blocked [`super::matmul::sgemm`] kernel. The
//! forward parallelizes over the batch through the execution layer (each
//! image's `[cout, oh*ow]` output slab is disjoint and each task owns a
//! private im2col buffer); for batch-1 inputs the nested SGEMM's panel
//! parallelism takes over instead.
//!
//! The backward passes (w.r.t. input and weight) reuse col2im / the
//! transposed GEMM, exactly the "standard pullbacks with respect to x and
//! w" the paper implements, and both fan out through the execution layer:
//!
//! - `dx`: each image's `[cin, h, w]` slab is disjoint, so the batch loop
//!   chunks over the pool like the forward ([`exec::for_chunks`]), each
//!   task owning private pooled scratch.
//! - `dW`: the weight gradient *sums over the batch*, so the batch is cut
//!   into a **fixed partition** ([`exec::for_partials`], boundaries
//!   independent of the thread count), each chunk accumulates a private
//!   pooled dW partial, and the partials are folded in a fixed-order
//!   binary tree — the result is bit-identical at any
//!   `MINITENSOR_NUM_THREADS`.

use super::exec;
use super::matmul::sgemm;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Convolution geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dSpec {
    pub stride: usize,
    pub padding: usize,
}

impl Default for Conv2dSpec {
    fn default() -> Self {
        Conv2dSpec {
            stride: 1,
            padding: 0,
        }
    }
}

impl Conv2dSpec {
    /// Output spatial size for one dimension.
    pub fn out_size(&self, in_size: usize, kernel: usize) -> Result<usize> {
        let padded = in_size + 2 * self.padding;
        if padded < kernel {
            return Err(Error::ShapeMismatch {
                op: "conv2d",
                expected: format!("input+2p >= kernel ({kernel})"),
                got: format!("{padded}"),
            });
        }
        Ok((padded - kernel) / self.stride + 1)
    }
}

/// Unfold `x [n, c, h, w]` into columns `[n, c*kh*kw, oh*ow]` (flattened to
/// a single buffer; one GEMM per image).
fn im2col(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    spec: Conv2dSpec,
    oh: usize,
    ow: usize,
    cols: &mut [f32],
) {
    let p = spec.padding as isize;
    let s = spec.stride;
    debug_assert_eq!(cols.len(), c * kh * kw * oh * ow);
    let mut idx = 0usize;
    for ci in 0..c {
        for u in 0..kh {
            for v in 0..kw {
                for oy in 0..oh {
                    let iy = (oy * s) as isize + u as isize - p;
                    if iy < 0 || iy >= h as isize {
                        for _ in 0..ow {
                            cols[idx] = 0.0;
                            idx += 1;
                        }
                        continue;
                    }
                    let row_base = ci * h * w + iy as usize * w;
                    for ox in 0..ow {
                        let ix = (ox * s) as isize + v as isize - p;
                        cols[idx] = if ix < 0 || ix >= w as isize {
                            0.0
                        } else {
                            x[row_base + ix as usize]
                        };
                        idx += 1;
                    }
                }
            }
        }
    }
}

/// Scatter columns back into an image — the adjoint of [`im2col`].
fn col2im(
    cols: &[f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    spec: Conv2dSpec,
    oh: usize,
    ow: usize,
    x: &mut [f32],
) {
    let p = spec.padding as isize;
    let s = spec.stride;
    let mut idx = 0usize;
    for ci in 0..c {
        for u in 0..kh {
            for v in 0..kw {
                for oy in 0..oh {
                    let iy = (oy * s) as isize + u as isize - p;
                    if iy < 0 || iy >= h as isize {
                        idx += ow;
                        continue;
                    }
                    let row_base = ci * h * w + iy as usize * w;
                    for ox in 0..ow {
                        let ix = (ox * s) as isize + v as isize - p;
                        if ix >= 0 && ix < w as isize {
                            x[row_base + ix as usize] += cols[idx];
                        }
                        idx += 1;
                    }
                }
            }
        }
    }
}

/// Forward conv2d: `x [n, cin, h, w]` * `weight [cout, cin, kh, kw]` →
/// `[n, cout, oh, ow]` (eq 6). Bias, if any, is added by the layer above.
pub fn conv2d(x: &Tensor, weight: &Tensor, spec: Conv2dSpec) -> Result<Tensor> {
    let (n, cin, h, w) = dims4(x, "conv2d input")?;
    let (cout, cin_w, kh, kw) = dims4(weight, "conv2d weight")?;
    if cin != cin_w {
        return Err(Error::ShapeMismatch {
            op: "conv2d",
            expected: format!("weight cin {cin}"),
            got: format!("{cin_w}"),
        });
    }
    let oh = spec.out_size(h, kh)?;
    let ow = spec.out_size(w, kw)?;

    crate::runtime::stats::record_dispatch();
    crate::runtime::stats::record_output_alloc();
    let mut sp = crate::runtime::trace::span("exec", "conv2d");
    sp.arg_u("images", n as u64);
    sp.arg_u("elems", (n * cout * oh * ow) as u64);
    let xc = x.contiguous();
    let wc = weight.contiguous();
    let xs = xc.contiguous_data().unwrap();
    let ws = wc.contiguous_data().unwrap();

    let k = cin * kh * kw;
    let mut out = vec![0.0f32; n * cout * oh * ow];
    let optr = exec::SyncPtr::new_raw(out.as_mut_ptr());
    exec::for_chunks(n, 2 * cout * k * oh * ow, |i0, i1| {
        // Per-task im2col buffer, recycled through the worker-local pool.
        let mut cols = crate::tensor::pool::take(k * oh * ow);
        cols.resize(k * oh * ow, 0.0);
        for i in i0..i1 {
            im2col(
                &xs[i * cin * h * w..(i + 1) * cin * h * w],
                cin,
                h,
                w,
                kh,
                kw,
                spec,
                oh,
                ow,
                &mut cols,
            );
            // out[i] [cout, oh*ow] = W [cout, k] · cols [k, oh*ow]
            // SAFETY: each image owns a disjoint slab of `out`.
            let o = unsafe { optr.band(i * cout * oh * ow, cout * oh * ow) };
            sgemm(cout, k, oh * ow, ws, &cols, o);
        }
        crate::tensor::pool::put(cols);
    });
    Tensor::from_vec(out, &[n, cout, oh, ow])
}

/// Gradient of conv2d w.r.t. the input: `dx = Wᵀ · dy`, folded by col2im.
pub fn conv2d_backward_input(
    grad_out: &Tensor,
    weight: &Tensor,
    input_dims: &[usize],
    spec: Conv2dSpec,
) -> Result<Tensor> {
    let (n, cout, oh, ow) = dims4(grad_out, "conv2d grad_out")?;
    let (cout_w, cin, kh, kw) = dims4(weight, "conv2d weight")?;
    if cout != cout_w {
        return Err(Error::ShapeMismatch {
            op: "conv2d_backward_input",
            expected: format!("cout {cout_w}"),
            got: format!("{cout}"),
        });
    }
    // The fan-out below writes dx through raw disjoint bands sized from
    // input_dims, so a caller-supplied mismatch must fail here rather
    // than walk past the allocation.
    if input_dims.len() != 4 || input_dims[0] != n || input_dims[1] != cin {
        return Err(Error::ShapeMismatch {
            op: "conv2d_backward_input",
            expected: format!("input_dims [{n}, {cin}, h, w]"),
            got: format!("{input_dims:?}"),
        });
    }
    let (h, w) = (input_dims[2], input_dims[3]);
    let k = cin * kh * kw;
    crate::runtime::stats::record_dispatch();
    crate::runtime::stats::record_output_alloc();
    let mut sp = crate::runtime::trace::span("exec", "conv2d_bwd_input");
    sp.arg_u("images", n as u64);

    let gc = grad_out.contiguous();
    let gs = gc.contiguous_data().unwrap();
    // Wᵀ [k, cout]: transpose once.
    let wc = weight.contiguous();
    let ws = wc.contiguous_data().unwrap();
    let mut wt = vec![0.0f32; k * cout];
    for o in 0..cout {
        for p in 0..k {
            wt[p * cout + o] = ws[o * k + p];
        }
    }

    // Each image's dx slab is disjoint: fan the batch out over the pool
    // (mirrors the forward). Per-image arithmetic is unchanged, so the
    // gradient is bit-identical at any thread count.
    let mut dx = vec![0.0f32; input_dims.iter().product()];
    let dxptr = exec::SyncPtr::new_raw(dx.as_mut_ptr());
    let wt = &wt;
    exec::for_chunks(n, 2 * cout * k * oh * ow, |i0, i1| {
        // Per-task scratch, recycled through the worker-local pool.
        let mut cols = crate::tensor::pool::take(k * oh * ow);
        cols.resize(k * oh * ow, 0.0);
        for i in i0..i1 {
            cols.iter_mut().for_each(|v| *v = 0.0);
            // cols [k, oh*ow] = Wᵀ [k, cout] · dy[i] [cout, oh*ow]
            sgemm(
                k,
                cout,
                oh * ow,
                wt,
                &gs[i * cout * oh * ow..(i + 1) * cout * oh * ow],
                &mut cols,
            );
            // SAFETY: each image owns a disjoint, zero-initialized slab.
            let dxi = unsafe { dxptr.band(i * cin * h * w, cin * h * w) };
            col2im(&cols, cin, h, w, kh, kw, spec, oh, ow, dxi);
        }
        crate::tensor::pool::put(cols);
    });
    Tensor::from_vec(dx, input_dims)
}

/// Cap on the number of dW partial buffers `conv2d_backward_weight` cuts
/// the batch into. Bounds partial memory at `MAX_DW_PARTIALS × |W|` while
/// keeping the partition — and therefore the combine tree and the float
/// result — a pure function of the batch size, never the thread count.
const MAX_DW_PARTIALS: usize = 16;

/// Accumulate `dW += dy[i] · colsᵀ` for images `i0..i1` into `dw`, using
/// the provided per-task scratch buffers.
#[allow(clippy::too_many_arguments)]
fn backward_weight_range(
    i0: usize,
    i1: usize,
    xs: &[f32],
    gs: &[f32],
    (cin, h, w): (usize, usize, usize),
    (cout, oh, ow): (usize, usize, usize),
    (kh, kw): (usize, usize),
    spec: Conv2dSpec,
    cols: &mut [f32],
    colst: &mut [f32],
    dw: &mut [f32],
) {
    let k = cin * kh * kw;
    for i in i0..i1 {
        im2col(
            &xs[i * cin * h * w..(i + 1) * cin * h * w],
            cin,
            h,
            w,
            kh,
            kw,
            spec,
            oh,
            ow,
            cols,
        );
        // transpose cols → [oh*ow, k]
        for p in 0..k {
            for q in 0..oh * ow {
                colst[q * k + p] = cols[p * oh * ow + q];
            }
        }
        // dW [cout, k] += dy[i] [cout, oh*ow] · colsᵀ [oh*ow, k]
        sgemm(
            cout,
            oh * ow,
            k,
            &gs[i * cout * oh * ow..(i + 1) * cout * oh * ow],
            colst,
            dw,
        );
    }
}

/// Gradient of conv2d w.r.t. the weight: `dW = dy · colsᵀ` summed over the
/// batch.
///
/// The batch sum is parallelized with per-chunk dW partials drawn from the
/// thread-local pool and combined in a fixed-order binary tree. Both the
/// partition and the tree depend only on `n` (see [`MAX_DW_PARTIALS`]), so
/// the gradient is **bit-identical at any thread count** — the invariant
/// the `exec_parallel` 1-vs-4-thread tests pin down.
pub fn conv2d_backward_weight(
    grad_out: &Tensor,
    x: &Tensor,
    weight_dims: &[usize],
    spec: Conv2dSpec,
) -> Result<Tensor> {
    let (n, cin, h, w) = dims4(x, "conv2d input")?;
    let (ng, cout, oh, ow) = dims4(grad_out, "conv2d grad_out")?;
    // The batch fan-out slices gs by absolute image index and sizes the
    // partial slabs from weight_dims, so inconsistent geometry must fail
    // here, not as a slice panic on a pool worker.
    if ng != n || weight_dims.len() != 4 || weight_dims[0] != cout || weight_dims[1] != cin {
        return Err(Error::ShapeMismatch {
            op: "conv2d_backward_weight",
            expected: format!("grad_out [{n}, cout, oh, ow], weight_dims [cout, {cin}, kh, kw]"),
            got: format!("{} with {weight_dims:?}", grad_out.shape()),
        });
    }
    let (kh, kw) = (weight_dims[2], weight_dims[3]);
    let k = cin * kh * kw;
    crate::runtime::stats::record_dispatch();
    crate::runtime::stats::record_output_alloc();
    let mut sp = crate::runtime::trace::span("exec", "conv2d_bwd_weight");
    sp.arg_u("images", n as u64);

    let xc = x.contiguous();
    let xs = xc.contiguous_data().unwrap();
    let gc = grad_out.contiguous();
    let gs = gc.contiguous_data().unwrap();

    let wlen = cout * k;
    let per_image = 2 * cout * k * oh * ow + k * oh * ow; // GEMM + transpose

    // Serial fast path (small problems, and any n <= 1): accumulate
    // straight into dw — no partials to combine. The two branches fold
    // dW in different float orders, so this cutoff is part of the
    // numeric contract: it stays the compile-time const (NOT the
    // runtime-tunable `parallel::par_threshold()`), exactly like
    // `exec::REDUCE_CHUNK` — a given problem always picks the same
    // branch and produces the same gradient bits regardless of
    // `MINITENSOR_PAR_THRESHOLD` or thread count.
    if n <= 1 || n.saturating_mul(per_image) < exec::PAR_THRESHOLD {
        let mut dw = vec![0.0f32; wlen];
        let mut cols = crate::tensor::pool::take(k * oh * ow);
        cols.resize(k * oh * ow, 0.0);
        let mut colst = crate::tensor::pool::take(oh * ow * k);
        colst.resize(oh * ow * k, 0.0);
        backward_weight_range(
            0,
            n,
            xs,
            gs,
            (cin, h, w),
            (cout, oh, ow),
            (kh, kw),
            spec,
            &mut cols,
            &mut colst,
            &mut dw,
        );
        crate::tensor::pool::put(cols);
        crate::tensor::pool::put(colst);
        return Tensor::from_vec(dw, weight_dims);
    }

    // Fixed partition of the batch into at most MAX_DW_PARTIALS chunks;
    // each chunk owns a disjoint pooled dW slab sized via the exec
    // layer's own partition arithmetic.
    let chunk = n.div_ceil(MAX_DW_PARTIALS.min(n));
    let n_chunks = exec::partials_count(n, chunk);
    let mut partials = crate::tensor::pool::take(n_chunks * wlen);
    partials.resize(n_chunks * wlen, 0.0);
    let pptr = exec::SyncPtr::new_raw(partials.as_mut_ptr());
    exec::for_partials(n, chunk, |ci, i0, i1| {
        // Per-task scratch from the worker-local pool (no vec![0.0; ..]
        // churn in the hot loop).
        let mut cols = crate::tensor::pool::take(k * oh * ow);
        cols.resize(k * oh * ow, 0.0);
        let mut colst = crate::tensor::pool::take(oh * ow * k);
        colst.resize(oh * ow * k, 0.0);
        // SAFETY: chunk `ci` owns the disjoint, zero-initialized slab
        // `[ci*wlen, (ci+1)*wlen)` of `partials`.
        let dwp = unsafe { pptr.band(ci * wlen, wlen) };
        backward_weight_range(
            i0,
            i1,
            xs,
            gs,
            (cin, h, w),
            (cout, oh, ow),
            (kh, kw),
            spec,
            &mut cols,
            &mut colst,
            dwp,
        );
        crate::tensor::pool::put(cols);
        crate::tensor::pool::put(colst);
    });

    // Fixed-order binary-tree combine: fold partial (i + stride) into
    // partial i with stride doubling. The tree shape depends only on
    // n_chunks, so the floating-point result is thread-count invariant.
    let mut stride = 1;
    while stride < n_chunks {
        let mut i = 0;
        while i + stride < n_chunks {
            let (head, tail) = partials.split_at_mut((i + stride) * wlen);
            let dst = &mut head[i * wlen..i * wlen + wlen];
            let src = &tail[..wlen];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += *s;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    // Copy the root partial out instead of truncating: truncate would
    // keep the full n_chunks × wlen capacity alive behind the gradient
    // tensor for its whole lifetime; this returns the slab to the pool.
    let mut dw = crate::tensor::pool::take(wlen);
    dw.extend_from_slice(&partials[..wlen]);
    crate::tensor::pool::put(partials);
    Tensor::from_vec(dw, weight_dims)
}

/// Max-pool 2-D with square window `k` and stride `k` (the common case).
/// Returns `(output, argmax_indices)`; indices feed the pullback.
pub fn max_pool2d(x: &Tensor, k: usize) -> Result<(Tensor, Vec<usize>)> {
    let (n, c, h, w) = dims4(x, "max_pool2d input")?;
    if h % k != 0 || w % k != 0 {
        return Err(Error::ShapeMismatch {
            op: "max_pool2d",
            expected: format!("h,w divisible by {k}"),
            got: format!("{h}x{w}"),
        });
    }
    let (oh, ow) = (h / k, w / k);
    crate::runtime::stats::record_dispatch();
    crate::runtime::stats::record_output_alloc();
    let xc = x.contiguous();
    let xs = xc.contiguous_data().unwrap();
    let mut out = vec![0.0f32; n * c * oh * ow];
    let mut arg = vec![0usize; n * c * oh * ow];
    let optr = exec::SyncPtr::new(&mut out);
    let aptr = exec::SyncPtr::new(&mut arg);
    exec::for_chunks(n * c, h * w, |img0, img1| {
        for img in img0..img1 {
            let base = img * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut bv = f32::NEG_INFINITY;
                    let mut bi = 0usize;
                    for dy in 0..k {
                        for dx in 0..k {
                            let idx = base + (oy * k + dy) * w + ox * k + dx;
                            if xs[idx] > bv {
                                bv = xs[idx];
                                bi = idx;
                            }
                        }
                    }
                    let o = img * oh * ow + oy * ow + ox;
                    // SAFETY: each image owns a disjoint output range.
                    unsafe {
                        optr.write(o, bv);
                        aptr.write(o, bi);
                    }
                }
            }
        }
    });
    Ok((Tensor::from_vec(out, &[n, c, oh, ow])?, arg))
}

/// Average-pool 2-D with square window `k`, stride `k`.
pub fn avg_pool2d(x: &Tensor, k: usize) -> Result<Tensor> {
    let (n, c, h, w) = dims4(x, "avg_pool2d input")?;
    if h % k != 0 || w % k != 0 {
        return Err(Error::ShapeMismatch {
            op: "avg_pool2d",
            expected: format!("h,w divisible by {k}"),
            got: format!("{h}x{w}"),
        });
    }
    let (oh, ow) = (h / k, w / k);
    crate::runtime::stats::record_dispatch();
    crate::runtime::stats::record_output_alloc();
    let xc = x.contiguous();
    let xs = xc.contiguous_data().unwrap();
    let inv = 1.0 / (k * k) as f32;
    let mut out = vec![0.0f32; n * c * oh * ow];
    let optr = exec::SyncPtr::new(&mut out);
    exec::for_chunks(n * c, h * w, |img0, img1| {
        for img in img0..img1 {
            let base = img * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for dy in 0..k {
                        for dx in 0..k {
                            acc += xs[base + (oy * k + dy) * w + ox * k + dx];
                        }
                    }
                    // SAFETY: each image owns a disjoint output range.
                    unsafe { optr.write(img * oh * ow + oy * ow + ox, acc * inv) };
                }
            }
        }
    });
    Tensor::from_vec(out, &[n, c, oh, ow])
}

fn dims4(t: &Tensor, what: &'static str) -> Result<(usize, usize, usize, usize)> {
    if t.rank() != 4 {
        return Err(Error::ShapeMismatch {
            op: what,
            expected: "rank 4 (NCHW)".into(),
            got: format!("rank {}", t.rank()),
        });
    }
    let d = t.dims();
    Ok((d[0], d[1], d[2], d[3]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    /// Direct 6-loop reference conv (eq 6 verbatim).
    fn conv2d_reference(x: &Tensor, w: &Tensor, spec: Conv2dSpec) -> Tensor {
        let (n, cin, h, wd) = dims4(x, "ref").unwrap();
        let (cout, _, kh, kw) = dims4(w, "ref").unwrap();
        let oh = spec.out_size(h, kh).unwrap();
        let ow = spec.out_size(wd, kw).unwrap();
        let mut out = vec![0.0f32; n * cout * oh * ow];
        for b in 0..n {
            for co in 0..cout {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0;
                        for ci in 0..cin {
                            for u in 0..kh {
                                for v in 0..kw {
                                    let iy = (oy * spec.stride + u) as isize - spec.padding as isize;
                                    let ix = (ox * spec.stride + v) as isize - spec.padding as isize;
                                    if iy >= 0 && iy < h as isize && ix >= 0 && ix < wd as isize {
                                        acc += w.at(&[co, ci, u, v]).unwrap()
                                            * x.at(&[b, ci, iy as usize, ix as usize]).unwrap();
                                    }
                                }
                            }
                        }
                        out[((b * cout + co) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
        Tensor::from_vec(out, &[n, cout, oh, ow]).unwrap()
    }

    #[test]
    fn conv_matches_direct_loop() {
        let mut rng = Rng::new(1);
        for (spec, h, w, kh) in [
            (Conv2dSpec { stride: 1, padding: 0 }, 6, 6, 3),
            (Conv2dSpec { stride: 1, padding: 1 }, 5, 7, 3),
            (Conv2dSpec { stride: 2, padding: 1 }, 8, 8, 3),
            (Conv2dSpec { stride: 2, padding: 2 }, 9, 9, 5),
        ] {
            let x = Tensor::randn(&[2, 3, h, w], 0.0, 1.0, &mut rng);
            let wt = Tensor::randn(&[4, 3, kh, kh], 0.0, 1.0, &mut rng);
            let fast = conv2d(&x, &wt, spec).unwrap();
            let slow = conv2d_reference(&x, &wt, spec);
            assert!(fast.allclose(&slow, 1e-4, 1e-4), "spec {spec:?}");
        }
    }

    #[test]
    fn output_shape() {
        let x = Tensor::zeros(&[1, 1, 28, 28]);
        let w = Tensor::zeros(&[8, 1, 3, 3]);
        let y = conv2d(&x, &w, Conv2dSpec { stride: 1, padding: 1 }).unwrap();
        assert_eq!(y.dims(), &[1, 8, 28, 28]);
        let y2 = conv2d(&x, &w, Conv2dSpec { stride: 2, padding: 1 }).unwrap();
        assert_eq!(y2.dims(), &[1, 8, 14, 14]);
    }

    #[test]
    fn identity_kernel_preserves_input() {
        // 1x1 kernel with weight 1 is the identity on a single channel.
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[1, 1, 4, 4], 0.0, 1.0, &mut rng);
        let w = Tensor::ones(&[1, 1, 1, 1]);
        let y = conv2d(&x, &w, Conv2dSpec::default()).unwrap();
        assert!(y.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn backward_input_matches_finite_difference() {
        let mut rng = Rng::new(3);
        let spec = Conv2dSpec { stride: 1, padding: 1 };
        let x = Tensor::randn(&[1, 2, 4, 4], 0.0, 1.0, &mut rng);
        let w = Tensor::randn(&[3, 2, 3, 3], 0.0, 1.0, &mut rng);
        // loss = sum(conv(x, w)); dL/dx via finite differences on 5 probes
        let g = Tensor::ones(&[1, 3, 4, 4]);
        let dx = conv2d_backward_input(&g, &w, x.dims(), spec).unwrap();
        let eps = 1e-2;
        let xv = x.to_vec();
        for probe in [0usize, 5, 13, 21, 31] {
            let mut plus = xv.clone();
            plus[probe] += eps;
            let mut minus = xv.clone();
            minus[probe] -= eps;
            let lp = conv2d(&Tensor::from_vec(plus, x.dims()).unwrap(), &w, spec)
                .unwrap()
                .sum()
                .item()
                .unwrap();
            let lm = conv2d(&Tensor::from_vec(minus, x.dims()).unwrap(), &w, spec)
                .unwrap()
                .sum()
                .item()
                .unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let an = dx.to_vec()[probe];
            assert!((fd - an).abs() < 1e-2, "probe {probe}: fd={fd} an={an}");
        }
    }

    #[test]
    fn backward_weight_matches_finite_difference() {
        let mut rng = Rng::new(4);
        let spec = Conv2dSpec { stride: 2, padding: 1 };
        let x = Tensor::randn(&[2, 2, 6, 6], 0.0, 1.0, &mut rng);
        let w = Tensor::randn(&[2, 2, 3, 3], 0.0, 1.0, &mut rng);
        let y = conv2d(&x, &w, spec).unwrap();
        let g = Tensor::ones(y.dims());
        let dw = conv2d_backward_weight(&g, &x, w.dims(), spec).unwrap();
        let eps = 1e-2;
        let wv = w.to_vec();
        for probe in [0usize, 7, 17, 35] {
            let mut plus = wv.clone();
            plus[probe] += eps;
            let mut minus = wv.clone();
            minus[probe] -= eps;
            let lp = conv2d(&x, &Tensor::from_vec(plus, w.dims()).unwrap(), spec)
                .unwrap()
                .sum()
                .item()
                .unwrap();
            let lm = conv2d(&x, &Tensor::from_vec(minus, w.dims()).unwrap(), spec)
                .unwrap()
                .sum()
                .item()
                .unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let an = dw.to_vec()[probe];
            assert!((fd - an).abs() < 2e-2, "probe {probe}: fd={fd} an={an}");
        }
    }

    #[test]
    fn maxpool_values_and_indices() {
        let x = Tensor::from_vec(
            vec![
                1., 2., 5., 6., //
                3., 4., 7., 8., //
                9., 10., 13., 14., //
                11., 12., 15., 16.,
            ],
            &[1, 1, 4, 4],
        )
        .unwrap();
        let (y, arg) = max_pool2d(&x, 2).unwrap();
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        assert_eq!(y.to_vec(), vec![4., 8., 12., 16.]);
        assert_eq!(arg, vec![5, 7, 13, 15]);
        assert!(max_pool2d(&Tensor::zeros(&[1, 1, 5, 4]), 2).is_err());
    }

    #[test]
    fn avgpool() {
        let x = Tensor::arange(0.0, 16.0).reshape(&[1, 1, 4, 4]).unwrap();
        let y = avg_pool2d(&x, 2).unwrap();
        assert_eq!(y.to_vec(), vec![2.5, 4.5, 10.5, 12.5]);
    }

    #[test]
    fn shape_validation() {
        let x3 = Tensor::zeros(&[2, 3, 4]);
        let w = Tensor::zeros(&[1, 1, 1, 1]);
        assert!(conv2d(&x3, &w, Conv2dSpec::default()).is_err());
        let x = Tensor::zeros(&[1, 2, 4, 4]);
        let w_badc = Tensor::zeros(&[1, 3, 3, 3]);
        assert!(conv2d(&x, &w_badc, Conv2dSpec::default()).is_err());
    }

    #[test]
    fn backward_input_rejects_mismatched_input_dims() {
        // The banded dx fan-out must error on inconsistent geometry, not
        // write past the allocation.
        let g = Tensor::zeros(&[4, 1, 4, 4]);
        let w = Tensor::zeros(&[1, 2, 3, 3]);
        let spec = Conv2dSpec::default();
        assert!(conv2d_backward_input(&g, &w, &[2, 2, 4, 4], spec).is_err());
        assert!(conv2d_backward_input(&g, &w, &[4, 3, 4, 4], spec).is_err());
        assert!(conv2d_backward_input(&g, &w, &[4, 2, 4], spec).is_err());
    }
}
