//! Binary elementwise operations with NumPy/PyTorch broadcasting.
//!
//! Paper §3.1: elementwise ops map `z_i = f(x_i, y_i)`; broadcasting
//! virtually expands size-1 dimensions (stride 0) without materializing.
//! Tier dispatch (contiguous fused / bias-row / strided walk), pooled
//! output allocation, and data-parallel chunking all live in the unified
//! execution layer — this file only defines the operator surface.
//!
//! The arithmetic families (`add`/`sub`/`mul`/`div`/`maximum`/`minimum`,
//! scalar add/mul, `where_cond`) dispatch as known [`simd::BinOp`] /
//! [`simd::UnOp`] kinds through the 8-lane funnels
//! ([`exec::binary_simd`], [`exec::unary_simd`], [`exec::ternary_select`]);
//! everything else (pow, comparisons, arbitrary `map`) keeps the
//! closure-generic paths.

use super::exec;
use crate::dtype::DType;
use crate::error::Result;
use crate::runtime::simd::{BinOp, UnOp};
use crate::tensor::Tensor;

/// Compute `f(a, b)` elementwise with broadcasting; result dtype is
/// `promote(a, b)` unless overridden by the caller (comparisons retag Bool).
/// Thin alias for [`exec::binary_op`], kept as the historical entry point.
pub fn binary_op(
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32) -> f32 + Copy + Sync,
) -> Result<Tensor> {
    exec::binary_op(a, b, f)
}

impl Tensor {
    pub(crate) fn storage_slice(&self) -> &[f32] {
        self.storage.as_slice()
    }

    pub(crate) fn offset(&self) -> isize {
        self.offset
    }

    /// Elementwise addition with broadcasting.
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        exec::binary_simd(self, other, BinOp::Add)
    }

    /// Elementwise subtraction with broadcasting.
    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        exec::binary_simd(self, other, BinOp::Sub)
    }

    /// Elementwise (Hadamard) product with broadcasting.
    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        exec::binary_simd(self, other, BinOp::Mul)
    }

    /// Elementwise division with broadcasting.
    pub fn div(&self, other: &Tensor) -> Result<Tensor> {
        exec::binary_simd(self, other, BinOp::Div)
    }

    /// Elementwise power with broadcasting.
    pub fn pow(&self, other: &Tensor) -> Result<Tensor> {
        binary_op(self, other, |a, b| a.powf(b))
    }

    /// Elementwise maximum ([`crate::runtime::simd::max_s`] per lane —
    /// what `maxps` computes; plain maximum on NaN-free data).
    pub fn maximum(&self, other: &Tensor) -> Result<Tensor> {
        exec::binary_simd(self, other, BinOp::Max)
    }

    /// Elementwise minimum (same lane kernel family as [`Self::maximum`]).
    pub fn minimum(&self, other: &Tensor) -> Result<Tensor> {
        exec::binary_simd(self, other, BinOp::Min)
    }

    /// Add a scalar.
    pub fn add_scalar(&self, s: f32) -> Tensor {
        exec::unary_simd(self, UnOp::AddScalar(s))
    }

    /// Multiply by a scalar.
    pub fn mul_scalar(&self, s: f32) -> Tensor {
        exec::unary_simd(self, UnOp::MulScalar(s))
    }

    /// Raise to a scalar power.
    pub fn pow_scalar(&self, s: f32) -> Tensor {
        self.map(|v| v.powf(s))
    }

    /// Elementwise equality → Bool tensor.
    pub fn eq_t(&self, other: &Tensor) -> Result<Tensor> {
        Ok(binary_op(self, other, |a, b| f32::from(a == b))?.with_dtype(DType::Bool))
    }

    /// Elementwise greater-than → Bool tensor.
    pub fn gt(&self, other: &Tensor) -> Result<Tensor> {
        Ok(binary_op(self, other, |a, b| f32::from(a > b))?.with_dtype(DType::Bool))
    }

    /// Elementwise less-than → Bool tensor.
    pub fn lt(&self, other: &Tensor) -> Result<Tensor> {
        Ok(binary_op(self, other, |a, b| f32::from(a < b))?.with_dtype(DType::Bool))
    }

    /// Elementwise greater-or-equal → Bool tensor.
    pub fn ge(&self, other: &Tensor) -> Result<Tensor> {
        Ok(binary_op(self, other, |a, b| f32::from(a >= b))?.with_dtype(DType::Bool))
    }

    /// Ternary select: `cond ? self : other`, broadcasting all three —
    /// one composed dispatch with one pooled output
    /// ([`exec::ternary_select`], the 8-lane compare/blend form of
    /// [`crate::ops::kernels::select`] — same per-element semantics the
    /// lazy graph's `where_cond` instruction applies, so the paths stay
    /// bitwise-equal; a true select, so `-0.0` and NaN payloads survive
    /// unchanged, unlike the old mask-multiply-add formulation).
    pub fn where_cond(&self, cond: &Tensor, other: &Tensor) -> Result<Tensor> {
        exec::ternary_select(cond, self, other)
    }

    /// Apply an arbitrary scalar function elementwise (always produces a
    /// fresh contiguous tensor). Runs through the execution layer:
    /// pool-backed output, no zero-fill, chunk-parallel on large inputs.
    pub fn map(&self, f: impl Fn(f32) -> f32 + Sync) -> Tensor {
        exec::unary_op(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_same_shape() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]).unwrap();
        let b = Tensor::from_vec(vec![10., 20., 30., 40.], &[2, 2]).unwrap();
        assert_eq!(a.add(&b).unwrap().to_vec(), vec![11., 22., 33., 44.]);
    }

    #[test]
    fn bias_broadcast_row_fast_path() {
        // the paper's (x + b)_{ij} = x_{ij} + b_j example
        let x = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        let b = Tensor::from_vec(vec![10., 20., 30.], &[3]).unwrap();
        let y = x.add(&b).unwrap();
        assert_eq!(y.to_vec(), vec![11., 22., 33., 14., 25., 36.]);
    }

    #[test]
    fn column_broadcast_strided_path() {
        let x = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        let c = Tensor::from_vec(vec![100., 200.], &[2, 1]).unwrap();
        let y = x.add(&c).unwrap();
        assert_eq!(y.to_vec(), vec![101., 102., 103., 204., 205., 206.]);
    }

    #[test]
    fn two_sided_broadcast() {
        let a = Tensor::from_vec(vec![1., 2.], &[2, 1]).unwrap();
        let b = Tensor::from_vec(vec![10., 20., 30.], &[1, 3]).unwrap();
        let y = a.mul(&b).unwrap();
        assert_eq!(y.dims(), &[2, 3]);
        assert_eq!(y.to_vec(), vec![10., 20., 30., 20., 40., 60.]);
    }

    #[test]
    fn scalar_tensor_broadcast() {
        let a = Tensor::from_vec(vec![1., 2.], &[2]).unwrap();
        let s = Tensor::scalar(3.0);
        assert_eq!(a.mul(&s).unwrap().to_vec(), vec![3., 6.]);
    }

    #[test]
    fn mismatch_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        assert!(a.add(&b).is_err());
    }

    #[test]
    fn sub_div_pow() {
        let a = Tensor::from_vec(vec![4., 9.], &[2]).unwrap();
        let b = Tensor::from_vec(vec![2., 3.], &[2]).unwrap();
        assert_eq!(a.sub(&b).unwrap().to_vec(), vec![2., 6.]);
        assert_eq!(a.div(&b).unwrap().to_vec(), vec![2., 3.]);
        assert_eq!(a.pow(&b).unwrap().to_vec(), vec![16., 729.]);
    }

    #[test]
    fn comparisons_produce_bool() {
        let a = Tensor::from_vec(vec![1., 5.], &[2]).unwrap();
        let b = Tensor::from_vec(vec![2., 2.], &[2]).unwrap();
        let g = a.gt(&b).unwrap();
        assert_eq!(g.dtype(), DType::Bool);
        assert_eq!(g.to_vec(), vec![0., 1.]);
        assert_eq!(a.lt(&b).unwrap().to_vec(), vec![1., 0.]);
        assert_eq!(a.eq_t(&a).unwrap().to_vec(), vec![1., 1.]);
        assert_eq!(a.ge(&b).unwrap().to_vec(), vec![0., 1.]);
    }

    #[test]
    fn where_cond_selects() {
        let cond = Tensor::from_vec(vec![1., 0., 1.], &[3]).unwrap();
        let a = Tensor::from_vec(vec![10., 20., 30.], &[3]).unwrap();
        let b = Tensor::from_vec(vec![-1., -2., -3.], &[3]).unwrap();
        assert_eq!(a.where_cond(&cond, &b).unwrap().to_vec(), vec![10., -2., 30.]);
    }

    #[test]
    fn maximum_minimum() {
        let a = Tensor::from_vec(vec![1., 5.], &[2]).unwrap();
        let b = Tensor::from_vec(vec![3., 2.], &[2]).unwrap();
        assert_eq!(a.maximum(&b).unwrap().to_vec(), vec![3., 5.]);
        assert_eq!(a.minimum(&b).unwrap().to_vec(), vec![1., 2.]);
    }

    #[test]
    fn ops_on_transposed_views() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2])
            .unwrap()
            .t()
            .unwrap();
        let b = Tensor::ones(&[2, 2]);
        assert_eq!(a.add(&b).unwrap().to_vec(), vec![2., 4., 3., 5.]);
    }

    #[test]
    fn dtype_promotion_i32_plus_f32() {
        let i = Tensor::from_vec_i32(vec![1, 2], &[2]).unwrap();
        let f = Tensor::from_vec(vec![0.5, 0.5], &[2]).unwrap();
        let y = i.add(&f).unwrap();
        assert_eq!(y.dtype(), DType::F32);
    }
}
