//! Unified kernel-execution layer (paper §3.1/§3.5).
//!
//! Every bulk op in `ops/` used to own a private copy of the same three
//! concerns: (a) the contiguous / bias-row / strided **tier dispatch**,
//! (b) output allocation, and (c) the loop itself. This module centralizes
//! all three and adds **data-parallel dispatch**: loops are split into
//! contiguous chunks and executed on the persistent worker pool
//! ([`crate::runtime::parallel`]), controlled by `MINITENSOR_NUM_THREADS`
//! (1 ⇒ exact serial behavior, bit-identical to the old per-op loops).
//!
//! The three tiers, unchanged in spirit from the per-op copies:
//!   1. contiguous same-shape → fused slice loop, chunk-parallel;
//!   2. contiguous LHS `[..., k]` ⊕ vector RHS `[k]` (the paper's `x + b`
//!      bias case) → row loop, row-parallel;
//!   3. general strided odometer walk → output-chunked via
//!      [`StridedIter::starting_at`].
//!
//! Outputs draw from the thread-local [`pool`] and are written exactly
//! once through [`SyncPtr`] — no zero-fill pass (EXPERIMENTS.md §Perf
//! L3.2), no allocator round-trip in hot loops.

use crate::error::{Error, Result};
use crate::runtime::parallel;
use crate::shape::StridedIter;
use crate::tensor::{pool, Tensor};

/// Minimum total elements of work before an op engages the worker pool;
/// below this the fork/join overhead exceeds the loop itself.
pub const PAR_THRESHOLD: usize = 1 << 15;

/// Target elements per parallel chunk (grain) for unit-cost loops.
pub const PAR_GRAIN: usize = 1 << 13;

/// Raw output pointer shareable across pool workers for **disjoint**
/// writes into a freshly [`pool::take`]n (or pre-initialized) buffer.
///
/// Safety contract (upheld by every caller in this module and the op
/// files): concurrent tasks write non-overlapping index ranges, every
/// index in `0..len` is written before `set_len`, and the buffer outlives
/// the `parallel_for` call that uses the pointer (guaranteed because
/// `parallel_for` joins before returning).
pub(crate) struct SyncPtr<T = f32>(*mut T);

unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    /// Capture the base pointer of an output buffer.
    pub(crate) fn new(v: &mut Vec<T>) -> SyncPtr<T> {
        SyncPtr(v.as_mut_ptr())
    }

    /// Capture an already-initialized output pointer (accumulator outputs
    /// like the SGEMM C matrix, which kernels read-modify-write).
    pub(crate) fn new_raw(p: *mut T) -> SyncPtr<T> {
        SyncPtr(p)
    }

    /// Mutable view of `len` initialized elements starting at `start`.
    ///
    /// # Safety
    /// The region must be initialized, inside the captured allocation, and
    /// disjoint from every band handed to a concurrently running task.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn band(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// `i` must be inside the captured buffer's capacity and written by
    /// exactly one task.
    #[inline]
    pub(crate) unsafe fn write(&self, i: usize, v: T) {
        self.0.add(i).write(v);
    }

    /// Mutable view of `[start, end)`.
    ///
    /// # Safety
    /// Ranges handed to concurrent tasks must be disjoint and inside the
    /// captured buffer's capacity; the caller must write every element it
    /// reads.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice(&self, start: usize, end: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), end - start)
    }
}

/// The single funnel every migrated kernel dispatches through: run
/// `body(start, end)` over `0..count` items of approximate per-item cost
/// `unit` (in element-ops). Serial below [`PAR_THRESHOLD`] total work,
/// chunked onto the pool above it, with the grain scaled so each chunk
/// carries at least [`PAR_GRAIN`] element-ops.
pub fn for_chunks(count: usize, unit: usize, body: impl Fn(usize, usize) + Sync) {
    if count == 0 {
        return;
    }
    let unit = unit.max(1);
    if count.saturating_mul(unit) < PAR_THRESHOLD {
        body(0, count);
    } else {
        let grain = (PAR_GRAIN / unit).max(1);
        parallel::parallel_for(count, grain, &body);
    }
}

/// Deterministic fan-out over a **fixed partition**: cut `0..count` into
/// `ceil(count/chunk)` contiguous chunks whose boundaries depend only on
/// `(count, chunk)` — never on the thread count — and run
/// `body(chunk_idx, start, end)` once per chunk, possibly concurrently
/// (indices are handed to the pool through an atomic cursor, so load
/// balance is dynamic but the decomposition is not).
///
/// Pair it with a fixed-order combine of per-chunk partials to get
/// **thread-count-invariant** reductions: the same partials are produced
/// and folded in the same order whether `MINITENSOR_NUM_THREADS` is 1 or
/// 64. The conv2d weight gradient is the canonical user.
pub fn for_partials(count: usize, chunk: usize, body: impl Fn(usize, usize, usize) + Sync) {
    if count == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = partials_count(count, chunk);
    parallel::parallel_for_indexed(n_chunks, &|i| {
        let start = i * chunk;
        let end = count.min(start + chunk);
        body(i, start, end);
    });
}

/// Number of chunks [`for_partials`] cuts for `(count, chunk)`. Callers
/// that preallocate one partial slot per chunk size their buffer with
/// this — the single source of truth for the partition arithmetic that
/// their disjoint-write safety rests on.
pub fn partials_count(count: usize, chunk: usize) -> usize {
    count.div_ceil(chunk.max(1))
}

/// Order-stable chunk-parallel reduction: compute `part(start, end)` over
/// the chunks [`for_chunks`] would cut, then combine the partials in
/// ascending chunk order. Deterministic for a fixed thread count; with a
/// single chunk (including every 1-thread run) the sole partial is
/// returned untouched, so the serial value is exact. `None` iff
/// `count == 0`. `part` may carry side effects (e.g. cross-entropy also
/// writes its probability rows) — chunks never overlap.
pub fn reduce_chunks(
    count: usize,
    unit: usize,
    part: impl Fn(usize, usize) -> f32 + Sync,
    combine: impl Fn(f32, f32) -> f32,
) -> Option<f32> {
    if count == 0 {
        return None;
    }
    // Serial fast path: small reductions (per-step loss scalars, metric
    // reads) skip the mutex/vec/sort machinery entirely.
    if count.saturating_mul(unit.max(1)) < PAR_THRESHOLD || parallel::num_threads() == 1 {
        return Some(part(0, count));
    }
    let parts = std::sync::Mutex::new(Vec::new());
    for_chunks(count, unit, |a, b| {
        let v = part(a, b);
        parts
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((a, v));
    });
    let mut parts = parts.into_inner().unwrap_or_else(|e| e.into_inner());
    parts.sort_unstable_by_key(|&(a, _)| a);
    parts.into_iter().map(|(_, v)| v).reduce(combine)
}

/// Compute `f(a, b)` elementwise with broadcasting; result dtype is
/// `promote(a, b)` unless retagged by the caller (comparisons → Bool).
/// This is the engine behind `Tensor::add/sub/mul/…`.
pub fn binary_op(
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32) -> f32 + Copy + Sync,
) -> Result<Tensor> {
    let out_shape = a.shape().broadcast(b.shape())?;
    let dtype = a.dtype().promote(b.dtype());
    let n = out_shape.numel();

    // Degenerate: any zero-sized dimension → empty result, no kernel run
    // (also shields the row tier from `k == 0` chunking).
    if n == 0 {
        return Ok(Tensor::from_vec(Vec::new(), out_shape.dims())?.with_dtype(dtype));
    }

    // Tier 1: identical shapes, both contiguous — fused chunk-parallel
    // slice loop.
    if a.shape() == b.shape() {
        if let (Some(sa), Some(sb)) = (a.contiguous_data(), b.contiguous_data()) {
            let mut out = pool::take(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |s, e| {
                for (i, (&x, &y)) in sa[s..e].iter().zip(&sb[s..e]).enumerate() {
                    // SAFETY: chunks are disjoint and inside `out`.
                    unsafe { ptr.write(s + i, f(x, y)) };
                }
            });
            // SAFETY: for_chunks covered every index in 0..n exactly once.
            unsafe { out.set_len(n) };
            return Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype));
        }
    }

    // Tier 2: contiguous LHS of shape [..., k] with RHS of shape [k]
    // (the paper's x + b bias case) — reuse the RHS row per outer index,
    // parallel over rows.
    if b.rank() == 1
        && a.shape() == &out_shape
        && a.rank() >= 1
        && a.dims()[a.rank() - 1] == b.dims()[0]
    {
        if let (Some(sa), Some(sb)) = (a.contiguous_data(), b.contiguous_data()) {
            let k = sb.len();
            let rows = n / k;
            let mut out = pool::take(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(rows, k, |r0, r1| {
                for (arow, r) in sa[r0 * k..r1 * k].chunks_exact(k).zip(r0..r1) {
                    for (i, (&x, &y)) in arow.iter().zip(sb).enumerate() {
                        // SAFETY: row ranges are disjoint per chunk.
                        unsafe { ptr.write(r * k + i, f(x, y)) };
                    }
                }
            });
            // SAFETY: every row of every chunk was written.
            unsafe { out.set_len(n) };
            return Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype));
        }
    }

    // Tier 3: general strided broadcast walk, chunked over the output's
    // row-major linear order.
    let sa = a.shape().broadcast_strides(a.strides(), &out_shape)?;
    let sb = b.shape().broadcast_strides(b.strides(), &out_shape)?;
    let da = a.storage_slice();
    let db = b.storage_slice();
    let mut out = pool::take(n);
    let ptr = SyncPtr::new(&mut out);
    for_chunks(n, 1, |s, e| {
        let ia = StridedIter::starting_at(&out_shape, &sa, a.offset(), s);
        let ib = StridedIter::starting_at(&out_shape, &sb, b.offset(), s);
        for (i, (oa, ob)) in ia.zip(ib).take(e - s).enumerate() {
            // SAFETY: chunks are disjoint and inside `out`.
            unsafe { ptr.write(s + i, f(da[oa as usize], db[ob as usize])) };
        }
    });
    // SAFETY: the strided chunks covered 0..n exactly once.
    unsafe { out.set_len(n) };
    Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype))
}

/// Apply `f` elementwise over any view, producing a fresh contiguous
/// tensor of the same shape and dtype. Contiguous sources run the fused
/// chunk-parallel loop; strided views take the tier-3 odometer walk,
/// chunked over the output's row-major order via
/// [`StridedIter::starting_at`] — same fan-out as the binary tier 3, so
/// transposed-view activations no longer serialize the whole map.
pub fn unary_op(t: &Tensor, f: impl Fn(f32) -> f32 + Sync) -> Tensor {
    let n = t.numel();
    let out: Vec<f32> = match t.contiguous_data() {
        Some(s) if n > 0 => {
            let mut out = pool::take(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |a, b| {
                for (i, &x) in s[a..b].iter().enumerate() {
                    // SAFETY: chunks are disjoint and inside `out`.
                    unsafe { ptr.write(a + i, f(x)) };
                }
            });
            // SAFETY: for_chunks covered every index in 0..n exactly once.
            unsafe { out.set_len(n) };
            out
        }
        Some(_) => Vec::new(),
        None => {
            let shape = t.shape();
            let strides = t.strides();
            let offset = t.offset();
            let data = t.storage_slice();
            let mut out = pool::take(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |a, b| {
                let it = StridedIter::starting_at(shape, strides, offset, a);
                for (i, o) in it.take(b - a).enumerate() {
                    // SAFETY: chunks are disjoint and inside `out`.
                    unsafe { ptr.write(a + i, f(data[o as usize])) };
                }
            });
            // SAFETY: the strided chunks covered 0..n exactly once.
            unsafe { out.set_len(n) };
            out
        }
    };
    Tensor::from_vec(out, t.dims())
        .expect("unary_op preserves shape")
        .with_dtype(t.dtype())
}

/// Row kernel over the last axis (the softmax/log-softmax family),
/// row-parallel, in three phases per row: `prep(src_row)` computes one
/// row statistic (max, logsumexp, …), `emit(stat, v)` produces each
/// output element exactly once (written through the raw pointer — no
/// zero-fill pass over the output, EXPERIMENTS.md §Perf L3.2), and
/// `finish(dst_row)` may rewrite the now-initialized row in place
/// (normalization).
pub fn map_rows(
    t: &Tensor,
    op: &'static str,
    prep: impl Fn(&[f32]) -> f32 + Sync,
    emit: impl Fn(f32, f32) -> f32 + Sync,
    finish: impl Fn(&mut [f32]) + Sync,
) -> Result<Tensor> {
    let k = *t
        .dims()
        .last()
        .ok_or_else(|| Error::msg(format!("{op}: rank must be >= 1")))?;
    let n = t.numel();
    if k == 0 || n == 0 {
        return Tensor::from_vec(Vec::new(), t.dims());
    }
    let src = t.contiguous();
    let s = src.contiguous_data().unwrap();
    let rows = n / k;
    let mut out = pool::take(n);
    let ptr = SyncPtr::new(&mut out);
    for_chunks(rows, k, |r0, r1| {
        for r in r0..r1 {
            let srow = &s[r * k..(r + 1) * k];
            let stat = prep(srow);
            for (j, &v) in srow.iter().enumerate() {
                // SAFETY: rows are disjoint per chunk; each element is
                // written exactly once.
                unsafe { ptr.write(r * k + j, emit(stat, v)) };
            }
            // SAFETY: the row was fully initialized by the writes above.
            finish(unsafe { ptr.slice(r * k, (r + 1) * k) });
        }
    });
    // SAFETY: every row of every chunk was written by `emit`.
    unsafe { out.set_len(n) };
    Tensor::from_vec(out, t.dims())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_chunks_small_work_is_single_call() {
        let calls = std::sync::atomic::AtomicUsize::new(0);
        for_chunks(100, 1, |s, e| {
            assert_eq!((s, e), (0, 100));
            calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(calls.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn for_chunks_zero_count_is_noop() {
        for_chunks(0, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn for_partials_boundaries_are_fixed_by_count_and_chunk() {
        // The partition must not depend on the thread count: collect the
        // (idx, start, end) triples and check them against the closed form.
        let seen = std::sync::Mutex::new(Vec::new());
        for_partials(10, 4, |i, s, e| {
            seen.lock().unwrap().push((i, s, e));
        });
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 0, 4), (1, 4, 8), (2, 8, 10)]);
        for_partials(0, 4, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn strided_unary_matches_contiguous_reference() {
        // Large transposed view: the chunked odometer walk must agree with
        // mapping the materialized copy, element for element.
        let t = Tensor::arange(0.0, (512 * 300) as f32)
            .reshape(&[512, 300])
            .unwrap()
            .t()
            .unwrap();
        assert!(!t.is_contiguous());
        let y = unary_op(&t, |v| v * 0.5 - 1.0);
        let want = unary_op(&t.contiguous(), |v| v * 0.5 - 1.0);
        assert_eq!(y.to_vec(), want.to_vec());
        assert_eq!(y.dims(), &[300, 512]);
    }

    #[test]
    fn binary_op_matches_scalar_reference_across_tiers() {
        // tier 1
        let a = Tensor::arange(0.0, 24.0).reshape(&[4, 6]).unwrap();
        let b = Tensor::arange(24.0, 48.0).reshape(&[4, 6]).unwrap();
        let y = binary_op(&a, &b, |x, y| x + 2.0 * y).unwrap();
        let want: Vec<f32> = a
            .to_vec()
            .iter()
            .zip(b.to_vec())
            .map(|(&x, y)| x + 2.0 * y)
            .collect();
        assert_eq!(y.to_vec(), want);

        // tier 2 (bias row)
        let bias = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[6]).unwrap();
        let y2 = binary_op(&a, &bias, |x, y| x * y).unwrap();
        assert_eq!(y2.at(&[2, 3]).unwrap(), a.at(&[2, 3]).unwrap() * 4.0);

        // tier 3 (column broadcast → strided walk)
        let col = Tensor::from_vec(vec![10., 20., 30., 40.], &[4, 1]).unwrap();
        let y3 = binary_op(&a, &col, |x, y| x + y).unwrap();
        assert_eq!(y3.at(&[3, 5]).unwrap(), 23.0 + 40.0);

        // tier 3 (same shape but non-contiguous operands)
        let at = a.t().unwrap();
        let bt = b.t().unwrap();
        let y4 = binary_op(&at, &bt, |x, y| x - y).unwrap();
        assert_eq!(y4.to_vec(), vec![-24.0; 24]);
    }

    #[test]
    fn unary_op_keeps_dtype_and_shape() {
        let t = Tensor::from_vec_i32(vec![1, -2, 3, -4], &[2, 2]).unwrap();
        let y = unary_op(&t, |v| -v);
        assert_eq!(y.dims(), &[2, 2]);
        assert_eq!(y.dtype(), crate::dtype::DType::I32);
        assert_eq!(y.to_vec(), vec![-1., 2., -3., 4.]);
    }

    #[test]
    fn map_rows_empty_and_scalar_edges() {
        let empty = Tensor::from_vec(Vec::new(), &[2, 0]).unwrap();
        let y = map_rows(
            &empty,
            "rowop",
            |_| panic!("no rows to run"),
            |_, v| v,
            |_| (),
        )
        .unwrap();
        assert_eq!(y.dims(), &[2, 0]);
        let scalar = Tensor::scalar(1.0);
        assert!(map_rows(&scalar, "rowop", |_| 0.0, |_, v| v, |_| ()).is_err());
    }

    #[test]
    fn map_rows_three_phase_composition() {
        // Subtract the row max, then negate in place: exercises prep,
        // emit, and finish together.
        let t = Tensor::from_vec(vec![1., 3., 2., -1., 0., 5.], &[2, 3]).unwrap();
        let y = map_rows(
            &t,
            "rowop",
            |row| row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
            |m, v| v - m,
            |dst| dst.iter_mut().for_each(|v| *v = -*v),
        )
        .unwrap();
        assert_eq!(y.to_vec(), vec![2., 0., 1., 6., 5., 0.]);
    }
}
