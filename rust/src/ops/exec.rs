//! Unified kernel-execution layer (paper §3.1/§3.5).
//!
//! Every bulk op in `ops/` used to own a private copy of the same three
//! concerns: (a) the contiguous / bias-row / strided **tier dispatch**,
//! (b) output allocation, and (c) the loop itself. This module centralizes
//! all three and adds **data-parallel dispatch**: loops are split into
//! contiguous chunks and executed on the persistent worker pool
//! ([`crate::runtime::parallel`]), controlled by `MINITENSOR_NUM_THREADS`
//! (1 ⇒ exact serial behavior, bit-identical to the old per-op loops).
//!
//! The three tiers, unchanged in spirit from the per-op copies:
//!   1. contiguous same-shape → fused slice loop, chunk-parallel;
//!   2. contiguous LHS `[..., k]` ⊕ vector RHS `[k]` (the paper's `x + b`
//!      bias case) → row loop, row-parallel;
//!   3. general strided odometer walk → output-chunked via
//!      [`StridedIter::starting_at`].
//!
//! Outputs draw from the thread-local [`pool`] and are written exactly
//! once through [`SyncPtr`] — no zero-fill pass (EXPERIMENTS.md §Perf
//! L3.2), no allocator round-trip in hot loops.
//!
//! The lazy expression-graph subsystem ([`crate::graph`]) enters here
//! too: [`fused_op`] dispatches one composed kernel over N inputs
//! (single pass, single pooled output), and [`fused_reduce`] adds a
//! full-reduction epilogue over the fixed [`REDUCE_CHUNK`] partition —
//! the same partition the eager [`reduce_fixed`] reductions use, which
//! is what makes fused and eager results bitwise-equal. Dispatches and
//! output allocations are counted in [`crate::runtime::stats`].

use std::cell::RefCell;
use std::mem::MaybeUninit;

use crate::dtype::DType;
use crate::error::{Error, Result};
use crate::runtime::{parallel, simd, stats, trace};
use crate::shape::{Shape, StridedIter};
use crate::tensor::{pool, Tensor};

/// Default minimum total elements of work before an op engages the worker
/// pool; below this the fork/join overhead exceeds the loop itself.
/// The live value is [`parallel::par_threshold`], overridable via
/// `MINITENSOR_PAR_THRESHOLD` / [`parallel::set_par_threshold`].
pub const PAR_THRESHOLD: usize = parallel::DEFAULT_PAR_THRESHOLD;

/// Default target elements per parallel chunk (grain) for unit-cost
/// loops. The live value is [`parallel::par_grain`], overridable via
/// `MINITENSOR_PAR_GRAIN` / [`parallel::set_par_grain`].
pub const PAR_GRAIN: usize = parallel::DEFAULT_PAR_GRAIN;

/// Fixed chunk size of the order-stable full reductions ([`reduce_fixed`]
/// and the fused-reduce epilogue). The partition this induces is **part of
/// the numeric contract**: per-chunk partials are computed over exactly
/// these boundaries and folded in ascending chunk order, so the result is
/// a pure function of the data — bit-identical at any
/// `MINITENSOR_NUM_THREADS`. Do not derive it from thread count or the
/// tunable grain.
pub const REDUCE_CHUNK: usize = 1 << 15;

/// Maximum number of distinct tensor inputs one fused kernel may read
/// (bounds the stack-allocated slice table in the dispatch loops; the
/// graph fuser splits regions that would exceed it).
pub const MAX_FUSED_INPUTS: usize = 16;

/// Block length (elements) for the gather phase of strided fused
/// dispatch: inputs are staged into L1-resident scratch blocks of this
/// size before the composed kernel runs over them.
pub const FUSE_BLOCK: usize = 1024;

thread_local! {
    /// Gather scratch for strided fused inputs (one FUSE_BLOCK row per
    /// input). Thread-local so pool workers reuse it allocation-free.
    static GATHER: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Chunk scratch for the fused-reduce epilogue (one REDUCE_CHUNK of
    /// materialized elementwise results per in-flight chunk).
    static RCHUNK: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Row scratch for the fused axis-reduce epilogue (one materialized
    /// row of elementwise results per in-flight row).
    static ROWBUF: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Raw output pointer shareable across pool workers for **disjoint**
/// writes into a freshly [`pool::take`]n (or pre-initialized) buffer.
///
/// Safety contract (upheld by every caller in this module and the op
/// files): concurrent tasks write non-overlapping index ranges, every
/// index in `0..len` is written before `set_len`, and the buffer outlives
/// the `parallel_for` call that uses the pointer (guaranteed because
/// `parallel_for` joins before returning).
pub(crate) struct SyncPtr<T = f32>(*mut T);

unsafe impl<T: Send> Send for SyncPtr<T> {}
unsafe impl<T: Send> Sync for SyncPtr<T> {}

impl<T> SyncPtr<T> {
    /// Capture the base pointer of an output buffer.
    pub(crate) fn new(v: &mut Vec<T>) -> SyncPtr<T> {
        SyncPtr(v.as_mut_ptr())
    }

    /// Capture an already-initialized output pointer (accumulator outputs
    /// like the SGEMM C matrix, which kernels read-modify-write).
    pub(crate) fn new_raw(p: *mut T) -> SyncPtr<T> {
        SyncPtr(p)
    }

    /// Mutable view of `len` initialized elements starting at `start`.
    ///
    /// # Safety
    /// The region must be initialized, inside the captured allocation, and
    /// disjoint from every band handed to a concurrently running task.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn band(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }

    /// Uninitialized-view of `len` elements starting at `start`, for
    /// kernels that fill a band through `MaybeUninit::write` (the fused
    /// dispatch path) — no zero-fill pass, no references to
    /// uninitialized `f32`s.
    ///
    /// # Safety
    /// The band must be inside the captured allocation and disjoint from
    /// every band handed to a concurrently running task; the caller must
    /// write every element before the buffer's length is set over it.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn band_uninit(&self, start: usize, len: usize) -> &mut [MaybeUninit<T>] {
        std::slice::from_raw_parts_mut(self.0.add(start) as *mut MaybeUninit<T>, len)
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// `i` must be inside the captured buffer's capacity and written by
    /// exactly one task.
    #[inline]
    pub(crate) unsafe fn write(&self, i: usize, v: T) {
        self.0.add(i).write(v);
    }

    /// Mutable view of `[start, end)`.
    ///
    /// # Safety
    /// Ranges handed to concurrent tasks must be disjoint and inside the
    /// captured buffer's capacity; the caller must write every element it
    /// reads.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice(&self, start: usize, end: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), end - start)
    }
}

/// The single funnel every migrated kernel dispatches through: run
/// `body(start, end)` over `0..count` items of approximate per-item cost
/// `unit` (in element-ops). Serial below [`parallel::par_threshold`]
/// total work (default [`PAR_THRESHOLD`], tunable via
/// `MINITENSOR_PAR_THRESHOLD`), chunked onto the pool above it, with the
/// grain scaled so each chunk carries at least [`parallel::par_grain`]
/// element-ops (default [`PAR_GRAIN`], tunable via
/// `MINITENSOR_PAR_GRAIN`).
pub fn for_chunks(count: usize, unit: usize, body: impl Fn(usize, usize) + Sync) {
    if count == 0 {
        return;
    }
    let unit = unit.max(1);
    if count.saturating_mul(unit) < parallel::par_threshold() {
        body(0, count);
    } else {
        let grain = (parallel::par_grain() / unit).max(1);
        parallel::parallel_for(count, grain, &body);
    }
}

/// Deterministic fan-out over a **fixed partition**: cut `0..count` into
/// `ceil(count/chunk)` contiguous chunks whose boundaries depend only on
/// `(count, chunk)` — never on the thread count — and run
/// `body(chunk_idx, start, end)` once per chunk, possibly concurrently
/// (indices are handed to the pool through an atomic cursor, so load
/// balance is dynamic but the decomposition is not).
///
/// **Fixed-partition contract:** the chunk size is part of the
/// determinism guarantee, not a tuning knob. Callers that promise
/// thread-count-invariant results (the conv2d weight gradient, the
/// order-stable full reductions in [`reduce_fixed`]) must pass a `chunk`
/// that is a pure function of the problem — a constant like
/// [`REDUCE_CHUNK`] or a value derived only from sizes — never anything
/// involving `num_threads()` or the tunable grain. Changing the chunk
/// changes which partials exist and therefore the folded float result.
/// Pair the fixed partition with a fixed-order combine of the per-chunk
/// partials and the same values come out whether `MINITENSOR_NUM_THREADS`
/// is 1 or 64.
///
/// `chunk` must be nonzero (a zero chunk is a caller bug — it would make
/// the partition arithmetic meaningless); debug builds assert this.
pub fn for_partials(count: usize, chunk: usize, body: impl Fn(usize, usize, usize) + Sync) {
    debug_assert!(chunk > 0, "for_partials: chunk must be > 0");
    if count == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = partials_count(count, chunk);
    parallel::parallel_for_indexed(n_chunks, &|i| {
        let start = i * chunk;
        let end = count.min(start + chunk);
        body(i, start, end);
    });
}

/// Number of chunks [`for_partials`] cuts for `(count, chunk)`:
/// `ceil(count/chunk)`, a pure function of its arguments (the
/// fixed-partition contract above — no thread-count term). Callers that
/// preallocate one partial slot per chunk size their buffer with this —
/// the single source of truth for the partition arithmetic that their
/// disjoint-write safety and determinism guarantees rest on.
pub fn partials_count(count: usize, chunk: usize) -> usize {
    debug_assert!(chunk > 0, "partials_count: chunk must be > 0");
    count.div_ceil(chunk.max(1))
}

/// Order-stable **thread-count-invariant** full reduction: compute
/// `part(start, end)` over the fixed [`for_partials`] partition of
/// `0..count` into `chunk`-sized pieces, then fold the partials in
/// ascending chunk order. Because neither the partition nor the fold
/// order depends on `num_threads()`, the result is bit-identical at any
/// `MINITENSOR_NUM_THREADS` — unlike [`reduce_chunks`], whose partition
/// follows the dispatch grain. A single chunk (every `count <= chunk`
/// reduction) returns `part`'s value untouched, so small reductions are
/// exactly the serial kernel. `None` iff `count == 0`.
///
/// This is the engine behind eager `Tensor::sum`/`max_all`/`min_all`
/// *and* the fused-reduce epilogue ([`fused_reduce`]) — both sides
/// produce identical partials over identical boundaries, which is what
/// makes fused evaluation bitwise-equal to the eager chain.
pub fn reduce_fixed(
    count: usize,
    chunk: usize,
    part: impl Fn(usize, usize) -> f32 + Sync,
    combine: impl Fn(f32, f32) -> f32,
) -> Option<f32> {
    if count == 0 {
        return None;
    }
    let chunk = chunk.max(1);
    let n_chunks = partials_count(count, chunk);
    if n_chunks == 1 {
        return Some(part(0, count));
    }
    let mut partials = vec![0.0f32; n_chunks];
    let ptr = SyncPtr::new(&mut partials);
    for_partials(count, chunk, |i, s, e| {
        // SAFETY: chunk indices are distinct, each inside `partials`.
        unsafe { ptr.write(i, part(s, e)) };
    });
    partials.into_iter().reduce(combine)
}

/// Order-stable chunk-parallel reduction: compute `part(start, end)` over
/// the chunks [`for_chunks`] would cut, then combine the partials in
/// ascending chunk order. Deterministic for a fixed thread count; with a
/// single chunk (including every 1-thread run) the sole partial is
/// returned untouched, so the serial value is exact. `None` iff
/// `count == 0`. `part` may carry side effects (e.g. cross-entropy also
/// writes its probability rows) — chunks never overlap.
pub fn reduce_chunks(
    count: usize,
    unit: usize,
    part: impl Fn(usize, usize) -> f32 + Sync,
    combine: impl Fn(f32, f32) -> f32,
) -> Option<f32> {
    if count == 0 {
        return None;
    }
    // Serial fast path: small reductions (per-step loss scalars, metric
    // reads) skip the mutex/vec/sort machinery entirely.
    if count.saturating_mul(unit.max(1)) < parallel::par_threshold()
        || parallel::num_threads() == 1
    {
        return Some(part(0, count));
    }
    let parts = std::sync::Mutex::new(Vec::new());
    for_chunks(count, unit, |a, b| {
        let v = part(a, b);
        parts
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((a, v));
    });
    let mut parts = parts.into_inner().unwrap_or_else(|e| e.into_inner());
    parts.sort_unstable_by_key(|&(a, _)| a);
    parts.into_iter().map(|(_, v)| v).reduce(combine)
}

/// Draw an op output buffer from the pool, counting it in the engine
/// stats (`output_allocs`). Every pooled output allocation — the
/// elementwise/unary/rows/reduce/fused kernels here, `ops::reduce`,
/// `matmul_nt`, and the cross-entropy forward — goes through this, so
/// the fusion tests can assert exact counts. Kernels whose outputs need
/// zero-initialized accumulators (`matmul`'s C, conv, pooling) allocate
/// directly but record the same dispatch/alloc counters (see the stats
/// scope note in `runtime::stats`).
pub(crate) fn take_output(n: usize) -> Vec<f32> {
    stats::record_output_alloc();
    pool::take(n)
}

/// Compute `f(a, b)` elementwise with broadcasting; result dtype is
/// `promote(a, b)` unless retagged by the caller (comparisons → Bool).
/// This is the engine behind `Tensor::add/sub/mul/…`.
pub fn binary_op(
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32) -> f32 + Copy + Sync,
) -> Result<Tensor> {
    let out_shape = a.shape().broadcast(b.shape())?;
    let dtype = a.dtype().promote(b.dtype());
    let n = out_shape.numel();
    stats::record_dispatch();
    let mut sp = trace::span("exec", "binary_op");
    sp.arg_u("elems", n as u64);

    // Degenerate: any zero-sized dimension → empty result, no kernel run
    // (also shields the row tier from `k == 0` chunking).
    if n == 0 {
        return Ok(Tensor::from_vec(Vec::new(), out_shape.dims())?.with_dtype(dtype));
    }

    // Tier 1: identical shapes, both contiguous — fused chunk-parallel
    // slice loop.
    if a.shape() == b.shape() {
        if let (Some(sa), Some(sb)) = (a.contiguous_data(), b.contiguous_data()) {
            sp.arg_u("tier", 1);
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |s, e| {
                for (i, (&x, &y)) in sa[s..e].iter().zip(&sb[s..e]).enumerate() {
                    // SAFETY: chunks are disjoint and inside `out`.
                    unsafe { ptr.write(s + i, f(x, y)) };
                }
            });
            // SAFETY: for_chunks covered every index in 0..n exactly once.
            unsafe { out.set_len(n) };
            return Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype));
        }
    }

    // Tier 2: contiguous LHS of shape [..., k] with RHS of shape [k]
    // (the paper's x + b bias case) — reuse the RHS row per outer index,
    // parallel over rows.
    if b.rank() == 1
        && a.shape() == &out_shape
        && a.rank() >= 1
        && a.dims()[a.rank() - 1] == b.dims()[0]
    {
        if let (Some(sa), Some(sb)) = (a.contiguous_data(), b.contiguous_data()) {
            sp.arg_u("tier", 2);
            let k = sb.len();
            let rows = n / k;
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(rows, k, |r0, r1| {
                for (arow, r) in sa[r0 * k..r1 * k].chunks_exact(k).zip(r0..r1) {
                    for (i, (&x, &y)) in arow.iter().zip(sb).enumerate() {
                        // SAFETY: row ranges are disjoint per chunk.
                        unsafe { ptr.write(r * k + i, f(x, y)) };
                    }
                }
            });
            // SAFETY: every row of every chunk was written.
            unsafe { out.set_len(n) };
            return Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype));
        }
    }

    // Tier 3: general strided broadcast walk, chunked over the output's
    // row-major linear order.
    sp.arg_u("tier", 3);
    let sa = a.shape().broadcast_strides(a.strides(), &out_shape)?;
    let sb = b.shape().broadcast_strides(b.strides(), &out_shape)?;
    let da = a.storage_slice();
    let db = b.storage_slice();
    let mut out = take_output(n);
    let ptr = SyncPtr::new(&mut out);
    for_chunks(n, 1, |s, e| {
        let ia = StridedIter::starting_at(&out_shape, &sa, a.offset(), s);
        let ib = StridedIter::starting_at(&out_shape, &sb, b.offset(), s);
        for (i, (oa, ob)) in ia.zip(ib).take(e - s).enumerate() {
            // SAFETY: chunks are disjoint and inside `out`.
            unsafe { ptr.write(s + i, f(da[oa as usize], db[ob as usize])) };
        }
    });
    // SAFETY: the strided chunks covered 0..n exactly once.
    unsafe { out.set_len(n) };
    Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype))
}

/// Apply `f` elementwise over any view, producing a fresh contiguous
/// tensor of the same shape and dtype. Contiguous sources run the fused
/// chunk-parallel loop; strided views take the tier-3 odometer walk,
/// chunked over the output's row-major order via
/// [`StridedIter::starting_at`] — same fan-out as the binary tier 3, so
/// transposed-view activations no longer serialize the whole map.
pub fn unary_op(t: &Tensor, f: impl Fn(f32) -> f32 + Sync) -> Tensor {
    let n = t.numel();
    stats::record_dispatch();
    let mut sp = trace::span("exec", "unary_op");
    sp.arg_u("elems", n as u64);
    sp.arg_u("tier", if t.contiguous_data().is_some() { 1 } else { 3 });
    let out: Vec<f32> = match t.contiguous_data() {
        Some(s) if n > 0 => {
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |a, b| {
                for (i, &x) in s[a..b].iter().enumerate() {
                    // SAFETY: chunks are disjoint and inside `out`.
                    unsafe { ptr.write(a + i, f(x)) };
                }
            });
            // SAFETY: for_chunks covered every index in 0..n exactly once.
            unsafe { out.set_len(n) };
            out
        }
        Some(_) => Vec::new(),
        None => {
            let shape = t.shape();
            let strides = t.strides();
            let offset = t.offset();
            let data = t.storage_slice();
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |a, b| {
                let it = StridedIter::starting_at(shape, strides, offset, a);
                for (i, o) in it.take(b - a).enumerate() {
                    // SAFETY: chunks are disjoint and inside `out`.
                    unsafe { ptr.write(a + i, f(data[o as usize])) };
                }
            });
            // SAFETY: the strided chunks covered 0..n exactly once.
            unsafe { out.set_len(n) };
            out
        }
    };
    Tensor::from_vec(out, t.dims())
        .expect("unary_op preserves shape")
        .with_dtype(t.dtype())
}

/// Count the 8-lane blocks a SIMD-funneled dispatch will process, for the
/// engine stats (`simd_blocks`). Called on the dispatching thread only,
/// after validation, and only when a vector path is active — the scalar
/// fallback contributes nothing, so `MINITENSOR_SIMD=off` runs report 0.
#[inline]
fn record_simd(n: usize) {
    if simd::path().is_vector() {
        stats::record_simd_blocks((n / simd::LANES) as u64);
    }
}

/// Kind-aware twin of [`binary_op`]: when the op is one of the known
/// [`simd::BinOp`] families and the operands hit tier 1 (contiguous,
/// same shape) or tier 2 (contiguous `[..., k]` ⊕ bias `[k]`), the loop
/// body is the explicit 8-lane block kernel [`simd::bin_to`] instead of a
/// scalar closure. Strided/broadcast operands fall back to [`binary_op`]
/// with the op's scalar twin [`simd::bin_s`] — per-element arithmetic is
/// identical on every path, so results are bitwise-equal regardless of
/// which tier (or `MINITENSOR_SIMD` setting) ran.
pub fn binary_simd(a: &Tensor, b: &Tensor, op: simd::BinOp) -> Result<Tensor> {
    let out_shape = a.shape().broadcast(b.shape())?;
    let dtype = a.dtype().promote(b.dtype());
    let n = out_shape.numel();

    // Tier 1: identical shapes, both contiguous — block kernel over
    // chunk slices.
    if n > 0 && a.shape() == b.shape() {
        if let (Some(sa), Some(sb)) = (a.contiguous_data(), b.contiguous_data()) {
            stats::record_dispatch();
            record_simd(n);
            let mut sp = trace::span("exec", "binary_simd");
            sp.arg_u("elems", n as u64);
            sp.arg_u("tier", 1);
            sp.arg_s("simd", simd::path().name());
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |s, e| {
                // SAFETY: chunks are disjoint and inside `out`; `bin_to`
                // writes every element of the band.
                unsafe {
                    let band = ptr.band_uninit(s, e - s);
                    simd::bin_to(op, &sa[s..e], &sb[s..e], band.as_mut_ptr() as *mut f32);
                }
            });
            // SAFETY: for_chunks covered every index in 0..n exactly once.
            unsafe { out.set_len(n) };
            return Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype));
        }
    }

    // Tier 2: contiguous LHS [..., k] with bias RHS [k] — block kernel
    // per row against the shared RHS.
    if n > 0
        && b.rank() == 1
        && a.shape() == &out_shape
        && a.rank() >= 1
        && a.dims()[a.rank() - 1] == b.dims()[0]
    {
        if let (Some(sa), Some(sb)) = (a.contiguous_data(), b.contiguous_data()) {
            stats::record_dispatch();
            record_simd(n);
            let mut sp = trace::span("exec", "binary_simd");
            sp.arg_u("elems", n as u64);
            sp.arg_u("tier", 2);
            sp.arg_s("simd", simd::path().name());
            let k = sb.len();
            let rows = n / k;
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(rows, k, |r0, r1| {
                for r in r0..r1 {
                    // SAFETY: row ranges are disjoint per chunk; `bin_to`
                    // writes every element of the row band.
                    unsafe {
                        let band = ptr.band_uninit(r * k, k);
                        simd::bin_to(op, &sa[r * k..(r + 1) * k], sb, band.as_mut_ptr() as *mut f32);
                    }
                }
            });
            // SAFETY: every row of every chunk was written.
            unsafe { out.set_len(n) };
            return Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype));
        }
    }

    // Tier 3 / degenerate: strided walk with the scalar twin — same
    // per-element function the vector lanes compute.
    binary_op(a, b, move |x, y| simd::bin_s(op, x, y))
}

/// Kind-aware twin of [`unary_op`]: contiguous sources run the 8-lane
/// block kernel [`simd::un_to`] over chunk slices; strided views fall
/// back to [`unary_op`] with the scalar twin [`simd::un_s`]. Bitwise
/// equal on every path (see [`crate::runtime::simd`]).
pub fn unary_simd(t: &Tensor, op: simd::UnOp) -> Tensor {
    let n = t.numel();
    if n > 0 {
        if let Some(s) = t.contiguous_data() {
            stats::record_dispatch();
            record_simd(n);
            let mut sp = trace::span("exec", "unary_simd");
            sp.arg_u("elems", n as u64);
            sp.arg_u("tier", 1);
            sp.arg_s("simd", simd::path().name());
            let mut out = take_output(n);
            let ptr = SyncPtr::new(&mut out);
            for_chunks(n, 1, |a, b| {
                // SAFETY: chunks are disjoint and inside `out`; `un_to`
                // writes every element of the band.
                unsafe {
                    let band = ptr.band_uninit(a, b - a);
                    simd::un_to(op, &s[a..b], band.as_mut_ptr() as *mut f32);
                }
            });
            // SAFETY: for_chunks covered every index in 0..n exactly once.
            unsafe { out.set_len(n) };
            return Tensor::from_vec(out, t.dims())
                .expect("unary_simd preserves shape")
                .with_dtype(t.dtype());
        }
    }
    unary_op(t, move |v| simd::un_s(op, v))
}

/// Ternary select `cond != 0 ? a : b` through the 8-lane block kernel
/// [`simd::select_to`] — the SIMD twin of
/// [`ternary_op`]`(c, a, b, kernels::select)`, sharing its planning,
/// tiering ([`composed_dispatch`]) and stats accounting. Both the direct
/// and the gathered path hand the kernel equal-length blocks, so every
/// tier vectorizes.
pub fn ternary_select(c: &Tensor, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let out_shape = c.shape().broadcast(a.shape())?.broadcast(b.shape())?;
    let dtype = c.dtype().promote(a.dtype()).promote(b.dtype());
    let plans = plan_fused_inputs(&[c, a, b], &out_shape)?;
    stats::record_dispatch();
    record_simd(out_shape.numel());
    let mut sp = trace::span("exec", "ternary_select");
    sp.arg_u("elems", out_shape.numel() as u64);
    sp.arg_s("simd", simd::path().name());
    composed_dispatch(&plans, &out_shape, dtype, 3, |ins, out| {
        // SAFETY: composed blocks are equal-length; `select_to` writes
        // every element of the band.
        unsafe { simd::select_to(ins[0], ins[1], ins[2], out.as_mut_ptr() as *mut f32) }
    })
}

/// Row kernel over the last axis (the softmax/log-softmax family),
/// row-parallel, in three phases per row: `prep(src_row)` computes one
/// row statistic (max, logsumexp, …), `emit(stat, v)` produces each
/// output element exactly once (written through the raw pointer — no
/// zero-fill pass over the output, EXPERIMENTS.md §Perf L3.2), and
/// `finish(dst_row)` may rewrite the now-initialized row in place
/// (normalization).
pub fn map_rows(
    t: &Tensor,
    op: &'static str,
    prep: impl Fn(&[f32]) -> f32 + Sync,
    emit: impl Fn(f32, f32) -> f32 + Sync,
    finish: impl Fn(&mut [f32]) + Sync,
) -> Result<Tensor> {
    let k = *t
        .dims()
        .last()
        .ok_or_else(|| Error::msg(format!("{op}: rank must be >= 1")))?;
    let n = t.numel();
    stats::record_dispatch();
    let mut sp = trace::span("exec", op);
    sp.arg_u("elems", n as u64);
    sp.arg_u("row_len", k as u64);
    if k == 0 || n == 0 {
        return Tensor::from_vec(Vec::new(), t.dims());
    }
    let src = t.contiguous();
    let s = src.contiguous_data().unwrap();
    let rows = n / k;
    let mut out = take_output(n);
    let ptr = SyncPtr::new(&mut out);
    for_chunks(rows, k, |r0, r1| {
        for r in r0..r1 {
            let srow = &s[r * k..(r + 1) * k];
            let stat = prep(srow);
            for (j, &v) in srow.iter().enumerate() {
                // SAFETY: rows are disjoint per chunk; each element is
                // written exactly once.
                unsafe { ptr.write(r * k + j, emit(stat, v)) };
            }
            // SAFETY: the row was fully initialized by the writes above.
            finish(unsafe { ptr.slice(r * k, (r + 1) * k) });
        }
    });
    // SAFETY: every row of every chunk was written by `emit`.
    unsafe { out.set_len(n) };
    Tensor::from_vec(out, t.dims())
}

/// Block-emit variant of [`map_rows`] for row kernels with an 8-lane SIMD
/// middle phase: `emit_row(stat, src_row, dst_row)` produces the whole
/// output row in one call (and must initialize every element of
/// `dst_row`), instead of a per-element closure. Same tiering, stats
/// accounting, and three-phase contract as [`map_rows`] — this is what
/// lets the softmax family run its exp pass through
/// [`simd::exp_scaled_sub_to`] while keeping one dispatch and one pooled
/// output per op.
pub fn map_rows_block(
    t: &Tensor,
    op: &'static str,
    prep: impl Fn(&[f32]) -> f32 + Sync,
    emit_row: impl Fn(f32, &[f32], &mut [MaybeUninit<f32>]) + Sync,
    finish: impl Fn(&mut [f32]) + Sync,
) -> Result<Tensor> {
    let k = *t
        .dims()
        .last()
        .ok_or_else(|| Error::msg(format!("{op}: rank must be >= 1")))?;
    let n = t.numel();
    stats::record_dispatch();
    let mut sp = trace::span("exec", op);
    sp.arg_u("elems", n as u64);
    sp.arg_u("row_len", k as u64);
    sp.arg_s("simd", simd::path().name());
    if k == 0 || n == 0 {
        return Tensor::from_vec(Vec::new(), t.dims());
    }
    record_simd(n);
    let src = t.contiguous();
    let s = src.contiguous_data().unwrap();
    let rows = n / k;
    let mut out = take_output(n);
    let ptr = SyncPtr::new(&mut out);
    for_chunks(rows, k, |r0, r1| {
        for r in r0..r1 {
            let srow = &s[r * k..(r + 1) * k];
            let stat = prep(srow);
            // SAFETY: rows are disjoint per chunk; `emit_row`'s contract
            // is to initialize every element of the band.
            unsafe { emit_row(stat, srow, ptr.band_uninit(r * k, k)) };
            // SAFETY: the row was fully initialized by `emit_row`.
            finish(unsafe { ptr.slice(r * k, (r + 1) * k) });
        }
    });
    // SAFETY: every row of every chunk was written by `emit_row`.
    unsafe { out.set_len(n) };
    Tensor::from_vec(out, t.dims())
}

/// Per-input access plan for one fused dispatch: either a direct
/// contiguous slice of exactly the output shape, or a strided/broadcast
/// walk (storage + projected strides + offset) staged through gather
/// scratch.
struct InputPlan<'a> {
    direct: Option<&'a [f32]>,
    data: &'a [f32],
    strides: Vec<isize>,
    offset: isize,
}

/// Validate and plan the inputs of a fused kernel **before any side
/// effects** (stats, allocations): arity within `1..=`
/// [`MAX_FUSED_INPUTS`], and every input broadcastable to `out_shape`.
fn plan_fused_inputs<'a>(
    inputs: &[&'a Tensor],
    out_shape: &Shape,
) -> Result<Vec<InputPlan<'a>>> {
    if inputs.is_empty() || inputs.len() > MAX_FUSED_INPUTS {
        return Err(Error::msg(format!(
            "fused kernel: {} inputs outside 1..={MAX_FUSED_INPUTS}",
            inputs.len()
        )));
    }
    inputs
        .iter()
        .map(|t| {
            let strides = t.shape().broadcast_strides(t.strides(), out_shape)?;
            Ok(InputPlan {
                direct: if t.shape() == out_shape {
                    t.contiguous_data()
                } else {
                    None
                },
                data: t.storage_slice(),
                strides,
                offset: t.offset(),
            })
        })
        .collect()
}

/// Run the composed kernel over virtual elements `[s, s + dst.len())` of
/// the broadcast view described by `plans`, staging non-direct inputs
/// through thread-local [`GATHER`] scratch in [`FUSE_BLOCK`] pieces so
/// the kernel always sees equal-length, broadcast-projected blocks.
/// `eval` must initialize every element of each destination block.
fn eval_gathered<F>(
    plans: &[InputPlan<'_>],
    out_shape: &Shape,
    s: usize,
    dst: &mut [MaybeUninit<f32>],
    eval: &F,
) where
    F: Fn(&[&[f32]], &mut [MaybeUninit<f32>]) + Sync,
{
    let k = plans.len();
    GATHER.with(|g| {
        let mut g = g.borrow_mut();
        if g.len() < k * FUSE_BLOCK {
            g.resize(k * FUSE_BLOCK, 0.0);
        }
        let e = s + dst.len();
        let mut pos = s;
        let mut rel = 0usize;
        while pos < e {
            let len = FUSE_BLOCK.min(e - pos);
            // Phase 1: gather strided/broadcast inputs into scratch rows.
            for (j, p) in plans.iter().enumerate() {
                if p.direct.is_none() {
                    let row = &mut g[j * FUSE_BLOCK..j * FUSE_BLOCK + len];
                    let it = StridedIter::starting_at(out_shape, &p.strides, p.offset, pos);
                    for (slot, o) in row.iter_mut().zip(it) {
                        *slot = p.data[o as usize];
                    }
                }
            }
            // Phase 2: point the slice table at storage (direct inputs)
            // or the freshly gathered rows, and run the composed kernel.
            let mut bufs: [&[f32]; MAX_FUSED_INPUTS] = [&[]; MAX_FUSED_INPUTS];
            for (j, p) in plans.iter().enumerate() {
                bufs[j] = match p.direct {
                    Some(d) => &d[pos..pos + len],
                    None => &g[j * FUSE_BLOCK..j * FUSE_BLOCK + len],
                };
            }
            eval(&bufs[..k], &mut dst[rel..rel + len]);
            pos += len;
            rel += len;
        }
    });
}

/// Dispatch one composed elementwise kernel over `inputs` in a **single
/// pass with a single pooled output allocation** — the lazy graph's
/// fused-region entry point (paper §3.5 / LoopStack-style fusion). The
/// kernel is the block form of a composed `Fn(&[f32]) -> f32` over N
/// inputs: `eval` receives one equal-length, broadcast-projected block
/// per input and must write every element of the output block,
/// conceptually `out[i] = f(in_0[i], …, in_{k-1}[i])`.
///
/// Tiering mirrors [`binary_op`]: when every input is contiguous and
/// exactly `out_shape`-shaped the kernel runs directly over raw chunk
/// slices; otherwise inputs are staged through L1-resident
/// [`FUSE_BLOCK`] gather blocks ([`eval_gathered`]). Chunk-parallel
/// either way, and because the partition never changes per-element
/// arithmetic, results are bit-identical at any `MINITENSOR_NUM_THREADS`.
///
/// `fused_ops` is the number of graph ops the kernel folds — it feeds
/// the engine stats and the threshold/grain cost model.
pub fn fused_op(
    inputs: &[&Tensor],
    out_shape: &Shape,
    dtype: DType,
    fused_ops: usize,
    eval: impl Fn(&[&[f32]], &mut [MaybeUninit<f32>]) + Sync,
) -> Result<Tensor> {
    let plans = plan_fused_inputs(inputs, out_shape)?;
    stats::record_dispatch();
    stats::record_fused(fused_ops, out_shape.numel());
    let mut sp = trace::span("exec", "fused_op");
    sp.arg_u("elems", out_shape.numel() as u64);
    sp.arg_u("ops", fused_ops as u64);
    sp.arg_s("simd", simd::path().name());
    let unit = (plans.len() + fused_ops).max(1);
    composed_dispatch(&plans, out_shape, dtype, unit, eval)
}

/// Ternary select `cond != 0 ? a : b` with broadcasting, in one dispatch
/// with one pooled output — the eager engine behind
/// [`Tensor::where_cond`], sharing the composed-kernel tiering with
/// [`fused_op`] (but counted as a plain dispatch, not a fused region).
pub fn ternary_op(
    c: &Tensor,
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32, f32) -> f32 + Sync,
) -> Result<Tensor> {
    let out_shape = c.shape().broadcast(a.shape())?.broadcast(b.shape())?;
    let dtype = c.dtype().promote(a.dtype()).promote(b.dtype());
    let plans = plan_fused_inputs(&[c, a, b], &out_shape)?;
    stats::record_dispatch();
    let mut sp = trace::span("exec", "ternary_op");
    sp.arg_u("elems", out_shape.numel() as u64);
    composed_dispatch(&plans, &out_shape, dtype, 3, |ins, out| {
        for (i, o) in out.iter_mut().enumerate() {
            o.write(f(ins[0][i], ins[1][i], ins[2][i]));
        }
    })
}

/// Shared body of [`fused_op`] / [`ternary_op`]: run one composed kernel
/// over planned inputs into a single pooled output. When every input is
/// contiguous and exactly `out_shape`-shaped the kernel runs directly
/// over raw chunk slices; otherwise inputs are staged through
/// L1-resident [`FUSE_BLOCK`] gather blocks ([`eval_gathered`]).
/// Chunk-parallel either way, and because the partition never changes
/// per-element arithmetic, results are bit-identical at any
/// `MINITENSOR_NUM_THREADS`.
fn composed_dispatch(
    plans: &[InputPlan<'_>],
    out_shape: &Shape,
    dtype: DType,
    unit: usize,
    eval: impl Fn(&[&[f32]], &mut [MaybeUninit<f32>]) + Sync,
) -> Result<Tensor> {
    let n = out_shape.numel();
    if n == 0 {
        return Ok(Tensor::from_vec(Vec::new(), out_shape.dims())?.with_dtype(dtype));
    }
    let unit = unit.max(1);
    let mut out = take_output(n);
    let ptr = SyncPtr::new(&mut out);
    if plans.iter().all(|p| p.direct.is_some()) {
        for_chunks(n, unit, |s, e| {
            let mut bufs: [&[f32]; MAX_FUSED_INPUTS] = [&[]; MAX_FUSED_INPUTS];
            for (j, p) in plans.iter().enumerate() {
                bufs[j] = &p.direct.unwrap()[s..e];
            }
            // SAFETY: chunks are disjoint and inside `out`'s capacity;
            // `eval`'s contract is to write every element of the band.
            let band = unsafe { ptr.band_uninit(s, e - s) };
            eval(&bufs[..plans.len()], band);
        });
    } else {
        for_chunks(n, unit, |s, e| {
            // SAFETY: as above.
            let band = unsafe { ptr.band_uninit(s, e - s) };
            eval_gathered(plans, out_shape, s, band, &eval);
        });
    }
    // SAFETY: the chunks covered 0..n exactly once and `eval`
    // initialized every element of each band.
    unsafe { out.set_len(n) };
    Ok(Tensor::from_vec(out, out_shape.dims())?.with_dtype(dtype))
}

/// Fused elementwise region with a full-reduction **epilogue** in one
/// dispatch and zero intermediate tensors: the virtual
/// `virt_shape`-shaped result of `eval` is materialized chunk by chunk
/// into thread-local scratch and reduced with `slice_reduce`, over the
/// fixed [`REDUCE_CHUNK`] partition of [`reduce_fixed`], partials folded
/// in ascending chunk order by `combine`.
///
/// Order-stable by construction: the partition and fold order are pure
/// functions of the element count, so the result is bit-identical at any
/// `MINITENSOR_NUM_THREADS` — and bitwise equal to materializing the
/// region with [`fused_op`] (or the eager op chain) and reducing that
/// tensor through [`reduce_fixed`], because identical partials are
/// computed with the same kernel over the same boundaries. `None` iff
/// the virtual result is empty.
pub fn fused_reduce(
    inputs: &[&Tensor],
    virt_shape: &Shape,
    fused_ops: usize,
    eval: impl Fn(&[&[f32]], &mut [MaybeUninit<f32>]) + Sync,
    slice_reduce: impl Fn(&[f32]) -> f32 + Sync,
    combine: impl Fn(f32, f32) -> f32,
) -> Result<Option<f32>> {
    let plans = plan_fused_inputs(inputs, virt_shape)?;
    let n = virt_shape.numel();
    stats::record_dispatch();
    stats::record_fused(fused_ops, n);
    let mut sp = trace::span("exec", "fused_reduce");
    sp.arg_u("elems", n as u64);
    sp.arg_u("ops", fused_ops as u64);
    sp.arg_s("simd", simd::path().name());
    Ok(reduce_fixed(
        n,
        REDUCE_CHUNK,
        |s, e| {
            RCHUNK.with(|scr| {
                let mut scr = scr.borrow_mut();
                if scr.len() < e - s {
                    scr.resize(REDUCE_CHUNK.min(n), 0.0);
                }
                let chunk = &mut scr[..e - s];
                // MaybeUninit view of already-initialized scratch:
                // writing through it keeps every element initialized.
                let view = unsafe {
                    std::slice::from_raw_parts_mut(
                        chunk.as_mut_ptr() as *mut MaybeUninit<f32>,
                        chunk.len(),
                    )
                };
                eval_gathered(&plans, virt_shape, s, view, &eval);
                slice_reduce(&*chunk)
            })
        },
        combine,
    ))
}

/// Fused elementwise region with a **per-row last-axis reduction
/// epilogue** in one dispatch and one pooled output: each row of the
/// `virt_shape = [..., k]`-shaped virtual result of `eval` is
/// materialized into thread-local scratch, reduced with `slice_reduce`
/// over the whole contiguous row, and finalized by `finish(total, k)`
/// (the Mean `* 1/k`). Rows fan out over the worker pool; per-row
/// arithmetic is serial and fixed, so results are **bit-identical at any
/// `MINITENSOR_NUM_THREADS`** — and bitwise-equal to materializing the
/// region and reducing it with the eager `reduce_axis(-1)` fast path,
/// which applies the same slice kernel to the same contiguous rows.
///
/// `out_dims` is the reduced shape (last axis dropped or kept as 1 —
/// same element count either way). This is the epilogue a lazy
/// elementwise pipeline ending in a last-axis reduce dispatches through;
/// the dedicated softmax row kernels (`map_rows`) remain the
/// single-dispatch path for full-row outputs.
#[allow(clippy::too_many_arguments)]
pub fn fused_axis_reduce(
    inputs: &[&Tensor],
    virt_shape: &Shape,
    fused_ops: usize,
    eval: impl Fn(&[&[f32]], &mut [MaybeUninit<f32>]) + Sync,
    slice_reduce: impl Fn(&[f32]) -> f32 + Sync,
    finish: impl Fn(f32, usize) -> f32 + Sync,
    identity: f32,
    out_dims: &[usize],
) -> Result<Tensor> {
    let k = *virt_shape
        .dims()
        .last()
        .ok_or_else(|| Error::msg("fused_axis_reduce: rank must be >= 1"))?;
    let plans = plan_fused_inputs(inputs, virt_shape)?;
    let n = virt_shape.numel();
    let out_len: usize = out_dims.iter().product();
    debug_assert!(k == 0 || out_len == n / k, "out_dims must hold one value per row");
    stats::record_dispatch();
    stats::record_fused(fused_ops, n);
    let mut sp = trace::span("exec", "fused_axis_reduce");
    sp.arg_u("elems", n as u64);
    sp.arg_u("ops", fused_ops as u64);
    sp.arg_s("simd", simd::path().name());
    if out_len == 0 {
        return Tensor::from_vec(Vec::new(), out_dims);
    }
    if k == 0 {
        // Empty rows: every output is the finalized identity, exactly
        // like the eager reduce_axis degenerate path (for Mean this is
        // identity * (1/0) — the same NaN the eager chain produces).
        return Tensor::from_vec(vec![finish(identity, 0); out_len], out_dims);
    }
    let rows = n / k;
    let unit = k.saturating_mul((plans.len() + fused_ops).max(1)).max(1);
    let mut out = take_output(rows);
    let ptr = SyncPtr::new(&mut out);
    // Cap on the row scratch each worker retains between dispatches
    // (one REDUCE_CHUNK, 128 KiB): wider rows allocate per chunk instead
    // of pinning megabytes in every pool worker for the process
    // lifetime.
    let keep = REDUCE_CHUNK;
    for_chunks(rows, unit, |r0, r1| {
        ROWBUF.with(|scr| {
            let mut scr = scr.borrow_mut();
            if scr.len() < k {
                scr.resize(k, 0.0);
            }
            for r in r0..r1 {
                let row = &mut scr[..k];
                // MaybeUninit view of already-initialized scratch:
                // writing through it keeps every element initialized.
                let view = unsafe {
                    std::slice::from_raw_parts_mut(
                        row.as_mut_ptr() as *mut MaybeUninit<f32>,
                        row.len(),
                    )
                };
                eval_gathered(&plans, virt_shape, r * k, view, &eval);
                // SAFETY: row indices are distinct, each inside `out`.
                unsafe { ptr.write(r, finish(slice_reduce(&*row), k)) };
            }
            if k > keep {
                *scr = Vec::new();
            }
        });
    });
    // SAFETY: every row index in 0..rows was written exactly once.
    unsafe { out.set_len(rows) };
    Tensor::from_vec(out, out_dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_chunks_small_work_is_single_call() {
        let calls = std::sync::atomic::AtomicUsize::new(0);
        for_chunks(100, 1, |s, e| {
            assert_eq!((s, e), (0, 100));
            calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(calls.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn for_chunks_zero_count_is_noop() {
        for_chunks(0, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn for_partials_boundaries_are_fixed_by_count_and_chunk() {
        // The partition must not depend on the thread count: collect the
        // (idx, start, end) triples and check them against the closed form.
        let seen = std::sync::Mutex::new(Vec::new());
        for_partials(10, 4, |i, s, e| {
            seen.lock().unwrap().push((i, s, e));
        });
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 0, 4), (1, 4, 8), (2, 8, 10)]);
        for_partials(0, 4, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn strided_unary_matches_contiguous_reference() {
        // Large transposed view: the chunked odometer walk must agree with
        // mapping the materialized copy, element for element.
        let t = Tensor::arange(0.0, (512 * 300) as f32)
            .reshape(&[512, 300])
            .unwrap()
            .t()
            .unwrap();
        assert!(!t.is_contiguous());
        let y = unary_op(&t, |v| v * 0.5 - 1.0);
        let want = unary_op(&t.contiguous(), |v| v * 0.5 - 1.0);
        assert_eq!(y.to_vec(), want.to_vec());
        assert_eq!(y.dims(), &[300, 512]);
    }

    #[test]
    fn binary_op_matches_scalar_reference_across_tiers() {
        // tier 1
        let a = Tensor::arange(0.0, 24.0).reshape(&[4, 6]).unwrap();
        let b = Tensor::arange(24.0, 48.0).reshape(&[4, 6]).unwrap();
        let y = binary_op(&a, &b, |x, y| x + 2.0 * y).unwrap();
        let want: Vec<f32> = a
            .to_vec()
            .iter()
            .zip(b.to_vec())
            .map(|(&x, y)| x + 2.0 * y)
            .collect();
        assert_eq!(y.to_vec(), want);

        // tier 2 (bias row)
        let bias = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[6]).unwrap();
        let y2 = binary_op(&a, &bias, |x, y| x * y).unwrap();
        assert_eq!(y2.at(&[2, 3]).unwrap(), a.at(&[2, 3]).unwrap() * 4.0);

        // tier 3 (column broadcast → strided walk)
        let col = Tensor::from_vec(vec![10., 20., 30., 40.], &[4, 1]).unwrap();
        let y3 = binary_op(&a, &col, |x, y| x + y).unwrap();
        assert_eq!(y3.at(&[3, 5]).unwrap(), 23.0 + 40.0);

        // tier 3 (same shape but non-contiguous operands)
        let at = a.t().unwrap();
        let bt = b.t().unwrap();
        let y4 = binary_op(&at, &bt, |x, y| x - y).unwrap();
        assert_eq!(y4.to_vec(), vec![-24.0; 24]);
    }

    #[test]
    fn unary_op_keeps_dtype_and_shape() {
        let t = Tensor::from_vec_i32(vec![1, -2, 3, -4], &[2, 2]).unwrap();
        let y = unary_op(&t, |v| -v);
        assert_eq!(y.dims(), &[2, 2]);
        assert_eq!(y.dtype(), crate::dtype::DType::I32);
        assert_eq!(y.to_vec(), vec![-1., 2., -3., 4.]);
    }

    #[test]
    fn map_rows_empty_and_scalar_edges() {
        let empty = Tensor::from_vec(Vec::new(), &[2, 0]).unwrap();
        let y = map_rows(
            &empty,
            "rowop",
            |_| panic!("no rows to run"),
            |_, v| v,
            |_| (),
        )
        .unwrap();
        assert_eq!(y.dims(), &[2, 0]);
        let scalar = Tensor::scalar(1.0);
        assert!(map_rows(&scalar, "rowop", |_| 0.0, |_, v| v, |_| ()).is_err());
    }

    #[test]
    fn map_rows_three_phase_composition() {
        // Subtract the row max, then negate in place: exercises prep,
        // emit, and finish together.
        let t = Tensor::from_vec(vec![1., 3., 2., -1., 0., 5.], &[2, 3]).unwrap();
        let y = map_rows(
            &t,
            "rowop",
            |row| row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
            |m, v| v - m,
            |dst| dst.iter_mut().for_each(|v| *v = -*v),
        )
        .unwrap();
        assert_eq!(y.to_vec(), vec![2., 0., 1., 6., 5., 0.]);
    }

    /// Reference composed kernel for the fused tests: relu(a*b + a).
    fn relu_fma(ins: &[&[f32]], out: &mut [MaybeUninit<f32>]) {
        for (i, o) in out.iter_mut().enumerate() {
            o.write((ins[0][i] * ins[1][i] + ins[0][i]).max(0.0));
        }
    }

    #[test]
    fn fused_op_matches_eager_chain_contiguous() {
        let a = Tensor::arange(-6.0, 6.0).reshape(&[3, 4]).unwrap();
        let b = Tensor::arange(0.0, 12.0).reshape(&[3, 4]).unwrap();
        let y = fused_op(&[&a, &b], a.shape(), DType::F32, 2, relu_fma).unwrap();
        let want = a.mul(&b).unwrap().add(&a).unwrap().relu();
        assert_eq!(y.to_vec(), want.to_vec());
        assert_eq!(y.dims(), &[3, 4]);
    }

    #[test]
    fn fused_op_gathers_broadcast_and_strided_inputs() {
        // bias-broadcast rhs and a transposed (strided) lhs
        let a = Tensor::arange(0.0, 12.0)
            .reshape(&[4, 3])
            .unwrap()
            .t()
            .unwrap(); // [3, 4], non-contiguous
        let b = Tensor::from_vec(vec![1., -2., 3., -4.], &[4]).unwrap();
        let out_shape = a.shape().broadcast(b.shape()).unwrap();
        let y = fused_op(&[&a, &b], &out_shape, DType::F32, 2, relu_fma).unwrap();
        let want = a.mul(&b).unwrap().add(&a).unwrap().relu();
        assert_eq!(y.to_vec(), want.to_vec());
    }

    #[test]
    fn fused_op_rejects_bad_inputs_before_side_effects() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4]); // not broadcastable to [2, 3]
        let before = stats::snapshot();
        assert!(fused_op(&[&a, &b], a.shape(), DType::F32, 1, relu_fma).is_err());
        assert!(fused_op(&[], a.shape(), DType::F32, 0, relu_fma).is_err());
        let after = stats::snapshot();
        assert_eq!(after, before, "failed validation must not count");
    }

    #[test]
    fn fused_op_empty_output() {
        let a = Tensor::from_vec(Vec::new(), &[0, 3]).unwrap();
        let y = fused_op(&[&a], a.shape(), DType::F32, 1, |ins, out| {
            for (i, o) in out.iter_mut().enumerate() {
                o.write(ins[0][i]);
            }
        })
        .unwrap();
        assert_eq!(y.dims(), &[0, 3]);
        assert_eq!(y.numel(), 0);
    }

    #[test]
    fn ternary_op_broadcasts_and_selects() {
        let c = Tensor::from_vec(vec![1.0, 0.0, 2.0], &[3]).unwrap();
        let a = Tensor::from_vec((0..6).map(|i| i as f32).collect(), &[2, 3]).unwrap();
        let b = Tensor::from_vec(vec![-1.0; 6], &[2, 3]).unwrap();
        let y = ternary_op(&c, &a, &b, crate::ops::kernels::select).unwrap();
        assert_eq!(y.dims(), &[2, 3]);
        assert_eq!(y.to_vec(), vec![0.0, -1.0, 2.0, 3.0, -1.0, 5.0]);
    }

    #[test]
    fn fused_axis_reduce_matches_eager_rows() {
        // relu(a*b + a) then per-row sum — against materialize + sum_axis.
        let rows = 37;
        let k = 300; // not a FUSE_BLOCK multiple, so row gather wraps
        let a = Tensor::arange(0.0, (rows * k) as f32)
            .mul_scalar(1e-3)
            .reshape(&[rows, k])
            .unwrap();
        let b = Tensor::arange(0.0, (rows * k) as f32)
            .mul_scalar(-2e-3)
            .reshape(&[rows, k])
            .unwrap();
        let fused = fused_axis_reduce(
            &[&a, &b],
            a.shape(),
            3,
            relu_fma,
            crate::ops::kernels::sum,
            |t, _| t,
            0.0,
            &[rows],
        )
        .unwrap();
        let want = a
            .mul(&b)
            .unwrap()
            .add(&a)
            .unwrap()
            .relu()
            .sum_axis(-1, false)
            .unwrap();
        assert_eq!(fused.dims(), &[rows]);
        let (f, w) = (fused.to_vec(), want.to_vec());
        for i in 0..rows {
            assert_eq!(f[i].to_bits(), w[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn fused_axis_reduce_empty_rows_and_outputs() {
        let empty = Tensor::from_vec(Vec::new(), &[0, 4]).unwrap();
        let y = fused_axis_reduce(
            &[&empty],
            empty.shape(),
            1,
            |ins, out| {
                for (i, o) in out.iter_mut().enumerate() {
                    o.write(ins[0][i]);
                }
            },
            crate::ops::kernels::sum,
            |t, _| t,
            0.0,
            &[0],
        )
        .unwrap();
        assert_eq!(y.dims(), &[0]);
        let zero_k = Tensor::from_vec(Vec::new(), &[3, 0]).unwrap();
        let y = fused_axis_reduce(
            &[&zero_k],
            zero_k.shape(),
            1,
            |ins, out| {
                for (i, o) in out.iter_mut().enumerate() {
                    o.write(ins[0][i]);
                }
            },
            crate::ops::kernels::sum,
            |t, _| t,
            0.0,
            &[3],
        )
        .unwrap();
        assert_eq!(y.to_vec(), vec![0.0; 3]);
    }

    #[test]
    fn reduce_fixed_single_chunk_is_exact_serial() {
        let v: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let got = reduce_fixed(v.len(), REDUCE_CHUNK, |a, b| v[a..b].iter().sum(), |x, y| {
            x + y
        })
        .unwrap();
        assert_eq!(got, 499500.0);
        assert!(reduce_fixed(0, REDUCE_CHUNK, |_, _| 0.0, |x, y| x + y).is_none());
    }

    #[test]
    fn fused_reduce_matches_materialize_then_reduce_fixed() {
        // Large enough for several REDUCE_CHUNK partials.
        let n = REDUCE_CHUNK * 2 + 123;
        let a = Tensor::arange(0.0, n as f32).mul_scalar(1e-3);
        let b = Tensor::arange(0.0, n as f32).mul_scalar(-2e-3);
        let kernel = |ins: &[&[f32]], out: &mut [MaybeUninit<f32>]| {
            for (i, o) in out.iter_mut().enumerate() {
                o.write((ins[0][i] * ins[1][i] + ins[0][i]).max(0.0));
            }
        };
        let fused = fused_reduce(
            &[&a, &b],
            a.shape(),
            3,
            kernel,
            crate::ops::kernels::sum,
            |x, y| x + y,
        )
        .unwrap()
        .unwrap();
        let mat = a.mul(&b).unwrap().add(&a).unwrap().relu();
        let md = mat.contiguous_data().unwrap();
        let want = reduce_fixed(
            md.len(),
            REDUCE_CHUNK,
            |s, e| crate::ops::kernels::sum(&md[s..e]),
            |x, y| x + y,
        )
        .unwrap();
        assert_eq!(fused.to_bits(), want.to_bits(), "bitwise partial parity");
    }
}
