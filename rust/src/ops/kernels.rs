//! Low-level bulk kernels over contiguous `f32` slices.
//!
//! The bulk entries (`sum`, `dot`, `max`, `min`, `axpy`, `scale`,
//! `add_assign`, `logsumexp`) dispatch through the explicit 8-lane SIMD
//! layer in [`crate::runtime::simd`] (AVX2 / NEON / scalar blocks picked
//! at runtime, `MINITENSOR_SIMD=off` to force scalar). The folds keep the
//! seed kernels' exact shape — 8 partial accumulators, sequential lane
//! fold, scalar tail — so results are bit-identical across paths and
//! bit-identical to the original autovectorized code. `fast_exp` and
//! `select` stay here as the scalar twins the vector kernels mirror
//! lane-for-lane; `binary_map`/`unary_map` remain closure-generic helpers
//! for callers outside the known op families.

use crate::runtime::simd;

/// Apply `f` elementwise over two equal-length inputs into `out`.
#[inline]
pub fn binary_map(a: &[f32], b: &[f32], out: &mut [f32], f: impl Fn(f32, f32) -> f32) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = f(x, y);
    }
}

/// Apply `f` elementwise over one input into `out`.
#[inline]
pub fn unary_map(a: &[f32], out: &mut [f32], f: impl Fn(f32) -> f32) {
    debug_assert_eq!(a.len(), out.len());
    for (o, &x) in out.iter_mut().zip(a) {
        *o = f(x);
    }
}

/// `out[i] = a[i] * s + out[i]` — multiply-accumulate with a scalar
/// (plain mul+add per lane, bit-identical to the seed scalar loop).
#[inline]
pub fn axpy(s: f32, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len());
    simd::axpy(s, a, out);
}

/// Sum with 8-way partial accumulators.
///
/// Splitting the reduction across independent accumulators breaks the
/// loop-carried dependence (one vector register on the SIMD paths); the
/// fixed summation tree — lane `j` accumulates elements ≡ `j` mod 8,
/// sequential lane fold, scalar tail — makes results deterministic across
/// runs and bit-identical across SIMD paths.
#[inline]
pub fn sum(a: &[f32]) -> f32 {
    simd::sum(a)
}

/// Dot product with 8-way partial accumulators (same fold as [`sum`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// Maximum element. Deterministic 8-lane fold of `max_s` (`if a > b { a }
/// else { b }` — what `maxps` computes); on NaN-free data this is the
/// plain maximum.
#[inline]
pub fn max(a: &[f32]) -> f32 {
    simd::max(a)
}

/// Minimum element (same fold shape as [`max`]).
#[inline]
pub fn min(a: &[f32]) -> f32 {
    simd::min(a)
}

/// Index of the maximum element (first occurrence).
#[inline]
pub fn argmax(a: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in a.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Numerically stable log-sum-exp of a slice.
#[inline]
pub fn logsumexp(a: &[f32]) -> f32 {
    let m = max(a);
    if m.is_infinite() {
        return m;
    }
    let s = simd::sum_exp_sub(a, m);
    m + s.ln()
}

/// Fast branch-free `e^x` (EXPERIMENTS.md §Perf L3.3).
///
/// Splits `x·log2(e) = k + f` with `k = ⌊·⌋`, evaluates `2^f` by a
/// degree-7 Taylor polynomial in `f·ln2`, and applies `2^k` through the
/// float exponent bits. Max relative error ≈ 4e-6 over the full range
/// (7e-7 truncation + Horner rounding) — below f32 noise for every
/// consumer (softmax, CE, sigmoid). Unlike the libm call this inlines
/// and pipelines inside row loops (~2x faster measured).
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    // Clamp to the finite-result range so the bit trick can't overflow.
    let x = x.clamp(-87.0, 88.0);
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    let t = x * LOG2E;
    let k = t.floor();
    let f = t - k; // in [0, 1)
    // 2^f = e^{f ln2}: Taylor coefficients ln2^i / i!.
    let p = 1.0
        + f * (0.693_147_18
            + f * (0.240_226_51
                + f * (0.055_504_11
                    + f * (0.009_618_129
                        + f * (0.001_333_355_8
                            + f * (1.540_353e-4 + f * 1.525_273_4e-5))))));
    let bits = ((k as i32 + 127) as u32) << 23;
    f32::from_bits(bits) * p
}

/// Ternary select: `cond != 0 ? a : b`. The one definition shared by the
/// eager `Tensor::where_cond` and the fusion IR's `where_cond`
/// instruction, which is what keeps the two bitwise-equal.
#[inline]
pub fn select(cond: f32, a: f32, b: f32) -> f32 {
    if cond != 0.0 {
        a
    } else {
        b
    }
}

/// In-place scale: `a[i] *= s`.
#[inline]
pub fn scale(a: &mut [f32], s: f32) {
    simd::un_ip(simd::UnOp::MulScalar(s), a);
}

/// In-place add: `a[i] += b[i]`.
#[inline]
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    simd::bin_ip(simd::BinOp::Add, a, b);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_and_unary_map() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        let mut out = [0.0; 3];
        binary_map(&a, &b, &mut out, |x, y| x * y);
        assert_eq!(out, [4.0, 10.0, 18.0]);
        unary_map(&a, &mut out, |x| -x);
        assert_eq!(out, [-1.0, -2.0, -3.0]);
    }

    #[test]
    fn sum_matches_naive_on_odd_lengths() {
        for n in [0usize, 1, 7, 8, 9, 31, 100] {
            let v: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            let naive: f32 = v.iter().sum();
            assert!((sum(&v) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32) * 0.1).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn extrema_and_argmax() {
        let v = [3.0, -1.0, 7.0, 7.0, 2.0];
        assert_eq!(max(&v), 7.0);
        assert_eq!(min(&v), -1.0);
        assert_eq!(argmax(&v), 2); // first occurrence
    }

    #[test]
    fn fast_exp_accuracy_across_range() {
        // Max relative error must stay under ~1e-6 over the working range.
        let mut max_rel = 0.0f32;
        let mut x = -80.0f32;
        while x < 80.0 {
            let got = fast_exp(x);
            let want = x.exp();
            let rel = ((got - want) / want).abs();
            max_rel = max_rel.max(rel);
            x += 0.0137;
        }
        // Theoretical truncation ≈7e-7; f32 rounding through the Horner
        // chain brings observed worst case to ~4e-6 — still well below
        // every consumer's tolerance (softmax/CE compare at 1e-5).
        assert!(max_rel < 5e-6, "max_rel={max_rel}");
        // exact anchor points
        assert_eq!(fast_exp(0.0), 1.0);
        assert!(fast_exp(-100.0) >= 0.0 && fast_exp(-100.0) < 1e-37);
        assert!(fast_exp(100.0).is_finite()); // clamped, not inf/nan
    }

    #[test]
    fn logsumexp_stable_for_large_inputs() {
        let v = [1000.0, 1000.0];
        let lse = logsumexp(&v);
        assert!((lse - (1000.0 + 2f32.ln())).abs() < 1e-3);
        assert!(lse.is_finite());
    }

    #[test]
    fn axpy_scale_add_assign() {
        let mut out = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut out);
        assert_eq!(out, vec![7.0, 9.0]);
        scale(&mut out, 0.5);
        assert_eq!(out, vec![3.5, 4.5]);
        add_assign(&mut out, &[0.5, 0.5]);
        assert_eq!(out, vec![4.0, 5.0]);
    }
}
