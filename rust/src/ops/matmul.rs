//! Matrix multiplication (paper §3.1, eq 1).
//!
//! The 2-D kernel is a cache-blocked, register-tiled SGEMM: full 4×16
//! tiles run the explicit FMA micro-kernel in [`crate::runtime::simd`]
//! (8 accumulator vectors held in registers across the K loop — AVX2
//! `vfmadd` on x86_64, NEON `fmla` on aarch64, correctly-rounded
//! `f32::mul_add` on the scalar path, so all paths are bit-equal), with
//! the B matrix pre-packed row-major per block and A packed into
//! MR-interleaved column panels. Ragged edge tiles keep a shared scalar
//! loop. MC row-panels of C are independent, so the panel loop fans out
//! over the worker pool (each task packs its own A panel; the packed B
//! block is shared read-only). Per-element accumulation order is
//! unchanged, so results are identical at any thread count. Batched
//! (≥3-D) matmul broadcasts leading dims and parallelizes over the batch
//! instead (the per-batch SGEMM then runs serially on its worker).

use super::exec;
use crate::error::{Error, Result};
use crate::runtime::simd;
use crate::tensor::Tensor;

/// Cache block sizes (elements). MC×KC panel of A (~128 KiB) and KC×NC
/// panel of B stay L2-resident on typical CPUs.
const MC: usize = 64;
const KC: usize = 512;
const NC: usize = 256;

/// Register tile: each micro-kernel iteration produces a 4×16 block of C.
/// 4×16 f32 accumulators = 8 YMM registers, plus 2 for the B row and one
/// broadcast for A — fits AVX2's 16-register file without spills.
const MR: usize = 4;
const NR: usize = 16;

/// `C[m×n] = A[m×k] · B[k×n]` over contiguous row-major slices.
///
/// Perf-pass design (EXPERIMENTS.md §Perf L3.1): both operands are packed
/// — B into row-major KC×NC panels, A into MR-interleaved column panels —
/// so the micro-kernel reads two contiguous streams and keeps the full
/// 4×16 accumulator block in registers across the K loop.
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);

    // Small-problem fast path: direct triple loop with contiguous inner
    // accumulation — packing overhead only pays off once the working set
    // leaves L1 (measured crossover ≈ 64³, EXPERIMENTS.md §Perf L3.1).
    if m * n * k <= 64 * 64 * 64 {
        sgemm_naive(m, k, n, a, b, c);
        return;
    }

    let mut packed_b = vec![0.0f32; KC * NC];
    let n_panels = m.div_ceil(MC);
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            // Pack B[pc..pc+kc, jc..jc+nc] row-major into packed_b.
            for p in 0..kc {
                let src = &b[(pc + p) * n + jc..(pc + p) * n + jc + nc];
                packed_b[p * nc..p * nc + nc].copy_from_slice(src);
            }
            // MC row-panels write disjoint row bands of C: fan the panel
            // loop out over the pool. Each task owns a private MR-padded
            // A pack buffer; packed_b is shared read-only.
            let c_ptr = exec::SyncPtr::new_raw(c.as_mut_ptr());
            let pb = &packed_b;
            exec::for_chunks(n_panels, 2 * MC * kc * nc, |p0, p1| {
                // Per-task A pack buffer, recycled through the (worker-
                // thread-local) pool so repeated blocks don't churn the
                // allocator with 128 KiB mmaps.
                let pa_len = MC.div_ceil(MR) * MR * KC;
                let mut packed_a = crate::tensor::pool::take(pa_len);
                packed_a.resize(pa_len, 0.0);
                for panel in p0..p1 {
                    let ic = panel * MC;
                    let mc = MC.min(m - ic);
                    pack_a(&a[ic * k + pc..], k, mc, kc, &mut packed_a);
                    // SAFETY: the macro kernel touches rows ic..ic+mc and
                    // columns jc..jc+nc only — panels are row-disjoint.
                    let c_band = unsafe {
                        c_ptr.band(ic * n + jc, (mc - 1) * n + nc)
                    };
                    macro_kernel(mc, kc, nc, &packed_a, pb, c_band, n);
                }
                crate::tensor::pool::put(packed_a);
            });
        }
    }
}

/// Pack an mc×kc block of A into MR-row interleaved panels:
/// `packed[panel][p][i] = A[panel*MR + i, p]`, zero-padding the tail rows.
/// The micro-kernel then reads A as one contiguous forward stream.
fn pack_a(a: &[f32], lda: usize, mc: usize, kc: usize, packed: &mut [f32]) {
    let mut idx = 0;
    let mut ir = 0;
    while ir < mc {
        let mr = MR.min(mc - ir);
        for p in 0..kc {
            for i in 0..MR {
                packed[idx] = if i < mr { a[(ir + i) * lda + p] } else { 0.0 };
                idx += 1;
            }
        }
        ir += MR;
    }
}

/// Multiply packed A panels by a packed KC×NC block of B into C.
fn macro_kernel(
    mc: usize,
    kc: usize,
    nc: usize,
    packed_a: &[f32],
    packed_b: &[f32],
    c: &mut [f32],
    ldc: usize,
) {
    let mut ir = 0;
    let mut panel = 0;
    while ir < mc {
        let mr = MR.min(mc - ir);
        let a_panel = &packed_a[panel * MR * kc..(panel + 1) * MR * kc];
        let mut jr = 0;
        while jr < nc {
            let nr = NR.min(nc - jr);
            if nr == NR && mr == MR {
                // Full 4×16 tile: explicit FMA register tile. The B
                // operand is the packed kc×nc block starting at column
                // `jr`, row stride `nc`.
                // SAFETY: `mr == MR && nr == NR` means rows ir..ir+MR and
                // columns jr..jr+NR all lie inside this panel's C band
                // (len `(mc-1)*ldc + nc`), `a_panel` holds `kc * MR`
                // floats, and `packed_b[jr..]` has `kc` rows of stride
                // `nc` with `NR` readable floats each (`jr + NR <= nc`).
                unsafe {
                    simd::sgemm_micro_4x16(
                        kc,
                        a_panel,
                        &packed_b[jr..],
                        nc,
                        c.as_mut_ptr().add(ir * ldc + jr),
                        ldc,
                    );
                }
            } else if nr == NR {
                micro_kernel(kc, a_panel, packed_b, jr, nc, c, ir, ldc, mr);
            } else {
                // Edge tile: scalar loop over the ragged columns.
                for i in 0..mr {
                    for j in 0..nr {
                        let mut acc = c[(ir + i) * ldc + jr + j];
                        for p in 0..kc {
                            acc += a_panel[p * MR + i] * packed_b[p * nc + jr + j];
                        }
                        c[(ir + i) * ldc + jr + j] = acc;
                    }
                }
            }
            jr += NR;
        }
        ir += MR;
        panel += 1;
    }
}

/// Scalar 4×16 register-tiled micro-kernel over packed panels, used for
/// row-tail panels (`mr < MR`) where the explicit SIMD tile can't write
/// all four C rows. Fixed-size array views (`try_into`) give LLVM exact
/// trip counts on the j-loops.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    kc: usize,
    a_panel: &[f32],
    packed_b: &[f32],
    jr: usize,
    nc: usize,
    c: &mut [f32],
    ir: usize,
    ldc: usize,
    mr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let av: &[f32; MR] = a_panel[p * MR..p * MR + MR].try_into().unwrap();
        let brow: &[f32; NR] = packed_b[p * nc + jr..p * nc + jr + NR].try_into().unwrap();
        for i in 0..MR {
            let ai = av[i];
            for j in 0..NR {
                acc[i][j] += ai * brow[j];
            }
        }
    }
    for (i, acc_i) in acc.iter().enumerate().take(mr) {
        let crow = &mut c[(ir + i) * ldc + jr..(ir + i) * ldc + jr + NR];
        for j in 0..NR {
            crow[j] += acc_i[j];
        }
    }
}

/// Reference triple-loop GEMM (also the small-size fast path).
pub fn sgemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// 2-D (or batched ≥3-D with broadcastable leading dims) matrix product.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.rank() < 2 || b.rank() < 2 {
        return Err(Error::ShapeMismatch {
            op: "matmul",
            expected: "rank >= 2".into(),
            got: format!("{} x {}", a.shape(), b.shape()),
        });
    }
    let (ar, br) = (a.rank(), b.rank());
    let (m, ka) = (a.dims()[ar - 2], a.dims()[ar - 1]);
    let (kb, n) = (b.dims()[br - 2], b.dims()[br - 1]);
    if ka != kb {
        return Err(Error::ShapeMismatch {
            op: "matmul",
            expected: format!("inner dims equal, lhs has k={ka}"),
            got: format!("rhs has k={kb}"),
        });
    }

    if ar == 2 && br == 2 {
        crate::runtime::stats::record_dispatch();
        crate::runtime::stats::record_output_alloc();
        let mut sp = crate::runtime::trace::span("exec", "matmul");
        sp.arg_u("m", m as u64);
        sp.arg_u("k", ka as u64);
        sp.arg_u("n", n as u64);
        let ac = a.contiguous();
        let bc = b.contiguous();
        let mut c = vec![0.0f32; m * n];
        sgemm(
            m,
            ka,
            n,
            ac.contiguous_data().unwrap(),
            bc.contiguous_data().unwrap(),
            &mut c,
        );
        return Tensor::from_vec(c, &[m, n]);
    }

    // Batched: broadcast leading dims.
    let lead_a = crate::shape::Shape::new(&a.dims()[..ar - 2]);
    let lead_b = crate::shape::Shape::new(&b.dims()[..br - 2]);
    let lead = lead_a.broadcast(&lead_b)?;
    let batch = lead.numel();

    let mut a_dims = lead.dims().to_vec();
    a_dims.extend([m, ka]);
    let mut b_dims = lead.dims().to_vec();
    b_dims.extend([ka, n]);
    let ab = a.broadcast_to(&a_dims)?.contiguous();
    let bb = b.broadcast_to(&b_dims)?.contiguous();
    let sa = ab.contiguous_data().unwrap();
    let sb = bb.contiguous_data().unwrap();

    // Batch entries are independent: fan out over the pool (the nested
    // SGEMM detects it is on a worker and stays serial).
    crate::runtime::stats::record_dispatch();
    crate::runtime::stats::record_output_alloc();
    let mut sp = crate::runtime::trace::span("exec", "matmul_batched");
    sp.arg_u("batch", batch as u64);
    sp.arg_u("m", m as u64);
    sp.arg_u("n", n as u64);
    let mut out = vec![0.0f32; batch * m * n];
    let optr = exec::SyncPtr::new_raw(out.as_mut_ptr());
    exec::for_chunks(batch, 2 * m * ka * n, |b0, b1| {
        for i in b0..b1 {
            // SAFETY: each batch index owns a disjoint slab of `out`.
            let c = unsafe { optr.band(i * m * n, m * n) };
            sgemm(
                m,
                ka,
                n,
                &sa[i * m * ka..(i + 1) * m * ka],
                &sb[i * ka * n..(i + 1) * ka * n],
                c,
            );
        }
    });
    let mut out_dims = lead.dims().to_vec();
    out_dims.extend([m, n]);
    Tensor::from_vec(out, &out_dims)
}

/// Batched matmul over explicit 4-D inputs `[b, h, m, k] x [b, h, k, n]`
/// (attention-style layout), kept as a separate entry point for benches.
pub fn matmul_4d_batched(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.rank() != 4 || b.rank() != 4 {
        return Err(Error::ShapeMismatch {
            op: "matmul_4d_batched",
            expected: "rank 4".into(),
            got: format!("{} x {}", a.shape(), b.shape()),
        });
    }
    matmul(a, b)
}

impl Tensor {
    /// `self · other` (see [`matmul`]).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        matmul(self, other)
    }

    /// `x · Wᵀ` — the Dense-layer product of paper eq (1)/(5), fused so the
    /// transpose is free (reads W row-major as the RHS panel directly).
    pub fn matmul_nt(&self, w: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || w.rank() != 2 {
            return Err(Error::ShapeMismatch {
                op: "matmul_nt",
                expected: "rank 2 both sides".into(),
                got: format!("{} x {}", self.shape(), w.shape()),
            });
        }
        let (m, k) = (self.dims()[0], self.dims()[1]);
        let (d, kw) = (w.dims()[0], w.dims()[1]);
        if k != kw {
            return Err(Error::ShapeMismatch {
                op: "matmul_nt",
                expected: format!("inner dims equal, x has k={k}"),
                got: format!("W has k={kw}"),
            });
        }
        crate::runtime::stats::record_dispatch();
        let mut sp = crate::runtime::trace::span("exec", "matmul_nt");
        sp.arg_u("m", m as u64);
        sp.arg_u("k", k as u64);
        sp.arg_u("n", d as u64);
        let xc = self.contiguous();
        let wc = w.contiguous();
        let xs = xc.contiguous_data().unwrap();
        let ws = wc.contiguous_data().unwrap();
        // C[i,j] = dot(x[i,:], w[j,:]) — both rows contiguous; output rows
        // are independent, so the row loop fans out over the pool.
        let out_len = m * d;
        if out_len == 0 {
            return Tensor::from_vec(Vec::new(), &[m, d]);
        }
        let mut out = exec::take_output(out_len);
        let ptr = exec::SyncPtr::new(&mut out);
        exec::for_chunks(m, 2 * k * d, |i0, i1| {
            for i in i0..i1 {
                let xrow = &xs[i * k..(i + 1) * k];
                for j in 0..d {
                    // SAFETY: row ranges are disjoint per chunk.
                    unsafe {
                        ptr.write(i * d + j, super::kernels::dot(xrow, &ws[j * k..(j + 1) * k]))
                    };
                }
            }
        });
        // SAFETY: every output row was written above.
        unsafe { out.set_len(out_len) };
        Tensor::from_vec(out, &[m, d])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn small_matmul_exact() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]).unwrap();
        let b = Tensor::from_vec(vec![5., 6., 7., 8.], &[2, 2]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.to_vec(), vec![19., 22., 43., 50.]);
    }

    #[test]
    fn rectangular() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        let b = Tensor::from_vec(vec![1., 0., 0., 1., 1., 1.], &[3, 2]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.dims(), &[2, 2]);
        assert_eq!(c.to_vec(), vec![1. + 3., 2. + 3., 4. + 6., 5. + 6.]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 5], 0.0, 1.0, &mut rng);
        let c = a.matmul(&Tensor::eye(5)).unwrap();
        assert!(c.allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn blocked_matches_naive_on_large_odd_sizes() {
        let mut rng = Rng::new(2);
        for (m, k, n) in [(65, 70, 33), (100, 257, 40), (128, 64, 96)] {
            let a = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 0.0, 1.0, &mut rng);
            let mut c_naive = vec![0.0f32; m * n];
            sgemm_naive(
                m,
                k,
                n,
                a.contiguous_data().unwrap(),
                b.contiguous_data().unwrap(),
                &mut c_naive,
            );
            let c = a.matmul(&b).unwrap();
            let expect = Tensor::from_vec(c_naive, &[m, n]).unwrap();
            assert!(
                c.allclose(&expect, 1e-4, 1e-4),
                "mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        assert!(a.matmul(&b).is_err());
        let v = Tensor::zeros(&[3]);
        assert!(a.matmul(&v).is_err());
    }

    #[test]
    fn batched_3d() {
        let a = Tensor::arange(0.0, 12.0).reshape(&[2, 2, 3]).unwrap();
        let b = Tensor::arange(0.0, 12.0).reshape(&[2, 3, 2]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.dims(), &[2, 2, 2]);
        // batch 0: [[0,1,2],[3,4,5]] x [[0,1],[2,3],[4,5]]
        assert_eq!(c.at(&[0, 0, 0]).unwrap(), 0.0 * 0.0 + 1.0 * 2.0 + 2.0 * 4.0);
        assert_eq!(c.at(&[0, 1, 1]).unwrap(), 3.0 * 1.0 + 4.0 * 3.0 + 5.0 * 5.0);
    }

    #[test]
    fn batched_broadcast_lhs() {
        // [2,2,3] x [3,2] broadcasts the rhs across the batch
        let a = Tensor::arange(0.0, 12.0).reshape(&[2, 2, 3]).unwrap();
        let b = Tensor::arange(0.0, 6.0).reshape(&[3, 2]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.dims(), &[2, 2, 2]);
        let b0 = a.select(0, 0).unwrap().matmul(&b).unwrap();
        assert_eq!(c.select(0, 0).unwrap().to_vec(), b0.to_vec());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[7, 5], 0.0, 1.0, &mut rng);
        let w = Tensor::randn(&[4, 5], 0.0, 1.0, &mut rng);
        let direct = x.matmul_nt(&w).unwrap();
        let via_t = x.matmul(&w.t().unwrap()).unwrap();
        assert!(direct.allclose(&via_t, 1e-4, 1e-5));
    }

    #[test]
    fn matmul_on_transposed_view() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[4, 6], 0.0, 1.0, &mut rng);
        let at = a.t().unwrap(); // [6,4] strided view
        let b = Tensor::randn(&[4, 3], 0.0, 1.0, &mut rng);
        let c = at.matmul(&b).unwrap();
        let c_ref = a.contiguous().t().unwrap().contiguous().matmul(&b).unwrap();
        assert!(c.allclose(&c_ref, 1e-5, 1e-6));
    }

    #[test]
    fn matmul_4d() {
        let a = Tensor::ones(&[2, 3, 4, 5]);
        let b = Tensor::ones(&[2, 3, 5, 6]);
        let c = matmul_4d_batched(&a, &b).unwrap();
        assert_eq!(c.dims(), &[2, 3, 4, 6]);
        assert_eq!(c.at(&[1, 2, 3, 4]).unwrap(), 5.0);
        assert!(matmul_4d_batched(&a, &Tensor::ones(&[5, 6])).is_err());
    }
}
