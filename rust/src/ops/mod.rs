//! Bulk tensor operations (paper §3.1): elementwise arithmetic with
//! broadcasting, unary maps, reductions, matrix multiplication,
//! convolution, pooling, and softmax.
//!
//! Layering: `kernels` holds the raw slice loops; each op first tries the
//! contiguous fast path through `kernels`, falling back to strided
//! iteration for views. Autograd (`crate::autograd`) wraps these
//! non-differentiable primitives with pullbacks.

pub mod attention;
pub mod conv;
pub mod elementwise;
pub mod kernels;
pub mod matmul;
pub mod reduce;
pub mod softmax;
pub mod unary;

pub use attention::attention;
pub use conv::{avg_pool2d, conv2d, max_pool2d, Conv2dSpec};
pub use matmul::{matmul, matmul_4d_batched};
