//! Bulk tensor operations (paper §3.1): elementwise arithmetic with
//! broadcasting, unary maps, reductions, matrix multiplication,
//! convolution, pooling, and softmax.
//!
//! Layering: `kernels` holds the raw slice loops; `exec` owns tier
//! dispatch (contiguous / bias-row / strided), pooled output allocation,
//! and data-parallel chunking over the persistent worker pool — every op
//! file funnels through it instead of hand-rolling its own dispatch.
//! Autograd (`crate::autograd`) wraps these non-differentiable primitives
//! with pullbacks.

pub mod attention;
pub mod conv;
pub mod elementwise;
pub mod exec;
pub mod kernels;
pub mod matmul;
pub mod reduce;
pub mod softmax;
pub mod unary;

pub use attention::{attention, attention_backward, attention_forward};
pub use conv::{avg_pool2d, conv2d, max_pool2d, Conv2dSpec};
pub use matmul::{matmul, matmul_4d_batched};
