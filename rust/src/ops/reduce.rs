//! Reductions: sum, mean, max, min, prod, argmax, variance — full and
//! per-axis with optional kept dims (paper §3.1: "reductions implement
//! linear functionals such as sum and averages such as mean").
//!
//! Axis reductions are decomposed as `[outer, axis, inner]` loops; when
//! `inner == 1` (reducing the last axis of a contiguous tensor) the inner
//! loop is a contiguous slice reduction through `kernels`. Both axis and
//! full reductions dispatch through the execution layer: axis reductions
//! parallelize over the outer index (per-output arithmetic order is
//! unchanged, so results are identical at any thread count); full
//! reductions fold per-chunk partials over the **fixed**
//! [`exec::REDUCE_CHUNK`] partition in ascending chunk order
//! ([`exec::reduce_fixed`]), so they too are bit-identical at any
//! `MINITENSOR_NUM_THREADS` — and bitwise-equal to the lazy graph's
//! fused-reduce epilogue, which computes the same partials over the same
//! boundaries. Reductions of at most one chunk (≤ 32768 elements) are
//! exactly the serial slice kernel.

use super::{exec, kernels};
use crate::dtype::DType;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// How a reduction combines elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceKind {
    Sum,
    Max,
    Min,
    Prod,
}

impl ReduceKind {
    fn identity(self) -> f32 {
        match self {
            ReduceKind::Sum => 0.0,
            ReduceKind::Max => f32::NEG_INFINITY,
            ReduceKind::Min => f32::INFINITY,
            ReduceKind::Prod => 1.0,
        }
    }

    #[inline]
    fn combine(self, a: f32, b: f32) -> f32 {
        match self {
            ReduceKind::Sum => a + b,
            ReduceKind::Max => a.max(b),
            ReduceKind::Min => a.min(b),
            ReduceKind::Prod => a * b,
        }
    }
}

/// Reduce one contiguous slice with the tuned slice kernels.
#[inline]
fn reduce_slice(s: &[f32], kind: ReduceKind) -> f32 {
    match kind {
        ReduceKind::Sum => kernels::sum(s),
        ReduceKind::Max => kernels::max(s),
        ReduceKind::Min => kernels::min(s),
        ReduceKind::Prod => s.iter().product(),
    }
}

/// Reduce every element to a scalar tensor.
pub fn reduce_all(t: &Tensor, kind: ReduceKind) -> Tensor {
    crate::runtime::stats::record_dispatch();
    let v = match (kind, t.contiguous_data()) {
        (ReduceKind::Prod, _) | (_, None) => t
            .iter()
            .fold(kind.identity(), |acc, v| kind.combine(acc, v)),
        (_, Some(s)) => {
            // Order-stable partials over the fixed REDUCE_CHUNK partition,
            // folded in chunk order: bit-identical at any thread count
            // (single chunk ⇒ exactly the serial kernel's value).
            exec::reduce_fixed(
                s.len(),
                exec::REDUCE_CHUNK,
                |a, b| reduce_slice(&s[a..b], kind),
                |x, y| kind.combine(x, y),
            )
            .unwrap_or_else(|| kind.identity())
        }
    };
    Tensor::scalar(v)
}

/// Reduce along one axis. `keepdim` keeps the reduced axis with size 1.
pub fn reduce_axis(t: &Tensor, axis: isize, kind: ReduceKind, keepdim: bool) -> Result<Tensor> {
    let ax = t.shape().normalize_axis(axis)?;
    crate::runtime::stats::record_dispatch();
    let dims = t.dims();
    let outer: usize = dims[..ax].iter().product();
    let len = dims[ax];
    let inner: usize = dims[ax + 1..].iter().product();
    let out_len = outer * inner;

    let mut out_dims = dims.to_vec();
    if keepdim {
        out_dims[ax] = 1;
    } else {
        out_dims.remove(ax);
    }

    // Degenerate axes: nothing to combine — every output is the identity
    // (an empty reduced axis), or there are no outputs at all.
    if out_len == 0 || len == 0 {
        return Tensor::from_vec(vec![kind.identity(); out_len], &out_dims);
    }

    let src = t.contiguous();
    let s = src.contiguous_data().unwrap();

    if inner == 1 {
        // Fast path: each output reduces one contiguous row; rows split
        // across the pool, per-row order untouched (thread-count
        // independent results). Raw single-element writes, so the pooled
        // buffer needs no initialization pass.
        let mut out = exec::take_output(out_len);
        let ptr = exec::SyncPtr::new(&mut out);
        exec::for_chunks(outer, len, |o0, o1| {
            for (o, row) in (o0..o1).zip(s[o0 * len..o1 * len].chunks_exact(len)) {
                // SAFETY: output ranges are disjoint per chunk.
                unsafe { ptr.write(o, reduce_slice(row, kind)) };
            }
        });
        // SAFETY: every output element was written exactly once.
        unsafe { out.set_len(out_len) };
        Tensor::from_vec(out, &out_dims)
    } else {
        // Strided: accumulate axis slices onto the inner panel — the
        // inner loop is contiguous, so it vectorizes; panels are disjoint
        // per outer index, so the outer loop parallelizes. The panels
        // need the identity as their starting value anyway, so the
        // resize doubles as the initialization that makes the parallel
        // slice hand-off sound.
        let mut out = exec::take_output(out_len);
        out.resize(out_len, kind.identity());
        let ptr = exec::SyncPtr::new(&mut out);
        exec::for_chunks(outer, len * inner, |o0, o1| {
            // SAFETY: panel ranges are disjoint per chunk and initialized.
            let panels = unsafe { ptr.slice(o0 * inner, o1 * inner) };
            for (panel, o) in panels.chunks_exact_mut(inner).zip(o0..o1) {
                let base = o * len * inner;
                for a in 0..len {
                    let row = &s[base + a * inner..base + (a + 1) * inner];
                    for (pv, &rv) in panel.iter_mut().zip(row) {
                        *pv = kind.combine(*pv, rv);
                    }
                }
            }
        });
        Tensor::from_vec(out, &out_dims)
    }
}

impl Tensor {
    /// Sum of all elements → scalar tensor.
    pub fn sum(&self) -> Tensor {
        reduce_all(self, ReduceKind::Sum)
    }

    /// Mean of all elements → scalar tensor.
    pub fn mean(&self) -> Tensor {
        self.sum().mul_scalar(1.0 / self.numel() as f32)
    }

    /// Max of all elements → scalar tensor.
    pub fn max_all(&self) -> Tensor {
        reduce_all(self, ReduceKind::Max)
    }

    /// Min of all elements → scalar tensor.
    pub fn min_all(&self) -> Tensor {
        reduce_all(self, ReduceKind::Min)
    }

    /// Product of all elements → scalar tensor.
    pub fn prod_all(&self) -> Tensor {
        reduce_all(self, ReduceKind::Prod)
    }

    /// Sum along `axis`.
    pub fn sum_axis(&self, axis: isize, keepdim: bool) -> Result<Tensor> {
        reduce_axis(self, axis, ReduceKind::Sum, keepdim)
    }

    /// Mean along `axis`.
    pub fn mean_axis(&self, axis: isize, keepdim: bool) -> Result<Tensor> {
        let ax = self.shape().normalize_axis(axis)?;
        let n = self.dims()[ax] as f32;
        Ok(self.sum_axis(axis, keepdim)?.mul_scalar(1.0 / n))
    }

    /// Max along `axis`.
    pub fn max_axis(&self, axis: isize, keepdim: bool) -> Result<Tensor> {
        reduce_axis(self, axis, ReduceKind::Max, keepdim)
    }

    /// Min along `axis`.
    pub fn min_axis(&self, axis: isize, keepdim: bool) -> Result<Tensor> {
        reduce_axis(self, axis, ReduceKind::Min, keepdim)
    }

    /// Index of the max along `axis` (I32 tensor, axis removed).
    pub fn argmax_axis(&self, axis: isize) -> Result<Tensor> {
        let ax = self.shape().normalize_axis(axis)?;
        let dims = self.dims();
        let outer: usize = dims[..ax].iter().product();
        let len = dims[ax];
        let inner: usize = dims[ax + 1..].iter().product();
        let src = self.contiguous();
        let s = src.contiguous_data().unwrap();
        let mut out = vec![0.0f32; outer * inner];
        for o in 0..outer {
            for i in 0..inner {
                let mut best = 0usize;
                let mut bv = f32::NEG_INFINITY;
                for a in 0..len {
                    let v = s[o * len * inner + a * inner + i];
                    if v > bv {
                        bv = v;
                        best = a;
                    }
                }
                out[o * inner + i] = best as f32;
            }
        }
        let mut out_dims = dims.to_vec();
        out_dims.remove(ax);
        Ok(Tensor::from_vec(out, &out_dims)?.with_dtype(DType::I32))
    }

    /// Index of the min along `axis` (I32 tensor, axis removed).
    pub fn argmin_axis(&self, axis: isize) -> Result<Tensor> {
        self.neg().argmax_axis(axis)
    }

    /// Standard deviation along `axis` (population, ddof=0).
    pub fn std_axis(&self, axis: isize, keepdim: bool) -> Result<Tensor> {
        Ok(self.var_axis(axis, keepdim)?.sqrt())
    }

    /// L2 norm of all elements → scalar tensor.
    pub fn norm(&self) -> Tensor {
        self.square().sum().sqrt()
    }

    /// Cumulative sum along the last axis (contiguous rows).
    pub fn cumsum_lastdim(&self) -> Result<Tensor> {
        let k = *self
            .dims()
            .last()
            .ok_or_else(|| Error::msg("cumsum: rank must be >= 1"))?;
        let src = self.contiguous();
        let s = src.contiguous_data().unwrap();
        let mut out = Vec::with_capacity(s.len());
        for row in s.chunks_exact(k) {
            let mut acc = 0.0f32;
            out.extend(row.iter().map(|&v| {
                acc += v;
                acc
            }));
        }
        Tensor::from_vec(out, self.dims())
    }

    /// Population variance along `axis` (ddof=0, as in BatchNorm eq 7).
    pub fn var_axis(&self, axis: isize, keepdim: bool) -> Result<Tensor> {
        let mean = self.mean_axis(axis, true)?;
        let centered = self.sub(&mean)?;
        let sq = centered.square();
        sq.mean_axis(axis, keepdim)
    }

    /// Sum over a *set* of axes (used by broadcast pullbacks), keeping dims.
    pub fn sum_axes_keepdim(&self, axes: &[usize]) -> Result<Tensor> {
        let mut cur = self.clone();
        for &ax in axes {
            cur = cur.sum_axis(ax as isize, true)?;
        }
        Ok(cur)
    }

    /// Reduce a gradient of `target` shape back to this tensor's shape by
    /// summing the broadcast axes — the generic broadcast pullback.
    pub fn reduce_grad_to(&self, grad: &Tensor) -> Result<Tensor> {
        if grad.shape() == self.shape() {
            return Ok(grad.clone());
        }
        let axes = self.shape().broadcast_reduce_axes(grad.shape());
        let summed = grad.sum_axes_keepdim(&axes)?;
        summed.reshape(self.dims())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t23() -> Tensor {
        Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap()
    }

    #[test]
    fn full_reductions() {
        let t = t23();
        assert_eq!(t.sum().item().unwrap(), 21.0);
        assert_eq!(t.mean().item().unwrap(), 3.5);
        assert_eq!(t.max_all().item().unwrap(), 6.0);
        assert_eq!(t.min_all().item().unwrap(), 1.0);
        assert_eq!(t.prod_all().item().unwrap(), 720.0);
    }

    #[test]
    fn axis_reductions_last_axis() {
        let t = t23();
        let s = t.sum_axis(1, false).unwrap();
        assert_eq!(s.dims(), &[2]);
        assert_eq!(s.to_vec(), vec![6., 15.]);
        let m = t.mean_axis(-1, false).unwrap();
        assert_eq!(m.to_vec(), vec![2., 5.]);
    }

    #[test]
    fn axis_reductions_leading_axis() {
        let t = t23();
        let s = t.sum_axis(0, false).unwrap();
        assert_eq!(s.dims(), &[3]);
        assert_eq!(s.to_vec(), vec![5., 7., 9.]);
        let mx = t.max_axis(0, false).unwrap();
        assert_eq!(mx.to_vec(), vec![4., 5., 6.]);
        let mn = t.min_axis(0, false).unwrap();
        assert_eq!(mn.to_vec(), vec![1., 2., 3.]);
    }

    #[test]
    fn keepdim_shapes() {
        let t = t23();
        assert_eq!(t.sum_axis(1, true).unwrap().dims(), &[2, 1]);
        assert_eq!(t.sum_axis(0, true).unwrap().dims(), &[1, 3]);
    }

    #[test]
    fn middle_axis_3d() {
        let t = Tensor::arange(0.0, 24.0).reshape(&[2, 3, 4]).unwrap();
        let s = t.sum_axis(1, false).unwrap();
        assert_eq!(s.dims(), &[2, 4]);
        // manual check: sum over axis 1 for [0,0,:] = 0+4+8 = 12
        assert_eq!(s.at(&[0, 0]).unwrap(), 12.0);
        assert_eq!(s.at(&[1, 3]).unwrap(), 15.0 + 19.0 + 23.0);
    }

    #[test]
    fn argmax() {
        let t = Tensor::from_vec(vec![1., 9., 3., 7., 5., 2.], &[2, 3]).unwrap();
        let a = t.argmax_axis(1).unwrap();
        assert_eq!(a.dtype(), DType::I32);
        assert_eq!(a.to_vec(), vec![1.0, 0.0]);
        let a0 = t.argmax_axis(0).unwrap();
        assert_eq!(a0.to_vec(), vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn variance() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]).unwrap();
        let v = t.var_axis(1, false).unwrap();
        assert_eq!(v.to_vec(), vec![0.25, 0.25]);
    }

    #[test]
    fn argmin_std_norm_cumsum() {
        let t = Tensor::from_vec(vec![3., 1., 2., 0., 5., 4.], &[2, 3]).unwrap();
        assert_eq!(t.argmin_axis(1).unwrap().to_vec(), vec![1.0, 0.0]);
        let s = t.std_axis(1, false).unwrap();
        let expect = ((2.0f32 / 3.0) as f32).sqrt(); // var of [3,1,2] = 2/3
        assert!((s.to_vec()[0] - expect).abs() < 1e-5);
        let n = Tensor::from_vec(vec![3.0, 4.0], &[2]).unwrap().norm();
        assert!((n.item().unwrap() - 5.0).abs() < 1e-6);
        let c = t.cumsum_lastdim().unwrap();
        assert_eq!(c.to_vec(), vec![3., 4., 6., 0., 5., 9.]);
    }

    #[test]
    fn reduce_grad_to_inverts_broadcast() {
        let b = Tensor::from_vec(vec![1., 2., 3.], &[3]).unwrap();
        let grad = Tensor::ones(&[4, 3]);
        let g = b.reduce_grad_to(&grad).unwrap();
        assert_eq!(g.dims(), &[3]);
        assert_eq!(g.to_vec(), vec![4., 4., 4.]);

        let k = Tensor::zeros(&[2, 1]);
        let grad2 = Tensor::ones(&[2, 5]);
        let g2 = k.reduce_grad_to(&grad2).unwrap();
        assert_eq!(g2.dims(), &[2, 1]);
        assert_eq!(g2.to_vec(), vec![5., 5.]);

        // scalar case
        let s = Tensor::scalar(1.0);
        let g3 = s.reduce_grad_to(&Tensor::ones(&[2, 2])).unwrap();
        assert_eq!(g3.item().unwrap(), 4.0);
    }

    #[test]
    fn reductions_on_views() {
        let t = t23().t().unwrap(); // [3,2] strided
        let s = t.sum_axis(0, false).unwrap();
        assert_eq!(s.to_vec(), vec![6., 15.]);
    }

    #[test]
    fn sum_matches_kernel_on_large() {
        let t = Tensor::arange(0.0, 1000.0);
        assert_eq!(t.sum().item().unwrap(), 499500.0);
    }
}
