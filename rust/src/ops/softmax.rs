//! Numerically stable softmax / log-softmax along the last axis, plus the
//! fused softmax-cross-entropy forward used by the loss (paper eq 8).

use super::kernels;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Softmax along the last axis, computed row-wise with the max-shift trick.
pub fn softmax_lastdim(t: &Tensor) -> Result<Tensor> {
    let src = t.contiguous();
    let s = src.contiguous_data().unwrap();
    let k = *t
        .dims()
        .last()
        .ok_or_else(|| Error::msg("softmax: rank must be >= 1"))?;
    // Independent passes over each (L1-resident) row: the exp pass
    // carries no serial dependency, so fast_exp pipelines; a fused
    // exp+sum loop is ~2x slower (EXPERIMENTS.md §Perf L3.3). The output
    // comes from the buffer pool and is written by `extend` — no
    // zero-fill.
    let mut out = crate::tensor::pool::take(s.len());
    for row in s.chunks_exact(k) {
        let m = kernels::max(row);
        out.extend(row.iter().map(|&v| kernels::fast_exp(v - m)));
    }
    for orow in out.chunks_exact_mut(k) {
        let inv = 1.0 / kernels::sum(orow);
        kernels::scale(orow, inv);
    }
    Tensor::from_vec(out, t.dims())
}

/// Log-softmax along the last axis (stable: `x - m - ln Σ exp(x-m)`).
pub fn log_softmax_lastdim(t: &Tensor) -> Result<Tensor> {
    let src = t.contiguous();
    let s = src.contiguous_data().unwrap();
    let k = *t
        .dims()
        .last()
        .ok_or_else(|| Error::msg("log_softmax: rank must be >= 1"))?;
    let mut out = vec![0.0f32; s.len()];
    for (orow, row) in out.chunks_exact_mut(k).zip(s.chunks_exact(k)) {
        let lse = kernels::logsumexp(row);
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = v - lse;
        }
    }
    Tensor::from_vec(out, t.dims())
}

/// Fused forward of mean cross-entropy over logits `[b, C]` with integer
/// labels `[b]` (paper eq 8). Returns `(loss_scalar, softmax_probs)`; the
/// probs feed the well-known `softmax - onehot` pullback.
pub fn cross_entropy_forward(logits: &Tensor, labels: &Tensor) -> Result<(Tensor, Tensor)> {
    if logits.rank() != 2 || labels.rank() != 1 || logits.dims()[0] != labels.dims()[0] {
        return Err(Error::ShapeMismatch {
            op: "cross_entropy",
            expected: "logits [b, C] with labels [b]".into(),
            got: format!("{} with {}", logits.shape(), labels.shape()),
        });
    }
    let b = logits.dims()[0];
    let c = logits.dims()[1];
    let src = logits.contiguous();
    let s = src.contiguous_data().unwrap();
    let mut probs = vec![0.0f32; b * c];
    let mut loss = 0.0f32;
    for (i, y) in labels.iter().enumerate() {
        let yi = y as usize;
        if yi >= c {
            return Err(Error::IndexOutOfBounds { index: yi, size: c });
        }
        let row = &s[i * c..(i + 1) * c];
        let lse = kernels::logsumexp(row);
        loss -= row[yi] - lse;
        let prow = &mut probs[i * c..(i + 1) * c];
        for (p, &v) in prow.iter_mut().zip(row) {
            *p = kernels::fast_exp(v - lse);
        }
    }
    Ok((
        Tensor::scalar(loss / b as f32),
        Tensor::from_vec(probs, &[b, c])?,
    ))
}

impl Tensor {
    /// Softmax along the last axis.
    pub fn softmax(&self) -> Result<Tensor> {
        softmax_lastdim(self)
    }

    /// Log-softmax along the last axis.
    pub fn log_softmax(&self) -> Result<Tensor> {
        log_softmax_lastdim(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one() {
        let t = Tensor::from_vec(vec![1., 2., 3., 1., 1., 1.], &[2, 3]).unwrap();
        let p = t.softmax().unwrap();
        let sums = p.sum_axis(1, false).unwrap();
        assert!(sums.allclose(&Tensor::ones(&[2]), 1e-5, 1e-6));
        // uniform row → uniform probs
        assert!((p.at(&[1, 0]).unwrap() - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn stable_for_huge_logits() {
        let t = Tensor::from_vec(vec![1000., 1000., -1000.], &[1, 3]).unwrap();
        let p = t.softmax().unwrap();
        assert!(p.iter().all(|v| v.is_finite()));
        assert!((p.at(&[0, 0]).unwrap() - 0.5).abs() < 1e-5);
        assert!(p.at(&[0, 2]).unwrap().abs() < 1e-6);
    }

    #[test]
    fn log_softmax_is_log_of_softmax() {
        let t = Tensor::from_vec(vec![0.5, -1.2, 3.3, 0.0], &[2, 2]).unwrap();
        let ls = t.log_softmax().unwrap();
        let p = t.softmax().unwrap().log();
        assert!(ls.allclose(&p, 1e-5, 1e-6));
    }

    #[test]
    fn softmax_shift_invariance() {
        let t = Tensor::from_vec(vec![1., 2., 3.], &[1, 3]).unwrap();
        let shifted = t.add_scalar(100.0);
        assert!(t
            .softmax()
            .unwrap()
            .allclose(&shifted.softmax().unwrap(), 1e-5, 1e-6));
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        // loss over uniform logits = ln(C)
        let logits = Tensor::zeros(&[4, 10]);
        let labels = Tensor::from_vec_i32(vec![0, 3, 5, 9], &[4]).unwrap();
        let (loss, probs) = cross_entropy_forward(&logits, &labels).unwrap();
        assert!((loss.item().unwrap() - 10f32.ln()).abs() < 1e-5);
        assert!((probs.at(&[0, 0]).unwrap() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_confident_correct_is_small() {
        let mut logits = vec![0.0f32; 2 * 3];
        logits[0] = 20.0; // row 0 very confident class 0
        logits[3 + 1] = 20.0; // row 1 very confident class 1
        let logits = Tensor::from_vec(logits, &[2, 3]).unwrap();
        let labels = Tensor::from_vec_i32(vec![0, 1], &[2]).unwrap();
        let (loss, _) = cross_entropy_forward(&logits, &labels).unwrap();
        assert!(loss.item().unwrap() < 1e-3);
    }

    #[test]
    fn cross_entropy_errors() {
        let logits = Tensor::zeros(&[2, 3]);
        let bad_shape = Tensor::zeros(&[3]);
        assert!(cross_entropy_forward(&logits, &bad_shape).is_err());
        let bad_label = Tensor::from_vec_i32(vec![0, 7], &[2]).unwrap();
        assert!(cross_entropy_forward(&logits, &bad_label).is_err());
    }
}
