//! Numerically stable softmax / log-softmax along the last axis, plus the
//! fused softmax-cross-entropy forward used by the loss (paper eq 8).
//!
//! These are the library's **fused row pipelines**: each op is one
//! dispatch with one pooled output, with the per-row reduce "epilogues"
//! (row max, row sum) folded into the row kernel rather than
//! materialized — the same shape the lazy graph lowers softmax-style
//! DAGs to through `exec::fused_axis_reduce`. Everything routes through
//! the execution layer's block row dispatcher ([`exec::map_rows_block`] /
//! [`exec::for_chunks`]) onto the 8-lane row kernels in
//! [`crate::runtime::simd`] (`max_scaled`, `exp_scaled_sub_to`): rows are
//! independent, so they parallelize across the worker pool with no change
//! in per-row arithmetic order — bit-identical at any
//! `MINITENSOR_NUM_THREADS` and on every SIMD path.
//!
//! [`softmax_scaled_lastdim`] additionally folds a scalar **prologue**
//! (`x * scale`) into the row pipeline, so attention's `scores / √d`
//! costs no extra pass and no extra tensor — bitwise-equal to
//! `mul_scalar` + `softmax` because the same `v * scale` products feed
//! the same row kernel.

use super::{exec, kernels};
use crate::error::{Error, Result};
use crate::runtime::simd;
use crate::tensor::Tensor;

/// Softmax along the last axis, computed row-wise with the max-shift trick.
pub fn softmax_lastdim(t: &Tensor) -> Result<Tensor> {
    // Per row: an 8-lane max fold, a branch-free vector exp pass (no
    // serial dependency, so fast_exp pipelines — a fused exp+sum loop is
    // ~2x slower, EXPERIMENTS.md §Perf L3.3), then one normalization pass
    // over the freshly written row.
    exec::map_rows_block(
        t,
        "softmax",
        |row| simd::max_scaled(row, 1.0),
        |m, src, dst| unsafe {
            simd::exp_scaled_sub_to(src, 1.0, m, dst.as_mut_ptr() as *mut f32)
        },
        |dst| {
            let inv = 1.0 / kernels::sum(dst);
            kernels::scale(dst, inv);
        },
    )
}

/// Softmax of `t * scale` along the last axis in **one dispatch** — the
/// `mul_scalar` prologue runs inside the row kernel instead of writing a
/// scaled copy of the whole tensor first. Bitwise-equal to
/// `t.mul_scalar(scale).softmax()`: the row max folds the same
/// `v * scale` products (in the same order `kernels::max` folds the
/// materialized row) and the exp pass re-applies the identical product.
pub fn softmax_scaled_lastdim(t: &Tensor, scale: f32) -> Result<Tensor> {
    // Same vector kernels as [`softmax_lastdim`] with the scale folded in:
    // `max_scaled` / `exp_scaled_sub_to` compute the identical `v * scale`
    // products in the identical lane-fold order, which is what makes the
    // bitwise pin against the unfused pair hold under SIMD.
    exec::map_rows_block(
        t,
        "softmax",
        move |row| simd::max_scaled(row, scale),
        move |m, src, dst| unsafe {
            simd::exp_scaled_sub_to(src, scale, m, dst.as_mut_ptr() as *mut f32)
        },
        |dst| {
            let inv = 1.0 / kernels::sum(dst);
            kernels::scale(dst, inv);
        },
    )
}

/// Log-softmax along the last axis (stable: `x - m - ln Σ exp(x-m)`).
pub fn log_softmax_lastdim(t: &Tensor) -> Result<Tensor> {
    // `v + (-lse)` is IEEE-identical to `v - lse`, so the vector
    // `AddScalar` kernel reuses the elementwise path bit-for-bit.
    exec::map_rows_block(
        t,
        "log_softmax",
        kernels::logsumexp,
        |lse, src, dst| unsafe {
            simd::un_to(
                simd::UnOp::AddScalar(-lse),
                src,
                dst.as_mut_ptr() as *mut f32,
            )
        },
        |_| (),
    )
}

/// Fused forward of mean cross-entropy over logits `[b, C]` with integer
/// labels `[b]` (paper eq 8). Returns `(loss_scalar, softmax_probs)`; the
/// probs feed the well-known `softmax - onehot` pullback.
pub fn cross_entropy_forward(logits: &Tensor, labels: &Tensor) -> Result<(Tensor, Tensor)> {
    if logits.rank() != 2 || labels.rank() != 1 || logits.dims()[0] != labels.dims()[0] {
        return Err(Error::ShapeMismatch {
            op: "cross_entropy",
            expected: "logits [b, C] with labels [b]".into(),
            got: format!("{} with {}", logits.shape(), labels.shape()),
        });
    }
    let b = logits.dims()[0];
    let c = logits.dims()[1];
    let src = logits.contiguous();
    let s = src.contiguous_data().unwrap();

    // Validate labels up front so the parallel row loop is infallible.
    let lab: Vec<usize> = labels.iter().map(|y| y as usize).collect();
    if let Some(&bad) = lab.iter().find(|&&yi| yi >= c) {
        return Err(Error::IndexOutOfBounds { index: bad, size: c });
    }
    crate::runtime::stats::record_dispatch();

    // Rows are independent: probs write disjoint slices, the loss is a
    // sum of per-chunk partials combined in row order (deterministic for
    // a fixed thread count; single-threaded it is the exact serial sum).
    let mut probs = exec::take_output(b * c);
    let ptr = exec::SyncPtr::new(&mut probs);
    let loss = exec::reduce_chunks(
        b,
        4 * c.max(1),
        |r0, r1| {
            let mut part = 0.0f32;
            for i in r0..r1 {
                let row = &s[i * c..(i + 1) * c];
                let lse = kernels::logsumexp(row);
                part -= row[lab[i]] - lse;
                // SAFETY: row ranges are disjoint per chunk; the vector
                // exp kernel initializes every element of the band.
                unsafe {
                    let band = ptr.band_uninit(i * c, c);
                    simd::exp_scaled_sub_to(row, 1.0, lse, band.as_mut_ptr() as *mut f32);
                }
            }
            part
        },
        |x, y| x + y,
    )
    .unwrap_or(0.0);
    // SAFETY: every row of every chunk was written above.
    unsafe { probs.set_len(b * c) };
    // Empty batch: mean over nothing is 0, not 0/0 = NaN.
    let mean = if b == 0 { 0.0 } else { loss / b as f32 };
    Ok((Tensor::scalar(mean), Tensor::from_vec(probs, &[b, c])?))
}

impl Tensor {
    /// Softmax along the last axis.
    pub fn softmax(&self) -> Result<Tensor> {
        softmax_lastdim(self)
    }

    /// Log-softmax along the last axis.
    pub fn log_softmax(&self) -> Result<Tensor> {
        log_softmax_lastdim(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one() {
        let t = Tensor::from_vec(vec![1., 2., 3., 1., 1., 1.], &[2, 3]).unwrap();
        let p = t.softmax().unwrap();
        let sums = p.sum_axis(1, false).unwrap();
        assert!(sums.allclose(&Tensor::ones(&[2]), 1e-5, 1e-6));
        // uniform row → uniform probs
        assert!((p.at(&[1, 0]).unwrap() - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn stable_for_huge_logits() {
        let t = Tensor::from_vec(vec![1000., 1000., -1000.], &[1, 3]).unwrap();
        let p = t.softmax().unwrap();
        assert!(p.iter().all(|v| v.is_finite()));
        assert!((p.at(&[0, 0]).unwrap() - 0.5).abs() < 1e-5);
        assert!(p.at(&[0, 2]).unwrap().abs() < 1e-6);
    }

    #[test]
    fn log_softmax_is_log_of_softmax() {
        let t = Tensor::from_vec(vec![0.5, -1.2, 3.3, 0.0], &[2, 2]).unwrap();
        let ls = t.log_softmax().unwrap();
        let p = t.softmax().unwrap().log();
        assert!(ls.allclose(&p, 1e-5, 1e-6));
    }

    #[test]
    fn softmax_scaled_is_bitwise_mul_scalar_then_softmax() {
        let t = Tensor::from_vec(
            (0..48).map(|i| (i as f32) * 0.37 - 8.0).collect(),
            &[6, 8],
        )
        .unwrap();
        for &scale in &[1.0f32, 0.125, 1.0 / 8f32.sqrt(), -2.0] {
            let fused = softmax_scaled_lastdim(&t, scale).unwrap();
            let eager = t.mul_scalar(scale).softmax().unwrap();
            let (f, e) = (fused.to_vec(), eager.to_vec());
            for i in 0..f.len() {
                assert_eq!(f[i].to_bits(), e[i].to_bits(), "scale={scale} i={i}");
            }
        }
    }

    #[test]
    fn softmax_scaled_is_one_dispatch() {
        use crate::runtime::stats;
        let t = Tensor::from_vec(vec![0.5, -1.0, 2.0, 0.0, 1.0, -2.0], &[2, 3]).unwrap();
        let before = stats::snapshot();
        softmax_scaled_lastdim(&t, 0.5).unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 1);
        assert_eq!(d.output_allocs, 1);
        // The unfused pair costs two of each.
        let before = stats::snapshot();
        t.mul_scalar(0.5).softmax().unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 2);
        assert_eq!(d.output_allocs, 2);
    }

    #[test]
    fn softmax_shift_invariance() {
        let t = Tensor::from_vec(vec![1., 2., 3.], &[1, 3]).unwrap();
        let shifted = t.add_scalar(100.0);
        assert!(t
            .softmax()
            .unwrap()
            .allclose(&shifted.softmax().unwrap(), 1e-5, 1e-6));
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        // loss over uniform logits = ln(C)
        let logits = Tensor::zeros(&[4, 10]);
        let labels = Tensor::from_vec_i32(vec![0, 3, 5, 9], &[4]).unwrap();
        let (loss, probs) = cross_entropy_forward(&logits, &labels).unwrap();
        assert!((loss.item().unwrap() - 10f32.ln()).abs() < 1e-5);
        assert!((probs.at(&[0, 0]).unwrap() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_confident_correct_is_small() {
        let mut logits = vec![0.0f32; 2 * 3];
        logits[0] = 20.0; // row 0 very confident class 0
        logits[3 + 1] = 20.0; // row 1 very confident class 1
        let logits = Tensor::from_vec(logits, &[2, 3]).unwrap();
        let labels = Tensor::from_vec_i32(vec![0, 1], &[2]).unwrap();
        let (loss, _) = cross_entropy_forward(&logits, &labels).unwrap();
        assert!(loss.item().unwrap() < 1e-3);
    }

    #[test]
    fn cross_entropy_errors() {
        let logits = Tensor::zeros(&[2, 3]);
        let bad_shape = Tensor::zeros(&[3]);
        assert!(cross_entropy_forward(&logits, &bad_shape).is_err());
        let bad_label = Tensor::from_vec_i32(vec![0, 7], &[2]).unwrap();
        assert!(cross_entropy_forward(&logits, &bad_label).is_err());
    }
}
