//! Unary elementwise operations: negation, exp/log family, and the
//! nonlinearities of paper §3.3 (ReLU, Sigmoid, Tanh, GELU).
//!
//! The known op kinds dispatch through [`exec::unary_simd`] as
//! [`simd::UnOp`]s — 8-lane blocks on contiguous inputs, the scalar twin
//! on strided views, bitwise-equal either way. `exp`/`tanh`/`sigmoid`/
//! `gelu` use the polynomial kernels ([`crate::ops::kernels::fast_exp`],
//! [`simd::tanh_s`]), which are the one definition shared by every path
//! (eager, fused tape, SIMD lanes). The long tail (log, trig, recip, pow)
//! keeps the closure-generic [`Tensor::map`] path.

use crate::ops::exec;
use crate::runtime::simd::{self, UnOp};
use crate::tensor::Tensor;

/// `sqrt(2/π)` constant used by the tanh-approximated GELU (shared with
/// the vector GELU kernel in `runtime::simd`).
pub(crate) const SQRT_2_OVER_PI: f32 = 0.797_884_56;

/// Scalar GELU (tanh approximation, the one used by the major
/// frameworks), on the polynomial [`simd::tanh_s`] so the scalar twin and
/// the vector lanes agree bit-for-bit.
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    0.5 * x * (1.0 + simd::tanh_s(SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)))
}

/// Derivative of the tanh-approximated GELU.
#[inline]
pub fn gelu_grad_scalar(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + 0.044715 * x * x * x);
    let t = simd::tanh_s(u);
    let du = SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Scalar logistic sigmoid, stable for large |x| (fast_exp inside — see
/// EXPERIMENTS.md §Perf L3.3).
#[inline]
pub fn sigmoid_scalar(x: f32) -> f32 {
    use crate::ops::kernels::fast_exp;
    if x >= 0.0 {
        1.0 / (1.0 + fast_exp(-x))
    } else {
        let e = fast_exp(x);
        e / (1.0 + e)
    }
}

impl Tensor {
    /// Elementwise negation.
    pub fn neg(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Neg)
    }

    /// Elementwise exponential ([`crate::ops::kernels::fast_exp`] — the
    /// polynomial kernel every exp in the engine shares; max relative
    /// error ≈ 4e-6).
    pub fn exp(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Exp)
    }

    /// Elementwise natural log.
    pub fn log(&self) -> Tensor {
        self.map(f32::ln)
    }

    /// Elementwise square root.
    pub fn sqrt(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Sqrt)
    }

    /// Elementwise absolute value.
    pub fn abs(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Abs)
    }

    /// Elementwise sine.
    pub fn sin(&self) -> Tensor {
        self.map(f32::sin)
    }

    /// Elementwise cosine.
    pub fn cos(&self) -> Tensor {
        self.map(f32::cos)
    }

    /// Elementwise square.
    pub fn square(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Square)
    }

    /// Elementwise reciprocal.
    pub fn recip(&self) -> Tensor {
        self.map(|v| 1.0 / v)
    }

    /// Clamp values into `[lo, hi]` (exact `f32::clamp` semantics on
    /// every path, including NaN and signed-zero behavior).
    pub fn clamp(&self, lo: f32, hi: f32) -> Tensor {
        exec::unary_simd(self, UnOp::Clamp(lo, hi))
    }

    /// ReLU: `max(x, 0)` (paper §3.3).
    pub fn relu(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Relu)
    }

    /// Logistic sigmoid (stable; [`sigmoid_scalar`] per lane).
    pub fn sigmoid(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Sigmoid)
    }

    /// Hyperbolic tangent ([`simd::tanh_s`] — Cephes-style polynomial
    /// core, `1 − 2/(e^{2|x|}+1)` tail; ~2 ULP of `f32::tanh`).
    pub fn tanh(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Tanh)
    }

    /// GELU, tanh approximation (paper §3.3).
    pub fn gelu(&self) -> Tensor {
        exec::unary_simd(self, UnOp::Gelu)
    }

    /// Leaky ReLU with slope `alpha` for negative inputs.
    pub fn leaky_relu(&self, alpha: f32) -> Tensor {
        exec::unary_simd(self, UnOp::LeakyRelu(alpha))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        let n = v.len();
        Tensor::from_vec(v, &[n]).unwrap()
    }

    #[test]
    fn basic_unary() {
        assert_eq!(t(vec![1., -2.]).neg().to_vec(), vec![-1., 2.]);
        assert_eq!(t(vec![0., 1.]).exp().to_vec()[0], 1.0);
        assert_eq!(t(vec![4.]).sqrt().to_vec(), vec![2.0]);
        assert_eq!(t(vec![-3.]).abs().to_vec(), vec![3.0]);
        assert_eq!(t(vec![3.]).square().to_vec(), vec![9.0]);
        assert_eq!(t(vec![4.]).recip().to_vec(), vec![0.25]);
        assert_eq!(t(vec![-5., 0.5, 5.]).clamp(-1.0, 1.0).to_vec(), vec![-1., 0.5, 1.]);
    }

    #[test]
    fn exp_log_roundtrip() {
        let x = t(vec![0.1, 1.0, 5.0]);
        let y = x.exp().log();
        assert!(y.allclose(&x, 1e-5, 1e-6));
    }

    #[test]
    fn relu_kink() {
        assert_eq!(t(vec![-1., 0., 2.]).relu().to_vec(), vec![0., 0., 2.]);
        assert_eq!(
            t(vec![-2., 3.]).leaky_relu(0.1).to_vec(),
            vec![-0.2, 3.0]
        );
    }

    #[test]
    fn sigmoid_properties() {
        let s = t(vec![0.0]).sigmoid();
        assert!((s.to_vec()[0] - 0.5).abs() < 1e-6);
        // stability at extremes
        let big = t(vec![100.0, -100.0]).sigmoid().to_vec();
        assert!((big[0] - 1.0).abs() < 1e-6);
        assert!(big[1].abs() < 1e-6);
        assert!(big.iter().all(|v| v.is_finite()));
        // symmetry: σ(-x) = 1 - σ(x)
        let a = sigmoid_scalar(1.7);
        let b = sigmoid_scalar(-1.7);
        assert!((a + b - 1.0).abs() < 1e-6);
    }

    #[test]
    fn tanh_matches_std() {
        let x = t(vec![0.5, -1.0]);
        let y = x.tanh().to_vec();
        assert!((y[0] - 0.5f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_values() {
        // gelu(0) = 0, gelu(large) ≈ identity, gelu(-large) ≈ 0
        assert_eq!(gelu_scalar(0.0), 0.0);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu_scalar(-10.0).abs() < 1e-3);
        // known value: gelu(1) ≈ 0.8412 (tanh approx)
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.5] {
            let eps = 1e-3;
            let fd = (gelu_scalar(x + eps) - gelu_scalar(x - eps)) / (2.0 * eps);
            assert!(
                (gelu_grad_scalar(x) - fd).abs() < 1e-3,
                "x={x}: {} vs {fd}",
                gelu_grad_scalar(x)
            );
        }
    }

    #[test]
    fn trig() {
        let x = t(vec![0.0, std::f32::consts::FRAC_PI_2]);
        let s = x.sin().to_vec();
        assert!(s[0].abs() < 1e-6 && (s[1] - 1.0).abs() < 1e-6);
        let c = x.cos().to_vec();
        assert!((c[0] - 1.0).abs() < 1e-6 && c[1].abs() < 1e-6);
    }
}
