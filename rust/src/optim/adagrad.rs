//! AdaGrad (Duchi et al., 2011): per-coordinate learning rates from the
//! accumulated squared gradient — the precursor of RMSprop/Adam that
//! rounds out the paper's optimizer family.

use super::Optimizer;
use crate::autograd::{no_grad, Var};
use crate::error::Result;
use crate::tensor::Tensor;

/// AdaGrad optimizer: `G += g²; θ -= η g / (√G + ε)`.
pub struct AdaGrad {
    params: Vec<Var>,
    lr: f32,
    eps: f32,
    accum: Vec<Option<Vec<f32>>>,
}

impl AdaGrad {
    /// AdaGrad with the given learning rate.
    pub fn new(params: Vec<Var>, lr: f32) -> AdaGrad {
        let n = params.len();
        AdaGrad {
            params,
            lr,
            eps: 1e-10,
            accum: vec![None; n],
        }
    }
}

impl Optimizer for AdaGrad {
    fn step(&mut self) -> Result<()> {
        no_grad(|| {
            for (i, p) in self.params.iter().enumerate() {
                let Some(grad) = p.grad() else { continue };
                let mut theta = p.data().to_vec();
                let gt = grad.contiguous();
                let gs = gt.contiguous_data().unwrap();
                let acc = self.accum[i].get_or_insert_with(|| vec![0.0; theta.len()]);
                for ((ti, &g), ai) in theta.iter_mut().zip(gs).zip(acc.iter_mut()) {
                    *ai += g * g;
                    *ti -= self.lr * g / (ai.sqrt() + self.eps);
                }
                p.set_data(Tensor::from_vec(theta, &p.dims())?);
            }
            Ok(())
        })
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.zero_grad();
        }
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn params(&self) -> &[Var] {
        &self.params
    }
}

/// Clip gradients in place to a maximum global L2 norm; returns the norm
/// before clipping. The standard stabilizer for RNN/transformer training.
pub fn clip_grad_norm(params: &[Var], max_norm: f32) -> Result<f32> {
    let mut total_sq = 0.0f32;
    for p in params {
        if let Some(g) = p.grad() {
            total_sq += g.square().sum().item()?;
        }
    }
    let norm = total_sq.sqrt();
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for p in params {
            if let Some(g) = p.grad() {
                p.zero_grad();
                p.accumulate_grad_public(&g.mul_scalar(scale));
            }
        }
    }
    Ok(norm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adagrad_converges_on_quadratic() {
        let p = Var::from_tensor(Tensor::from_vec(vec![3.0, -2.0], &[2]).unwrap(), true);
        let mut opt = AdaGrad::new(vec![p.clone()], 0.5);
        for _ in 0..300 {
            opt.zero_grad();
            p.square().sum().unwrap().backward().unwrap();
            opt.step().unwrap();
        }
        let norm: f32 = p.data().to_vec().iter().map(|v| v * v).sum();
        assert!(norm < 1e-2, "norm={norm}");
    }

    #[test]
    fn adagrad_first_step_size() {
        // G = g² ⇒ step ≈ lr·sign(g)
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = AdaGrad::new(vec![p.clone()], 0.1);
        opt.zero_grad();
        p.square().sum().unwrap().backward().unwrap();
        opt.step().unwrap();
        assert!((1.0 - p.data().item().unwrap() - 0.1).abs() < 1e-4);
    }

    #[test]
    fn clip_grad_norm_scales_to_bound() {
        let p = Var::from_tensor(Tensor::from_vec(vec![3.0, 4.0], &[2]).unwrap(), true);
        p.mul_scalar(1.0).sum().unwrap().backward().unwrap(); // grads = 1,1
        // inject a big gradient manually
        p.zero_grad();
        p.accumulate_grad_public(&Tensor::from_vec(vec![3.0, 4.0], &[2]).unwrap());
        let before = clip_grad_norm(&[p.clone()], 1.0).unwrap();
        assert!((before - 5.0).abs() < 1e-5);
        let g = p.grad().unwrap();
        let after: f32 = g.to_vec().iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((after - 1.0).abs() < 1e-5);
        // already-small grads untouched
        let q = Var::from_tensor(Tensor::scalar(0.0), true);
        q.accumulate_grad_public(&Tensor::scalar(0.5));
        clip_grad_norm(&[q.clone()], 1.0).unwrap();
        assert_eq!(q.grad().unwrap().item().unwrap(), 0.5);
    }
}
