//! Adam and AdamW (paper eq 10, Kingma & Ba 2015):
//!
//! ```text
//! m_t = β₁ m_{t-1} + (1−β₁) g_t
//! v_t = β₂ v_{t-1} + (1−β₂) g_t²
//! θ_{t+1} = θ_t − η m̂_t / (√v̂_t + ε)
//! ```
//! with bias-corrected `m̂ = m/(1−β₁ᵗ)`, `v̂ = v/(1−β₂ᵗ)`.

use super::Optimizer;
use crate::autograd::{no_grad, Var};
use crate::error::Result;
use crate::tensor::Tensor;

/// Hyper-parameters for [`Adam`].
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// L2 coupled decay (classic Adam) — added to the gradient.
    pub weight_decay: f32,
    /// Decoupled decay (AdamW) — applied directly to θ.
    pub decoupled_weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            decoupled_weight_decay: 0.0,
        }
    }
}

/// Adam optimizer with optional (decoupled) weight decay.
pub struct Adam {
    params: Vec<Var>,
    cfg: AdamConfig,
    m: Vec<Option<Vec<f32>>>,
    v: Vec<Option<Vec<f32>>>,
    t: u32,
}

impl Adam {
    /// Adam with default betas and the given learning rate.
    pub fn new(params: Vec<Var>, lr: f32) -> Adam {
        Adam::with_config(
            params,
            AdamConfig {
                lr,
                ..AdamConfig::default()
            },
        )
    }

    /// AdamW: decoupled weight decay.
    pub fn adamw(params: Vec<Var>, lr: f32, weight_decay: f32) -> Adam {
        Adam::with_config(
            params,
            AdamConfig {
                lr,
                decoupled_weight_decay: weight_decay,
                ..AdamConfig::default()
            },
        )
    }

    /// Fully explicit configuration.
    pub fn with_config(params: Vec<Var>, cfg: AdamConfig) -> Adam {
        let n = params.len();
        Adam {
            params,
            cfg,
            m: vec![None; n],
            v: vec![None; n],
            t: 0,
        }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u32 {
        self.t
    }
}

impl Optimizer for Adam {
    fn step(&mut self) -> Result<()> {
        self.t += 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        no_grad(|| {
            for (i, p) in self.params.iter().enumerate() {
                let Some(grad) = p.grad() else { continue };
                let mut theta = p.data().to_vec();
                let gt = grad.contiguous();
                let gs = gt.contiguous_data().unwrap();
                let m = self.m[i].get_or_insert_with(|| vec![0.0; theta.len()]);
                let v = self.v[i].get_or_insert_with(|| vec![0.0; theta.len()]);

                for (((ti, &g0), mi), vi) in
                    theta.iter_mut().zip(gs).zip(m.iter_mut()).zip(v.iter_mut())
                {
                    let g = g0 + c.weight_decay * *ti;
                    *mi = c.beta1 * *mi + (1.0 - c.beta1) * g;
                    *vi = c.beta2 * *vi + (1.0 - c.beta2) * g * g;
                    let mhat = *mi / bc1;
                    let vhat = *vi / bc2;
                    *ti -= c.lr * mhat / (vhat.sqrt() + c.eps)
                        + c.lr * c.decoupled_weight_decay * *ti;
                }
                p.set_data(Tensor::from_vec(theta, &p.dims())?);
            }
            Ok(())
        })
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.zero_grad();
        }
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn params(&self) -> &[Var] {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_size_is_lr() {
        // With bias correction, the very first Adam step ≈ lr·sign(g).
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = Adam::new(vec![p.clone()], 0.1);
        opt.zero_grad();
        p.square().sum().unwrap().backward().unwrap();
        opt.step().unwrap();
        let step = 1.0 - p.data().item().unwrap();
        assert!((step - 0.1).abs() < 1e-4, "step={step}");
    }

    #[test]
    fn converges_on_quadratic() {
        let p = Var::from_tensor(
            Tensor::from_vec(vec![3.0, -2.0, 0.7], &[3]).unwrap(),
            true,
        );
        let mut opt = Adam::new(vec![p.clone()], 0.05);
        for _ in 0..400 {
            opt.zero_grad();
            p.square().sum().unwrap().backward().unwrap();
            opt.step().unwrap();
        }
        let norm: f32 = p.data().to_vec().iter().map(|v| v * v).sum();
        assert!(norm < 1e-4, "norm={norm}");
    }

    #[test]
    fn adamw_decay_without_gradient_signal() {
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = Adam::adamw(vec![p.clone()], 0.1, 0.1);
        opt.zero_grad();
        p.mul_scalar(0.0).sum().unwrap().backward().unwrap(); // zero grad values
        opt.step().unwrap();
        // pure decoupled decay: θ = 1 − lr·wd·θ = 0.99
        assert!((p.data().item().unwrap() - 0.99).abs() < 1e-5);
    }

    #[test]
    fn adaptive_scaling_equalizes_unequal_gradients() {
        // Two coords with very different gradient scales should move at
        // roughly the same rate under Adam.
        let p = Var::from_tensor(Tensor::from_vec(vec![1.0, 1.0], &[2]).unwrap(), true);
        let scale = Tensor::from_vec(vec![100.0, 0.01], &[2]).unwrap();
        let mut opt = Adam::new(vec![p.clone()], 0.01);
        for _ in 0..10 {
            opt.zero_grad();
            p.mul_mask(&scale).unwrap().sum().unwrap().backward().unwrap();
            opt.step().unwrap();
        }
        let moved = p.data().to_vec();
        let d0 = 1.0 - moved[0];
        let d1 = 1.0 - moved[1];
        assert!((d0 / d1 - 1.0).abs() < 0.2, "d0={d0} d1={d1}");
    }

    #[test]
    fn steps_counter() {
        let mut opt = Adam::new(vec![], 0.1);
        assert_eq!(opt.steps(), 0);
        opt.step().unwrap();
        opt.step().unwrap();
        assert_eq!(opt.steps(), 2);
    }
}
