//! Optimizers (paper §3.3, eqs 9–10): SGD with momentum and weight decay,
//! Adam/AdamW, RMSprop, plus learning-rate schedulers.

mod adagrad;
mod adam;
mod rmsprop;
mod scheduler;
mod sgd;

pub use adagrad::{clip_grad_norm, AdaGrad};
pub use adam::{Adam, AdamConfig};
pub use rmsprop::RmsProp;
pub use scheduler::{CosineLr, LrSchedule, StepLr};
pub use sgd::Sgd;

use crate::autograd::Var;
use crate::error::Result;

/// A first-order optimizer over a fixed parameter list.
///
/// `step()` reads each parameter's accumulated `.grad` and updates the
/// value in place (no graph recording — updates are not differentiated
/// through). `zero_grad()` drops the gradient buffers so the next backward
/// reallocates them lazily (§3.5).
pub trait Optimizer {
    /// Apply one update step using the current gradients.
    fn step(&mut self) -> Result<()>;

    /// Clear gradients of all managed parameters.
    fn zero_grad(&mut self);

    /// Current learning rate.
    fn lr(&self) -> f32;

    /// Override the learning rate (used by schedulers).
    fn set_lr(&mut self, lr: f32);

    /// Managed parameters.
    fn params(&self) -> &[Var];
}
