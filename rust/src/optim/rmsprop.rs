//! RMSprop (Tieleman & Hinton 2012, paper §3.3): exponential average of
//! squared gradients, steps scaled by `(v_t + ε)^{-1/2}`.

use super::Optimizer;
use crate::autograd::{no_grad, Var};
use crate::error::Result;
use crate::tensor::Tensor;

/// RMSprop optimizer.
pub struct RmsProp {
    params: Vec<Var>,
    lr: f32,
    alpha: f32,
    eps: f32,
    v: Vec<Option<Vec<f32>>>,
}

impl RmsProp {
    /// RMSprop with smoothing constant `alpha` (default 0.99 in most
    /// frameworks).
    pub fn new(params: Vec<Var>, lr: f32, alpha: f32) -> RmsProp {
        let n = params.len();
        RmsProp {
            params,
            lr,
            alpha,
            eps: 1e-8,
            v: vec![None; n],
        }
    }
}

impl Optimizer for RmsProp {
    fn step(&mut self) -> Result<()> {
        no_grad(|| {
            for (i, p) in self.params.iter().enumerate() {
                let Some(grad) = p.grad() else { continue };
                let mut theta = p.data().to_vec();
                let gt = grad.contiguous();
                let gs = gt.contiguous_data().unwrap();
                let v = self.v[i].get_or_insert_with(|| vec![0.0; theta.len()]);
                for ((ti, &g), vi) in theta.iter_mut().zip(gs).zip(v.iter_mut()) {
                    *vi = self.alpha * *vi + (1.0 - self.alpha) * g * g;
                    *ti -= self.lr * g / (vi.sqrt() + self.eps);
                }
                p.set_data(Tensor::from_vec(theta, &p.dims())?);
            }
            Ok(())
        })
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.zero_grad();
        }
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn params(&self) -> &[Var] {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let p = Var::from_tensor(Tensor::from_vec(vec![4.0, -4.0], &[2]).unwrap(), true);
        let mut opt = RmsProp::new(vec![p.clone()], 0.05, 0.9);
        for _ in 0..300 {
            opt.zero_grad();
            p.square().sum().unwrap().backward().unwrap();
            opt.step().unwrap();
        }
        let norm: f32 = p.data().to_vec().iter().map(|v| v * v).sum();
        assert!(norm < 1e-2, "norm={norm}");
    }

    #[test]
    fn first_step_magnitude() {
        // v₁ = (1-α) g² ⇒ step = lr·g/(√((1-α))·|g| + ε) ≈ lr/√(1-α)
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = RmsProp::new(vec![p.clone()], 0.01, 0.9);
        opt.zero_grad();
        p.square().sum().unwrap().backward().unwrap();
        opt.step().unwrap();
        let step = 1.0 - p.data().item().unwrap();
        let expect = 0.01 / (0.1f32).sqrt();
        assert!((step - expect).abs() < 1e-3, "step={step} expect={expect}");
    }

    #[test]
    fn no_grad_no_update() {
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = RmsProp::new(vec![p.clone()], 0.1, 0.9);
        opt.step().unwrap();
        assert_eq!(p.data().item().unwrap(), 1.0);
    }
}
