//! Learning-rate schedules: step decay and cosine annealing with warmup.

/// A learning-rate schedule: maps a step counter to a multiplier of the
/// base learning rate.
pub trait LrSchedule {
    /// LR at `step` given the base rate.
    fn lr_at(&self, step: usize, base_lr: f32) -> f32;
}

/// Multiply the LR by `gamma` every `every` steps.
pub struct StepLr {
    pub every: usize,
    pub gamma: f32,
}

impl LrSchedule for StepLr {
    fn lr_at(&self, step: usize, base_lr: f32) -> f32 {
        base_lr * self.gamma.powi((step / self.every) as i32)
    }
}

/// Cosine annealing from base LR to `min_lr` over `total` steps, with
/// linear warmup for the first `warmup` steps.
pub struct CosineLr {
    pub total: usize,
    pub warmup: usize,
    pub min_lr: f32,
}

impl LrSchedule for CosineLr {
    fn lr_at(&self, step: usize, base_lr: f32) -> f32 {
        if step < self.warmup {
            return base_lr * (step + 1) as f32 / self.warmup as f32;
        }
        let t = (step - self.warmup) as f32 / (self.total - self.warmup).max(1) as f32;
        let t = t.min(1.0);
        self.min_lr
            + 0.5 * (base_lr - self.min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay() {
        let s = StepLr {
            every: 10,
            gamma: 0.1,
        };
        assert_eq!(s.lr_at(0, 1.0), 1.0);
        assert_eq!(s.lr_at(9, 1.0), 1.0);
        assert!((s.lr_at(10, 1.0) - 0.1).abs() < 1e-7);
        assert!((s.lr_at(25, 1.0) - 0.01).abs() < 1e-7);
    }

    #[test]
    fn cosine_warmup_then_decay() {
        let s = CosineLr {
            total: 100,
            warmup: 10,
            min_lr: 0.0,
        };
        // warmup ramps up linearly
        assert!(s.lr_at(0, 1.0) < s.lr_at(5, 1.0));
        assert!((s.lr_at(9, 1.0) - 1.0).abs() < 1e-6);
        // midpoint of cosine ≈ half
        let mid = s.lr_at(55, 1.0);
        assert!((mid - 0.5).abs() < 0.02, "mid={mid}");
        // end hits min
        assert!(s.lr_at(100, 1.0) < 1e-6);
        // past the end stays at min
        assert!(s.lr_at(500, 1.0) < 1e-6);
    }

    #[test]
    fn cosine_is_monotone_after_warmup() {
        let s = CosineLr {
            total: 50,
            warmup: 5,
            min_lr: 0.01,
        };
        let mut last = f32::INFINITY;
        for step in 5..50 {
            let lr = s.lr_at(step, 1.0);
            assert!(lr <= last + 1e-6);
            last = lr;
        }
        assert!(last >= 0.01 - 1e-6);
    }
}
