//! Stochastic gradient descent with momentum and weight decay (paper
//! eq 9):
//!
//! ```text
//! v_t = μ v_{t-1} + ∇θ L_t + λ θ_t
//! θ_{t+1} = θ_t − η v_t
//! ```

use super::Optimizer;
use crate::autograd::{no_grad, Var};
use crate::error::Result;
use crate::ops::kernels;
use crate::tensor::Tensor;

/// SGD optimizer (eq 9). With `momentum = 0` and `weight_decay = 0` it is
/// plain gradient descent.
pub struct Sgd {
    params: Vec<Var>,
    lr: f32,
    momentum: f32,
    weight_decay: f32,
    velocity: Vec<Option<Vec<f32>>>,
}

impl Sgd {
    /// Plain SGD.
    pub fn new(params: Vec<Var>, lr: f32) -> Sgd {
        Sgd::with_momentum(params, lr, 0.0, 0.0)
    }

    /// SGD with momentum μ and L2 weight decay λ.
    pub fn with_momentum(params: Vec<Var>, lr: f32, momentum: f32, weight_decay: f32) -> Sgd {
        let n = params.len();
        Sgd {
            params,
            lr,
            momentum,
            weight_decay,
            velocity: vec![None; n],
        }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self) -> Result<()> {
        no_grad(|| {
            for (i, p) in self.params.iter().enumerate() {
                let Some(grad) = p.grad() else { continue };
                let theta = p.data().contiguous();
                let mut buf = theta.to_vec();
                let g = grad.contiguous();
                let gs = g.contiguous_data().unwrap();

                if self.momentum == 0.0 && self.weight_decay == 0.0 {
                    // Fused fast path: θ -= η g.
                    kernels::axpy(-self.lr, gs, &mut buf);
                } else {
                    let v = self.velocity[i].get_or_insert_with(|| vec![0.0; buf.len()]);
                    for ((vi, &gi), ti) in v.iter_mut().zip(gs).zip(buf.iter_mut()) {
                        // v = μ v + g + λ θ ; θ -= η v   (eq 9)
                        *vi = self.momentum * *vi + gi + self.weight_decay * *ti;
                        *ti -= self.lr * *vi;
                    }
                }
                p.set_data(Tensor::from_vec(buf, &p.dims())?);
            }
            Ok(())
        })
    }

    fn zero_grad(&mut self) {
        for p in &self.params {
            p.zero_grad();
        }
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn params(&self) -> &[Var] {
        &self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_step(opt: &mut impl Optimizer, p: &Var) -> f32 {
        // L = ||θ||²; ∇ = 2θ
        opt.zero_grad();
        let loss = p.square().sum().unwrap();
        loss.backward().unwrap();
        let l = loss.item().unwrap();
        opt.step().unwrap();
        l
    }

    #[test]
    fn plain_sgd_descends_quadratic() {
        let p = Var::from_tensor(Tensor::from_vec(vec![5.0, -3.0], &[2]).unwrap(), true);
        let mut opt = Sgd::new(vec![p.clone()], 0.1);
        let mut last = f32::INFINITY;
        for _ in 0..50 {
            let l = quadratic_step(&mut opt, &p);
            assert!(l <= last + 1e-6);
            last = l;
        }
        assert!(last < 1e-3, "final loss {last}");
    }

    #[test]
    fn single_step_is_exact() {
        // θ = 1, L = θ² ⇒ g = 2 ⇒ θ' = 1 − 0.1·2 = 0.8
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = Sgd::new(vec![p.clone()], 0.1);
        quadratic_step(&mut opt, &p);
        assert!((p.data().item().unwrap() - 0.8).abs() < 1e-6);
    }

    #[test]
    fn momentum_accelerates_along_consistent_gradient() {
        // Two steps on a linear slope: velocity accumulates.
        let p = Var::from_tensor(Tensor::scalar(0.0), true);
        let mut opt = Sgd::with_momentum(vec![p.clone()], 0.1, 0.9, 0.0);
        // L = θ (grad = 1 everywhere): after 1 step θ=-0.1; after 2 steps
        // v = 0.9*1+1 = 1.9, θ = -0.1 - 0.19 = -0.29
        for _ in 0..2 {
            opt.zero_grad();
            // manual gradient injection: sum() of p gives dL/dθ = 1
            let loss = p.sum().unwrap();
            loss.backward().unwrap();
            opt.step().unwrap();
        }
        assert!((p.data().item().unwrap() + 0.29).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_parameters() {
        let p = Var::from_tensor(Tensor::scalar(1.0), true);
        let mut opt = Sgd::with_momentum(vec![p.clone()], 0.1, 0.0, 0.5);
        // grad of L=0 is absent, so inject via a loss of p*0 — no grad at
        // all means no update; use L = 0.0*p + small loss instead:
        opt.zero_grad();
        let loss = p.mul_scalar(0.0).sum().unwrap();
        loss.backward().unwrap();
        opt.step().unwrap();
        // v = 0 + 0 + 0.5*1 = 0.5 ⇒ θ = 1 − 0.05
        assert!((p.data().item().unwrap() - 0.95).abs() < 1e-6);
    }

    #[test]
    fn skips_params_without_grad() {
        let p = Var::from_tensor(Tensor::scalar(2.0), true);
        let mut opt = Sgd::new(vec![p.clone()], 0.1);
        opt.step().unwrap(); // no backward has run
        assert_eq!(p.data().item().unwrap(), 2.0);
    }

    #[test]
    fn lr_getter_setter() {
        let mut opt = Sgd::new(vec![], 0.1);
        assert_eq!(opt.lr(), 0.1);
        opt.set_lr(0.01);
        assert_eq!(opt.lr(), 0.01);
    }
}
