//! Artifact manifest: what `python/compile/aot.py` wrote and how to call
//! each entry point.
//!
//! The manifest is a deliberately simple line-oriented format (no JSON
//! dependency in the vendor set):
//!
//! ```text
//! # name | hlo file | input shapes ; output shapes
//! matmul | matmul.hlo.txt | 128x128,128x128 ; 128x128
//! mlp_forward | mlp_forward.hlo.txt | 32x64,256x64,256,10x256,10 ; 32x10
//! ```

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One AOT-compiled entry point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    /// Logical name (e.g. "mlp_train_step").
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: PathBuf,
    /// Expected input shapes, in call order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Produced output shapes, in tuple order.
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed artifacts manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').map(str::trim).collect();
            if parts.len() != 3 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected 3 '|'-separated fields, got {}",
                    lineno + 1,
                    parts.len()
                )));
            }
            let (ins, outs) = parts[2].split_once(';').ok_or_else(|| {
                Error::Artifact(format!(
                    "manifest line {}: missing ';' between input and output shapes",
                    lineno + 1
                ))
            })?;
            artifacts.push(Artifact {
                name: parts[0].to_string(),
                file: PathBuf::from(parts[1]),
                input_shapes: parse_shapes(ins)?,
                output_shapes: parse_shapes(outs)?,
            });
        }
        Ok(Manifest { artifacts, dir })
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no artifact named '{name}' (available: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|shape| {
            let shape = shape.trim();
            if shape == "scalar" {
                return Ok(Vec::new());
            }
            shape
                .split('x')
                .map(|d| {
                    d.trim()
                        .parse::<usize>()
                        .map_err(|e| Error::Artifact(format!("bad dim '{d}': {e}")))
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let text = "\
# comment
matmul | matmul.hlo.txt | 128x128,128x128 ; 128x128
loss | loss.hlo.txt | 32x10,32 ; scalar
";
        let m = Manifest::parse(text, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let mm = m.get("matmul").unwrap();
        assert_eq!(mm.input_shapes, vec![vec![128, 128], vec![128, 128]]);
        assert_eq!(mm.output_shapes, vec![vec![128, 128]]);
        let loss = m.get("loss").unwrap();
        assert_eq!(loss.output_shapes, vec![Vec::<usize>::new()]);
        assert_eq!(m.path_of(mm), PathBuf::from("/tmp/matmul.hlo.txt"));
    }

    #[test]
    fn missing_name_errors() {
        let m = Manifest::parse("", PathBuf::new()).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Manifest::parse("just one field", PathBuf::new()).is_err());
        assert!(Manifest::parse("a | b | no-semicolon", PathBuf::new()).is_err());
        assert!(Manifest::parse("a | b | 2xbad ; 1", PathBuf::new()).is_err());
    }
}
