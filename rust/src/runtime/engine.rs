//! PJRT execution engine: one process-wide CPU client, compiled
//! executables cached per artifact, `Tensor` ⇄ `Literal` conversion.

use std::collections::HashMap;

use super::artifact::{Artifact, Manifest};
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// A compiled, ready-to-run AOT model.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    artifact: Artifact,
}

impl LoadedModel {
    /// Execute with `Tensor` inputs, returning all tuple outputs as
    /// `Tensor`s (the exporter lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.artifact.input_shapes.len() {
            return Err(Error::Xla(format!(
                "artifact '{}' expects {} inputs, got {}",
                self.artifact.name,
                self.artifact.input_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (t, expect)) in inputs.iter().zip(&self.artifact.input_shapes).enumerate() {
            if t.dims() != expect.as_slice() {
                return Err(Error::Xla(format!(
                    "artifact '{}' input {i}: expected shape {expect:?}, got {:?}",
                    self.artifact.name,
                    t.dims()
                )));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .zip(&self.artifact.output_shapes)
            .map(|(lit, dims)| literal_to_tensor(&lit, dims))
            .collect()
    }

    /// The artifact this executable came from.
    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }
}

/// Process-wide PJRT engine: owns the client and an executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, LoadedModel>,
}

impl Engine {
    /// Build a CPU engine over an artifacts directory (expects
    /// `manifest.txt` inside, produced by `make artifacts`).
    pub fn cpu(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// PJRT platform string (e.g. "cpu"/"Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.cache.contains_key(name) {
            let artifact = self.manifest.get(name)?.clone();
            let path = self.manifest.path_of(&artifact);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache
                .insert(name.to_string(), LoadedModel { exe, artifact });
        }
        Ok(&self.cache[name])
    }

    /// One-shot convenience: load (cached) and run.
    pub fn run(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        self.cache[name].run(inputs)
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect()
    }
}

/// Convert a `Tensor` to an f32 `Literal` of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let flat = t.to_vec();
    let lit = xla::Literal::vec1(&flat);
    if t.rank() == 0 {
        // jax scalars lower as rank-0; reshape accordingly.
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// Convert a `Literal` back to a `Tensor` with the given shape.
pub fn literal_to_tensor(lit: &xla::Literal, dims: &[usize]) -> Result<Tensor> {
    let v: Vec<f32> = lit.to_vec()?;
    Tensor::from_vec(v, dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(lit.element_count(), 6);
        let back = literal_to_tensor(&lit, &[2, 3]).unwrap();
        assert_eq!(back.to_vec(), t.to_vec());
    }

    #[test]
    fn scalar_literal() {
        let t = Tensor::scalar(7.5);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(lit.element_count(), 1);
        let back = literal_to_tensor(&lit, &[]).unwrap();
        assert_eq!(back.item().unwrap(), 7.5);
    }

    // Engine tests that require actual artifacts live in
    // rust/tests/runtime_xla.rs (they need `make artifacts` to have run).
}
