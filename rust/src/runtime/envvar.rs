//! Warn-once parsing for `MINITENSOR_*` environment variables.
//!
//! The engine's knobs (`MINITENSOR_NUM_THREADS`, `MINITENSOR_TRACE_CAPACITY`,
//! `MINITENSOR_PROGRAM_CACHE`, `MINITENSOR_FAULTS`, …) resolve lazily on
//! first use; a typo'd
//! value used to fall back to the default *silently*, which reads exactly
//! like the override worked. [`parse`] keeps the fall-back behavior but
//! says so once per variable per process on stderr.
//!
//! The parsing itself is the pure function [`parse_checked`] over an
//! already-read raw value, so every call site can unit-test its own
//! accepted/rejected forms without mutating the process environment
//! (tests run multi-threaded; `std::env::set_var` there is a race).

use std::collections::BTreeSet;
use std::str::FromStr;
use std::sync::Mutex;

/// Variables already warned about (process-global: several modules read
/// their variable from per-thread lazy init, and the warning must not
/// repeat per thread).
static WARNED: Mutex<BTreeSet<&'static str>> = Mutex::new(BTreeSet::new());

/// Pure parse step: `Ok(None)` = unset, `Ok(Some(v))` = parsed and
/// accepted by `valid`, `Err(msg)` = set but unusable (the caller falls
/// back to its default). `expected` describes the accepted form for the
/// message.
pub(crate) fn parse_checked<T: FromStr>(
    name: &str,
    raw: Option<&str>,
    valid: impl Fn(&T) -> bool,
    expected: &str,
) -> Result<Option<T>, String> {
    let Some(raw) = raw else { return Ok(None) };
    match raw.trim().parse::<T>() {
        Ok(v) if valid(&v) => Ok(Some(v)),
        _ => Err(format!(
            "minitensor: warning: ignoring invalid {name}={raw:?} (expected {expected}); \
             using the default"
        )),
    }
}

/// Read-and-validate `name` from an already-fetched raw value, warning
/// once per process on stderr when the value is set but invalid. Returns
/// `None` both for "unset" and "invalid" — the caller applies its
/// default either way.
pub(crate) fn parse<T: FromStr>(
    name: &'static str,
    raw: Option<&str>,
    valid: impl Fn(&T) -> bool,
    expected: &str,
) -> Option<T> {
    match parse_checked(name, raw, valid, expected) {
        Ok(v) => v,
        Err(msg) => {
            let mut warned = WARNED.lock().unwrap_or_else(|e| e.into_inner());
            if warned.insert(name) {
                eprintln!("{msg}");
            }
            None
        }
    }
}

/// Convenience: [`parse`] over the live environment.
pub(crate) fn parse_env<T: FromStr>(
    name: &'static str,
    valid: impl Fn(&T) -> bool,
    expected: &str,
) -> Option<T> {
    let raw = std::env::var(name).ok();
    parse(name, raw.as_deref(), valid, expected)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_is_ok_none() {
        assert_eq!(
            parse_checked::<usize>("X", None, |_| true, "an integer"),
            Ok(None)
        );
    }

    #[test]
    fn valid_value_parses() {
        assert_eq!(
            parse_checked::<usize>("X", Some(" 42 "), |_| true, "an integer"),
            Ok(Some(42))
        );
    }

    #[test]
    fn invalid_value_errors_with_context() {
        let err = parse_checked::<usize>("MINITENSOR_X", Some("banana"), |_| true, "an integer")
            .unwrap_err();
        assert!(err.contains("MINITENSOR_X"), "{err}");
        assert!(err.contains("banana"), "{err}");
        assert!(err.contains("an integer"), "{err}");
    }

    #[test]
    fn rejected_by_validator_errors() {
        let r = parse_checked::<usize>("X", Some("0"), |&v| v > 0, "a positive integer");
        assert!(r.is_err());
    }

    #[test]
    fn parse_falls_back_to_none_and_only_warns_once() {
        // Both calls take the warn path; the second must be deduplicated.
        for _ in 0..2 {
            let v: Option<usize> =
                parse("MINITENSOR_TEST_ONLY_VAR", Some("nope"), |_| true, "an integer");
            assert_eq!(v, None);
        }
        assert!(WARNED
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .contains("MINITENSOR_TEST_ONLY_VAR"));
    }
}
