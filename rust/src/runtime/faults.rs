//! Failpoint-style fault injection: induce the failures the engine must
//! contain, in-tree and in CI.
//!
//! The serve stack's robustness story (panic isolation, supervised
//! restart, the stuck-worker watchdog) is only trustworthy if every
//! failure mode it claims to handle can be *induced* on demand. This
//! module plants named **sites** on the paths that can fail in
//! production and lets tests, the `minitensor chaos` command, or an
//! operator arm them:
//!
//! | site                   | where it fires                                  |
//! |------------------------|-------------------------------------------------|
//! | `serve.worker.forward` | before each `forward_batch` in a serve worker   |
//! | `parallel.chunk`       | at the top of each worker-pool chunk body       |
//! | `pool.alloc`           | in the buffer pool's `try_take` (forced miss)   |
//! | `graph.compile`        | on the program-cache miss path, before compile  |
//!
//! Arming: the `MINITENSOR_FAULTS` environment variable or the
//! [`arm`]/[`disarm`] API. The env grammar is a comma-separated list of
//! `site:kind:prob[:count]`, e.g.
//!
//! ```text
//! MINITENSOR_FAULTS=serve.worker.forward:panic:0.2,pool.alloc:error:0.05:100
//! ```
//!
//! Kinds: `panic` (the site panics), `error` (the site returns
//! [`Error::FaultInjected`], or degrades gracefully where there is no
//! error channel — e.g. a forced pool miss), and `delay_ms=<ms>` (the
//! site sleeps; this is what exercises the serve watchdog). `prob` is
//! the per-visit injection probability in `[0, 1]`; the optional
//! `count` caps the total number of injections for the site.
//!
//! **Disabled cost:** the same discipline as `trace.rs` — one relaxed
//! atomic load per site visit ([`armed`]), no lock, no branch on site
//! names. `benches/faults_overhead.rs` is the regression guard. The
//! armed path takes a process-wide mutex and draws from a deterministic
//! per-site xorshift64* stream (seeded from the site name, so a given
//! arm specification injects at the same visit numbers every run — no
//! `rand` dependency, no flaky CI).
//!
//! Every injection increments `minitensor_faults_injected_total` in the
//! process metrics registry, so a chaos run's blast radius is visible on
//! `/metrics` and `/healthz` next to the recovery counters it causes.

use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, Once};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::{envvar, metrics};

const STATE_UNINIT: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

/// Fast-path switch: OFF means no site is armed and [`check`] returns
/// immediately after one relaxed load.
static ARMED: AtomicU8 = AtomicU8::new(STATE_UNINIT);

/// Armed sites. Locked only on the armed path and by the management API.
static SITES: Mutex<Vec<Site>> = Mutex::new(Vec::new());

/// What an armed site does when the probability draw fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The site panics (`catch_unwind` containment is the thing under test).
    Panic,
    /// The site fails with [`Error::FaultInjected`]; sites with no error
    /// channel degrade instead (forced pool miss) or escalate to a panic
    /// (`parallel.chunk`, where a panic payload *is* the error channel).
    Error,
    /// The site sleeps for the given number of milliseconds (exercises
    /// deadlines and the stuck-worker watchdog).
    DelayMs(u64),
}

struct Site {
    name: String,
    kind: FaultKind,
    prob: f64,
    /// Remaining injections; `None` = unlimited.
    remaining: Option<u64>,
    /// Total injections fired at this site since it was armed.
    injected: u64,
    /// Deterministic xorshift64* state, seeded from the site name.
    rng: u64,
}

/// One parsed `site:kind:prob[:count]` clause.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Spec {
    pub site: String,
    pub kind: FaultKind,
    pub prob: f64,
    pub count: Option<u64>,
}

/// The full `MINITENSOR_FAULTS` value: a comma-separated clause list.
/// `FromStr` so it routes through `envvar::parse` warn-once validation.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct SpecList(pub Vec<Spec>);

impl FromStr for SpecList {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Self, String> {
        let mut specs = Vec::new();
        for clause in s.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let parts: Vec<&str> = clause.split(':').collect();
            if parts.len() < 3 || parts.len() > 4 {
                return Err(format!("clause {clause:?}: want site:kind:prob[:count]"));
            }
            let site = parts[0].trim();
            if site.is_empty() {
                return Err(format!("clause {clause:?}: empty site name"));
            }
            let kind = match parts[1].trim() {
                "panic" => FaultKind::Panic,
                "error" => FaultKind::Error,
                k if k.starts_with("delay_ms=") => {
                    let ms = k["delay_ms=".len()..]
                        .parse::<u64>()
                        .map_err(|_| format!("clause {clause:?}: bad delay_ms value"))?;
                    FaultKind::DelayMs(ms)
                }
                k => return Err(format!("clause {clause:?}: unknown kind {k:?}")),
            };
            let prob = parts[2]
                .trim()
                .parse::<f64>()
                .ok()
                .filter(|p| (0.0..=1.0).contains(p))
                .ok_or_else(|| format!("clause {clause:?}: prob must be in [0, 1]"))?;
            let count = match parts.get(3) {
                None => None,
                Some(c) => Some(
                    c.trim()
                        .parse::<u64>()
                        .ok()
                        .filter(|&n| n > 0)
                        .ok_or_else(|| format!("clause {clause:?}: count must be a positive integer"))?,
                ),
            };
            specs.push(Spec {
                site: site.to_string(),
                kind,
                prob,
                count,
            });
        }
        if specs.is_empty() {
            return Err("no clauses".to_string());
        }
        Ok(SpecList(specs))
    }
}

/// Is any site armed? One relaxed atomic load in the steady state —
/// this is the entire cost an unarmed failpoint adds to a hot path.
#[inline]
pub fn armed() -> bool {
    let s = ARMED.load(Ordering::Relaxed);
    if s == STATE_UNINIT {
        return resolve();
    }
    s == STATE_ON
}

/// First-call resolution: parse `MINITENSOR_FAULTS` and settle ON/OFF.
#[cold]
fn resolve() -> bool {
    ensure_env();
    let on = !sites().is_empty();
    let target = if on { STATE_ON } else { STATE_OFF };
    let _ = ARMED.compare_exchange(STATE_UNINIT, target, Ordering::Relaxed, Ordering::Relaxed);
    ARMED.load(Ordering::Relaxed) == STATE_ON
}

fn sites() -> std::sync::MutexGuard<'static, Vec<Site>> {
    SITES.lock().unwrap_or_else(|e| e.into_inner())
}

/// Parse `MINITENSOR_FAULTS` exactly once per process (warn-once on a
/// malformed value, like every other `MINITENSOR_*` knob).
fn ensure_env() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        if let Some(list) = envvar::parse_env::<SpecList>(
            "MINITENSOR_FAULTS",
            |_| true,
            "site:kind:prob[:count][,...] with kind panic|error|delay_ms=<ms>",
        ) {
            let mut guard = sites();
            for spec in list.0 {
                upsert(&mut guard, spec);
            }
        }
    });
}

fn upsert(guard: &mut Vec<Site>, spec: Spec) {
    let seed = fnv1a(spec.site.as_bytes()) | 1;
    match guard.iter_mut().find(|s| s.name == spec.site) {
        Some(s) => {
            s.kind = spec.kind;
            s.prob = spec.prob;
            s.remaining = spec.count;
            s.injected = 0;
            s.rng = seed;
        }
        None => guard.push(Site {
            name: spec.site,
            kind: spec.kind,
            prob: spec.prob,
            remaining: spec.count,
            injected: 0,
            rng: seed,
        }),
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn xorshift_star(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    *state = x;
    x.wrapping_mul(0x2545F4914F6CDD1D)
}

/// Arm `site` with the given kind, per-visit probability (clamped to
/// `[0, 1]`), and optional total-injection cap. Re-arming an already
/// armed site replaces its spec and resets its injection counter and
/// RNG stream, so `prob: 1.0, count: Some(k)` means "exactly the next
/// `k` visits inject" — the deterministic shape tests want.
pub fn arm(site: impl Into<String>, kind: FaultKind, prob: f64, count: Option<u64>) {
    ensure_env();
    let spec = Spec {
        site: site.into(),
        kind,
        prob: prob.clamp(0.0, 1.0),
        count,
    };
    upsert(&mut sites(), spec);
    ARMED.store(STATE_ON, Ordering::Relaxed);
}

/// Disarm one site. Returns whether it was armed. When the last site is
/// disarmed the fast path drops back to the single-load OFF state.
pub fn disarm(site: &str) -> bool {
    ensure_env();
    let mut guard = sites();
    let before = guard.len();
    guard.retain(|s| s.name != site);
    let removed = guard.len() != before;
    if guard.is_empty() {
        ARMED.store(STATE_OFF, Ordering::Relaxed);
    }
    drop(guard);
    removed
}

/// Disarm every site (including any armed from the environment).
pub fn disarm_all() {
    ensure_env();
    sites().clear();
    ARMED.store(STATE_OFF, Ordering::Relaxed);
}

/// Total injections fired at `site` since it was (re-)armed.
pub fn injected(site: &str) -> u64 {
    if ARMED.load(Ordering::Relaxed) == STATE_UNINIT {
        ensure_env();
    }
    sites()
        .iter()
        .find(|s| s.name == site)
        .map(|s| s.injected)
        .unwrap_or(0)
}

/// `(site, injections)` for every armed site — the chaos report.
pub fn status() -> Vec<(String, u64)> {
    if ARMED.load(Ordering::Relaxed) == STATE_UNINIT {
        ensure_env();
    }
    sites().iter().map(|s| (s.name.clone(), s.injected)).collect()
}

/// Visit a site: `None` = proceed normally (always, when unarmed);
/// `Some(kind)` = the caller must now inject that fault. Most sites use
/// [`fire`]/[`fire_infallible`] instead; [`check`] is for sites that
/// map `Error` onto a domain-specific degradation (the pool's forced
/// miss).
#[inline]
pub fn check(site: &str) -> Option<FaultKind> {
    if !armed() {
        return None;
    }
    check_armed(site)
}

#[cold]
fn check_armed(site: &str) -> Option<FaultKind> {
    let kind = {
        let mut guard = sites();
        let s = guard.iter_mut().find(|s| s.name == site)?;
        if s.remaining == Some(0) {
            return None;
        }
        // 53-bit uniform draw in [0, 1); prob 1.0 therefore always fires.
        let draw = (xorshift_star(&mut s.rng) >> 11) as f64 / (1u64 << 53) as f64;
        if draw >= s.prob {
            return None;
        }
        if let Some(n) = &mut s.remaining {
            *n -= 1;
        }
        s.injected += 1;
        s.kind
    };
    metrics::counter_add("minitensor_faults_injected_total", 1);
    Some(kind)
}

/// Visit a site on a fallible path: injects `panic` by panicking,
/// `error` as `Err(Error::FaultInjected)`, `delay_ms` by sleeping.
pub fn fire(site: &'static str) -> Result<()> {
    match check(site) {
        None => Ok(()),
        Some(FaultKind::DelayMs(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::Error) => Err(Error::FaultInjected { site }),
        Some(FaultKind::Panic) => panic!("minitensor: injected fault at {site}"),
    }
}

/// Visit a site on an infallible path (no `Result` channel): `error`
/// escalates to a panic — on `parallel.chunk` the structured panic
/// payload *is* how failures reach the submitting thread.
pub fn fire_infallible(site: &str) {
    match check(site) {
        None => {}
        Some(FaultKind::DelayMs(ms)) => std::thread::sleep(Duration::from_millis(ms)),
        Some(_) => panic!("minitensor: injected fault at {site}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_accepts_the_documented_forms() {
        let l: SpecList = "serve.worker.forward:panic:0.2".parse().unwrap();
        assert_eq!(l.0.len(), 1);
        assert_eq!(l.0[0].kind, FaultKind::Panic);
        assert_eq!(l.0[0].prob, 0.2);
        assert_eq!(l.0[0].count, None);

        let l: SpecList = "pool.alloc:error:1.0:5, parallel.chunk:delay_ms=3:0.5"
            .parse()
            .unwrap();
        assert_eq!(l.0.len(), 2);
        assert_eq!(l.0[0].count, Some(5));
        assert_eq!(l.0[1].kind, FaultKind::DelayMs(3));
    }

    #[test]
    fn grammar_rejects_malformed_clauses() {
        for bad in [
            "",
            "siteonly",
            "site:panic",
            "site:panic:2.0",
            "site:panic:-0.1",
            "site:explode:0.5",
            "site:delay_ms=abc:0.5",
            "site:panic:0.5:0",
            "site:panic:0.5:1:extra",
            ":panic:0.5",
        ] {
            assert!(bad.parse::<SpecList>().is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn count_caps_injections_exactly() {
        let site = "test.faults.count_cap";
        arm(site, FaultKind::Error, 1.0, Some(2));
        assert_eq!(check(site), Some(FaultKind::Error));
        assert_eq!(check(site), Some(FaultKind::Error));
        assert_eq!(check(site), None);
        assert_eq!(injected(site), 2);
        assert!(disarm(site));
    }

    #[test]
    fn prob_zero_never_fires_and_prob_draws_are_deterministic() {
        let site = "test.faults.prob";
        arm(site, FaultKind::Error, 0.0, None);
        for _ in 0..100 {
            assert_eq!(check(site), None);
        }
        assert_eq!(injected(site), 0);

        // Same site name → same seed → the same visit numbers inject.
        let run = |n: usize| -> Vec<bool> {
            arm(site, FaultKind::Error, 0.3, None);
            (0..n).map(|_| check(site).is_some()).collect()
        };
        let a = run(64);
        let b = run(64);
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x), "prob 0.3 over 64 draws should fire");
        assert!(!a.iter().all(|&x| x), "prob 0.3 should not always fire");
        assert!(disarm(site));
    }

    #[test]
    fn fire_maps_kinds_onto_the_result_channel() {
        let site = "test.faults.fire";
        arm(site, FaultKind::Error, 1.0, Some(1));
        let err = fire(site).unwrap_err();
        assert!(matches!(err, Error::FaultInjected { site: s } if s == site));
        assert!(fire(site).is_ok(), "count exhausted");

        arm(site, FaultKind::Panic, 1.0, Some(1));
        let p = std::panic::catch_unwind(|| fire(site));
        let msg = p.expect_err("must panic");
        let msg = msg
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("injected fault"), "{msg}");
        assert!(msg.contains(site), "{msg}");

        arm(site, FaultKind::DelayMs(1), 1.0, Some(1));
        let t0 = std::time::Instant::now();
        fire(site).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(1));
        assert!(disarm(site));
    }

    #[test]
    fn injections_mirror_into_the_metrics_registry() {
        let grab = || {
            metrics::snapshot()
                .counters
                .iter()
                .find(|(k, _)| k == "minitensor_faults_injected_total")
                .map(|&(_, v)| v)
                .unwrap_or(0)
        };
        let before = grab();
        let site = "test.faults.registry";
        arm(site, FaultKind::Error, 1.0, Some(3));
        for _ in 0..5 {
            let _ = check(site);
        }
        assert!(grab() >= before + 3);
        assert!(disarm(site));
    }

    #[test]
    fn disarm_unknown_site_is_false() {
        assert!(!disarm("test.faults.never_armed"));
    }
}
