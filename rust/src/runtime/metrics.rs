//! Process-wide metrics registry: counters, gauges, and histograms with
//! lock-free per-thread shards, exported as a typed snapshot, JSON, and
//! Prometheus text exposition (hand-rolled HTTP, zero dependencies).
//!
//! This is the third observability pillar next to [`stats`](super::stats)
//! (exact per-thread counters for tests/benches) and
//! [`trace`](super::trace) (on-demand timelines): an **always-on
//! aggregate view** a fleet can scrape continuously. One registry, one
//! naming scheme — `minitensor_<subsystem>_<what>[_total]`:
//!
//! | family | series |
//! |---|---|
//! | exec | `minitensor_exec_dispatches_total`, `_output_allocs_total`, `_fused_kernels_total`, `_fused_ops_total`, `_fused_elems_total`, `_simd_blocks_total` |
//! | program cache | `minitensor_program_cache_hits_total`, `_misses_total`, `minitensor_graph_fusion_bailouts_total` |
//! | pool | `minitensor_pool_hits_total`, `_misses_total`, `_returns_total`, `_bytes_pooled`, `_bytes_live`, `_bytes_highwater` |
//! | parallel | `minitensor_parallel_chunks_total`, `_tasks_total`, `_pool_workers` |
//! | serve | every `coordinator::Metrics` counter/series, mirrored as `minitensor_serve_*` (latency/queue series export as summaries) |
//! | robustness | `minitensor_faults_injected_total` (the `faults` failpoint layer), `minitensor_serve_worker_crashes_total`, `_worker_restarts_total`, `_worker_timeouts_total`, `_replies_dropped_total` |
//!
//! **Hot-path cost.** The engine-side counters above are *sharded*: each
//! thread owns a fixed slot array it alone writes (registered once, like
//! the trace rings), so an increment is one branch on the
//! enable flag plus one relaxed load+store of the calling thread's own
//! cache line — no RMW contention, no lock. `snapshot()` merges the
//! shards. Counters only grow (shards outlive their threads), so scraped
//! totals are monotonic. Gauges shard as wrapping signed deltas: a buffer
//! allocated on thread A may drop on thread B, leaving A's shard
//! permanently high and B's "negative" — the cross-shard sum is still
//! exact. Dynamically named serve/train metrics go through a mutex map
//! instead; they are recorded per *batch*, not per element, so the lock
//! is off the kernel hot path.
//!
//! **Switch.** `MINITENSOR_METRICS=off` (or [`set_enabled`]) turns every
//! record path into the flag check alone — that is the "registry-disabled
//! build" the `metrics_overhead` bench compares against. Note that
//! [`stats`](super::stats) reads its per-thread view from these shards,
//! so disabling the registry freezes those counters too (the fusion
//! tests run with the default, on).
//!
//! **Export.** [`snapshot`] → [`MetricsSnapshot`] (typed, plus
//! [`MetricsSnapshot::to_json`]), [`prometheus_text`] → text exposition
//! format 0.0.4, and [`serve_http`] → a tiny blocking
//! `std::net::TcpListener` responder serving `GET /metrics` (Prometheus),
//! `GET /metrics.json`, and `GET /healthz` (process health:
//! `live`/`degraded` → 200, `draining` → 503, JSON body with the
//! restart/fault counters — see [`health_set`]/[`healthz_json`]). The
//! serve stack starts one when `ServeConfig::metrics_port` is set;
//! `minitensor metrics` does a one-shot dump.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Histogram (promoted from coordinator::metrics in PR 9 — the serve stack
// re-exports it, so `coordinator::Metrics` and the registry share one type).
// ---------------------------------------------------------------------------

/// Bucket count of a [`Histogram`]. 512 buckets over [`H_MIN`, `H_MAX`]
/// gives a per-bucket ratio of (1e10)^(1/512) ≈ 1.046 — percentiles are
/// reported within ~±2.3% of the true value.
const BUCKETS: usize = 512;
/// Lower edge of the bucketed range, in seconds (1 µs).
const H_MIN: f64 = 1e-6;
/// Upper edge of the bucketed range, in seconds (~2.8 hours).
const H_MAX: f64 = 1e4;

/// Fixed-size log-bucketed histogram of non-negative observations
/// (seconds, sizes, depths — any positive magnitude).
///
/// O(1) memory, O(1) `observe`, mergeable across threads/workers by
/// adding bucket counts. Values outside [1e-6, 1e4] clamp into the edge
/// buckets; the exact observed `min`/`max` are tracked so the reported
/// percentiles never step outside the observed range.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(v: f64) -> usize {
        if v.is_nan() || v <= H_MIN {
            return 0; // ≤ H_MIN, zero, negative, or NaN
        }
        if v >= H_MAX {
            return BUCKETS - 1;
        }
        let frac = (v / H_MIN).ln() / (H_MAX / H_MIN).ln();
        ((frac * BUCKETS as f64) as usize).min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the value a percentile query
    /// reports for observations that landed there.
    fn representative(i: usize) -> f64 {
        H_MIN * (H_MAX / H_MIN).powf((i as f64 + 0.5) / BUCKETS as f64)
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { 0.0 };
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (bucket-wise addition) —
    /// how per-worker locals combine into a process view.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact running sum of all observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (running sum / count); `None` if empty.
    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        Some(self.sum / self.count as f64)
    }

    /// Percentile (q in [0,1]) to within one bucket; `None` if empty.
    /// Reports the containing bucket's geometric midpoint, clamped to
    /// the exact observed [min, max]; the extreme ranks (q=0, q=1)
    /// report the exact observed min/max.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        if rank == 0 {
            return Some(self.min);
        }
        if rank == self.count - 1 {
            return Some(self.max);
        }
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Some(Self::representative(i).clamp(self.min, self.max));
            }
        }
        Some(self.max) // unreachable in practice (counts sum to count)
    }
}

// ---------------------------------------------------------------------------
// Sharded engine counters/gauges.
// ---------------------------------------------------------------------------

/// How a built-in slot merges across shards and renders in exposition.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Kind {
    /// Monotone sum across shards; rendered as a Prometheus counter.
    Counter,
    /// Wrapping signed sum across shards (per-thread deltas); gauge.
    GaugeSum,
    /// Maximum across shards (per-thread high-water marks); gauge.
    GaugeMax,
}

/// Built-in sharded series, written by the engine hot paths. Keep in sync
/// with [`DEFS`] (indexed by discriminant).
#[derive(Clone, Copy)]
#[repr(usize)]
pub(crate) enum Id {
    ExecDispatches = 0,
    OutputAllocs,
    FusedKernels,
    FusedOps,
    FusedElems,
    ProgramCacheHits,
    ProgramCacheMisses,
    FusionBailouts,
    SimdBlocks,
    PoolHits,
    PoolMisses,
    PoolReturns,
    PoolBytesPooled,
    PoolBytesLive,
    PoolBytesHighwater,
    ParallelChunks,
    ParallelTasks,
}

/// Number of built-in sharded slots.
const ID_COUNT: usize = 17;

struct Def {
    name: &'static str,
    kind: Kind,
    help: &'static str,
}

const DEFS: [Def; ID_COUNT] = [
    Def {
        name: "minitensor_exec_dispatches_total",
        kind: Kind::Counter,
        help: "Kernel dispatches through the exec-layer funnels.",
    },
    Def {
        name: "minitensor_exec_output_allocs_total",
        kind: Kind::Counter,
        help: "Output buffers drawn (pool or fresh) by exec-layer kernels.",
    },
    Def {
        name: "minitensor_exec_fused_kernels_total",
        kind: Kind::Counter,
        help: "Fused-region kernels launched by the lazy graph subsystem.",
    },
    Def {
        name: "minitensor_exec_fused_ops_total",
        kind: Kind::Counter,
        help: "Graph ops folded into fused kernels.",
    },
    Def {
        name: "minitensor_exec_fused_elems_total",
        kind: Kind::Counter,
        help: "Output elements produced by fused kernels.",
    },
    Def {
        name: "minitensor_program_cache_hits_total",
        kind: Kind::Counter,
        help: "Lazy-graph eval() calls that reused a cached compiled program.",
    },
    Def {
        name: "minitensor_program_cache_misses_total",
        kind: Kind::Counter,
        help: "Lazy-graph eval() calls that compiled a fresh program.",
    },
    Def {
        name: "minitensor_graph_fusion_bailouts_total",
        kind: Kind::Counter,
        help: "Regions degraded to per-op dispatch by partitioner caps.",
    },
    Def {
        name: "minitensor_exec_simd_blocks_total",
        kind: Kind::Counter,
        help: "Full 8-lane vector blocks processed by SIMD-funneled kernels.",
    },
    Def {
        name: "minitensor_pool_hits_total",
        kind: Kind::Counter,
        help: "Buffer-pool requests satisfied from a pooled allocation.",
    },
    Def {
        name: "minitensor_pool_misses_total",
        kind: Kind::Counter,
        help: "Buffer-pool requests that fell back to a fresh allocation.",
    },
    Def {
        name: "minitensor_pool_returns_total",
        kind: Kind::Counter,
        help: "Buffers accepted back into the pool on storage drop.",
    },
    Def {
        name: "minitensor_pool_bytes_pooled",
        kind: Kind::GaugeSum,
        help: "Bytes currently parked in the per-thread buffer pools.",
    },
    Def {
        name: "minitensor_pool_bytes_live",
        kind: Kind::GaugeSum,
        help: "Bytes currently held by live tensor storages.",
    },
    Def {
        name: "minitensor_pool_bytes_highwater",
        kind: Kind::GaugeMax,
        help: "Largest pooled-bytes footprint any one thread has held.",
    },
    Def {
        name: "minitensor_parallel_chunks_total",
        kind: Kind::Counter,
        help: "Chunks fanned out to the worker pool by parallel_for.",
    },
    Def {
        name: "minitensor_parallel_tasks_total",
        kind: Kind::Counter,
        help: "Index tasks fanned out by parallel_for_indexed.",
    },
];

/// One thread's slot array. Only the owning thread writes (relaxed
/// load+store — no RMW needed without concurrent writers); any thread
/// may read. Registered once per thread and never removed, so merged
/// counters are monotone even after the thread exits.
struct Shard {
    slots: [AtomicU64; ID_COUNT],
}

impl Shard {
    fn new() -> Shard {
        Shard {
            slots: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

struct Registry {
    shards: Mutex<Vec<Arc<Shard>>>,
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    series: Mutex<BTreeMap<String, Histogram>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        shards: Mutex::new(Vec::new()),
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        series: Mutex::new(BTreeMap::new()),
    })
}

thread_local! {
    static SHARD: std::cell::OnceCell<Arc<Shard>> = const { std::cell::OnceCell::new() };
}

/// Run `f` against the calling thread's shard, registering it on first
/// use. Silently skips during thread teardown (a TLS-destructor-order
/// storage drop may land after the shard slot is gone — losing that
/// final decrement is harmless).
#[inline]
fn with_shard<R>(f: impl FnOnce(&Shard) -> R) -> Option<R> {
    SHARD
        .try_with(|cell| {
            let shard = cell.get_or_init(|| {
                let shard = Arc::new(Shard::new());
                registry()
                    .shards
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .push(Arc::clone(&shard));
                shard
            });
            f(shard)
        })
        .ok()
}

// --- enable switch ---------------------------------------------------------

const EN_UNINIT: u8 = 0;
const EN_ON: u8 = 1;
const EN_OFF: u8 = 2;
static ENABLED: AtomicU8 = AtomicU8::new(EN_UNINIT);

/// Is the registry recording? One relaxed atomic load — the entire cost
/// a metric site adds when recording is off.
#[inline]
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        EN_ON => true,
        EN_OFF => false,
        _ => resolve_enabled(),
    }
}

#[cold]
fn resolve_enabled() -> bool {
    let off = matches!(
        std::env::var("MINITENSOR_METRICS").as_deref().map(str::trim),
        Ok("off") | Ok("0") | Ok("false")
    );
    let target = if off { EN_OFF } else { EN_ON };
    let _ = ENABLED.compare_exchange(EN_UNINIT, target, Ordering::Relaxed, Ordering::Relaxed);
    ENABLED.load(Ordering::Relaxed) == EN_ON
}

/// Turn recording on/off for the whole process (overrides
/// `MINITENSOR_METRICS`). Off also freezes [`stats`](super::stats),
/// which reads the same shards — the switch exists for A/B overhead
/// measurement (`benches/metrics_overhead.rs`), not routine use.
pub fn set_enabled(on: bool) {
    ENABLED.store(if on { EN_ON } else { EN_OFF }, Ordering::Relaxed);
}

// --- hot-path recording (crate-internal) -----------------------------------

/// Add `n` to a built-in counter slot on the calling thread's shard.
#[inline]
pub(crate) fn add(id: Id, n: u64) {
    if !enabled() {
        return;
    }
    with_shard(|s| {
        let slot = &s.slots[id as usize];
        // Owner-only writer: plain load+store, no RMW.
        slot.store(
            slot.load(Ordering::Relaxed).wrapping_add(n),
            Ordering::Relaxed,
        );
    });
}

/// Apply a signed delta to a built-in gauge slot (two's-complement
/// wrapping on the calling thread's shard; the cross-shard sum is exact
/// even when one shard's local total goes negative).
#[inline]
pub(crate) fn gauge_add(id: Id, delta: i64) {
    add(id, delta as u64);
}

/// Raise a built-in high-water slot to at least `v` on this thread.
#[inline]
pub(crate) fn gauge_peak(id: Id, v: u64) {
    if !enabled() {
        return;
    }
    with_shard(|s| {
        let slot = &s.slots[id as usize];
        if v > slot.load(Ordering::Relaxed) {
            slot.store(v, Ordering::Relaxed);
        }
    });
}

/// The calling thread's own slot value (what [`stats`](super::stats)
/// builds its exact per-thread view from).
#[inline]
pub(crate) fn thread_get(id: Id) -> u64 {
    with_shard(|s| s.slots[id as usize].load(Ordering::Relaxed)).unwrap_or(0)
}

// --- dynamically named metrics (mutex-backed; per-batch rates) -------------

/// Increment a named counter (created on first use). Intended for
/// per-request/per-batch rates — the serve stack mirrors its
/// `coordinator::Metrics` counters here — not for per-element hot loops.
pub fn counter_add(name: &str, by: u64) {
    if !enabled() {
        return;
    }
    let mut c = registry().counters.lock().unwrap_or_else(|e| e.into_inner());
    *c.entry(name.to_string()).or_insert(0) += by;
}

/// Set a named gauge to an absolute value.
pub fn gauge_set(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    let mut g = registry().gauges.lock().unwrap_or_else(|e| e.into_inner());
    g.insert(name.to_string(), v);
}

/// Record one observation into a named histogram series (exported as a
/// Prometheus summary).
pub fn observe(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    let mut s = registry().series.lock().unwrap_or_else(|e| e.into_inner());
    s.entry(name.to_string()).or_default().observe(v);
}

/// Fold an externally accumulated histogram into a named series.
pub fn merge_histogram(name: &str, h: &Histogram) {
    if !enabled() {
        return;
    }
    let mut s = registry().series.lock().unwrap_or_else(|e| e.into_inner());
    s.entry(name.to_string()).or_default().merge(h);
}

// ---------------------------------------------------------------------------
// Snapshot + exposition.
// ---------------------------------------------------------------------------

/// Point-in-time digest of one histogram series.
#[derive(Debug, Clone, Copy)]
pub struct SummarySnapshot {
    /// Observation count.
    pub count: u64,
    /// Exact running sum.
    pub sum: f64,
    /// Exact mean.
    pub mean: f64,
    /// Exact observed minimum.
    pub min: f64,
    /// Exact observed maximum.
    pub max: f64,
    /// Median (within one log bucket).
    pub p50: f64,
    /// 95th percentile (within one log bucket).
    pub p95: f64,
    /// 99th percentile (within one log bucket).
    pub p99: f64,
}

impl SummarySnapshot {
    fn from_histogram(h: &Histogram) -> Option<SummarySnapshot> {
        if h.count() == 0 {
            return None;
        }
        Some(SummarySnapshot {
            count: h.count(),
            sum: h.sum(),
            mean: h.mean().unwrap_or(0.0),
            min: h.percentile(0.0).unwrap_or(0.0),
            max: h.percentile(1.0).unwrap_or(0.0),
            p50: h.percentile(0.5).unwrap_or(0.0),
            p95: h.percentile(0.95).unwrap_or(0.0),
            p99: h.percentile(0.99).unwrap_or(0.0),
        })
    }
}

/// Full registry snapshot: every built-in slot merged across shards plus
/// every dynamically named metric, each list sorted by name.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Monotone counters (`*_total`).
    pub counters: Vec<(String, u64)>,
    /// Instantaneous gauges.
    pub gauges: Vec<(String, f64)>,
    /// Histogram series digests.
    pub summaries: Vec<(String, SummarySnapshot)>,
}

/// Merge every shard and named map into a [`MetricsSnapshot`].
pub fn snapshot() -> MetricsSnapshot {
    let reg = registry();
    let mut sums = [0u64; ID_COUNT];
    let mut maxes = [0u64; ID_COUNT];
    {
        let shards = reg.shards.lock().unwrap_or_else(|e| e.into_inner());
        for sh in shards.iter() {
            for (i, slot) in sh.slots.iter().enumerate() {
                let v = slot.load(Ordering::Relaxed);
                sums[i] = sums[i].wrapping_add(v);
                maxes[i] = maxes[i].max(v);
            }
        }
    }
    let mut counters: BTreeMap<String, u64> = BTreeMap::new();
    let mut gauges: BTreeMap<String, f64> = BTreeMap::new();
    for (i, def) in DEFS.iter().enumerate() {
        match def.kind {
            Kind::Counter => {
                counters.insert(def.name.to_string(), sums[i]);
            }
            // Clamp transient sub-zero sums (a snapshot racing a
            // cross-thread transfer) to zero for display.
            Kind::GaugeSum => {
                gauges.insert(def.name.to_string(), (sums[i] as i64).max(0) as f64);
            }
            Kind::GaugeMax => {
                gauges.insert(def.name.to_string(), maxes[i] as f64);
            }
        }
    }
    for (k, v) in reg.counters.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        *counters.entry(k.clone()).or_insert(0) += v;
    }
    for (k, v) in reg.gauges.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        gauges.insert(k.clone(), *v);
    }
    let summaries = reg
        .series
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .filter_map(|(k, h)| SummarySnapshot::from_histogram(h).map(|s| (k.clone(), s)))
        .collect();
    MetricsSnapshot {
        counters: counters.into_iter().collect(),
        gauges: gauges.into_iter().collect(),
        summaries,
    }
}

/// HELP strings for well-known *named* (mutex-map) metrics that don't
/// live in the sharded [`DEFS`] table — the robustness counters the
/// serve supervisor and the `faults` layer write.
const NAMED_HELP: &[(&str, &str)] = &[
    (
        "minitensor_faults_injected_total",
        "Faults injected by the runtime::faults failpoint layer",
    ),
    (
        "minitensor_serve_worker_crashes_total",
        "Serve worker panics contained by catch_unwind",
    ),
    (
        "minitensor_serve_worker_restarts_total",
        "Serve model replicas rebuilt by the supervisor after a crash or timeout",
    ),
    (
        "minitensor_serve_worker_timeouts_total",
        "Serve batches failed by the stuck-worker watchdog",
    ),
    (
        "minitensor_serve_replies_dropped_total",
        "Serve replies dropped because the client gave up and hung up",
    ),
];

fn help_for(name: &str) -> Option<&'static str> {
    DEFS.iter()
        .find(|d| d.name == name)
        .map(|d| d.help)
        .or_else(|| {
            NAMED_HELP
                .iter()
                .find(|(n, _)| *n == name)
                .map(|&(_, h)| h)
        })
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        // Histogram digests never produce non-finite values; gauges set
        // through the public API could. Prometheus spells these NaN/+Inf.
        if v.is_nan() {
            "NaN".into()
        } else if v > 0.0 {
            "+Inf".into()
        } else {
            "-Inf".into()
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl MetricsSnapshot {
    /// Prometheus text exposition format 0.0.4: `# HELP`/`# TYPE` plus a
    /// sample line per counter and gauge; each histogram series exports
    /// as a summary (quantile samples + `_sum` + `_count`).
    pub fn prometheus_text(&self) -> String {
        let mut out = String::with_capacity(4096);
        for (name, v) in &self.counters {
            if let Some(h) = help_for(name) {
                out.push_str(&format!("# HELP {name} {h}\n"));
            }
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            if let Some(h) = help_for(name) {
                out.push_str(&format!("# HELP {name} {h}\n"));
            }
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", fmt_f64(*v)));
        }
        for (name, s) in &self.summaries {
            out.push_str(&format!("# TYPE {name} summary\n"));
            for (q, v) in [("0.5", s.p50), ("0.95", s.p95), ("0.99", s.p99)] {
                out.push_str(&format!("{name}{{quantile=\"{q}\"}} {}\n", fmt_f64(v)));
            }
            out.push_str(&format!("{name}_sum {}\n", fmt_f64(s.sum)));
            out.push_str(&format!("{name}_count {}\n", s.count));
        }
        out
    }

    /// Hand-rolled JSON object:
    /// `{"counters":{..},"gauges":{..},"summaries":{name:{count,sum,...}}}`.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{v}", json_escape(k)));
        }
        out.push_str("},\"gauges\":{");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let v = if v.is_finite() { *v } else { 0.0 };
            out.push_str(&format!("\"{}\":{v}", json_escape(k)));
        }
        out.push_str("},\"summaries\":{");
        for (i, (k, s)) in self.summaries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"count\":{},\"sum\":{},\"mean\":{},\"min\":{},\"max\":{},\
                 \"p50\":{},\"p95\":{},\"p99\":{}}}",
                json_escape(k),
                s.count,
                s.sum,
                s.mean,
                s.min,
                s.max,
                s.p50,
                s.p95,
                s.p99
            ));
        }
        out.push_str("}}");
        out
    }
}

/// [`snapshot`] rendered as Prometheus text exposition.
pub fn prometheus_text() -> String {
    snapshot().prometheus_text()
}

// ---------------------------------------------------------------------------
// Process health (readiness for /healthz).
// ---------------------------------------------------------------------------

const HEALTH_LIVE: u8 = 0;
const HEALTH_DEGRADED: u8 = 1;
const HEALTH_DRAINING: u8 = 2;

/// Process-wide health state, reported by `/healthz`. Defaults to
/// `live`; the serve supervisor mirrors its state here.
static HEALTH: AtomicU8 = AtomicU8::new(HEALTH_LIVE);

/// Set the process health state (`"live"`, `"degraded"`, or
/// `"draining"`); unknown strings are ignored. Written by the serve
/// supervisor on every transition, readable by any `/healthz` scrape.
pub fn health_set(state: &str) {
    let v = match state {
        "live" => HEALTH_LIVE,
        "degraded" => HEALTH_DEGRADED,
        "draining" => HEALTH_DRAINING,
        _ => return,
    };
    HEALTH.store(v, Ordering::Relaxed);
}

/// The current process health state string.
pub fn health() -> &'static str {
    match HEALTH.load(Ordering::Relaxed) {
        HEALTH_DEGRADED => "degraded",
        HEALTH_DRAINING => "draining",
        _ => "live",
    }
}

/// The `/healthz` JSON body: the health state plus the robustness
/// counters an operator correlates with it (worker crashes/restarts/
/// timeouts, dropped replies, injected faults).
pub fn healthz_json() -> String {
    let snap = snapshot();
    let counter = |name: &str| {
        snap.counters
            .iter()
            .find(|(k, _)| k == name)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    };
    format!(
        "{{\"status\":\"{}\",\"worker_crashes\":{},\"worker_restarts\":{},\
         \"worker_timeouts\":{},\"replies_dropped\":{},\"faults_injected\":{}}}",
        health(),
        counter("minitensor_serve_worker_crashes_total"),
        counter("minitensor_serve_worker_restarts_total"),
        counter("minitensor_serve_worker_timeouts_total"),
        counter("minitensor_serve_replies_dropped_total"),
        counter("minitensor_faults_injected_total"),
    )
}

// ---------------------------------------------------------------------------
// HTTP exposition (hand-rolled, std-only).
// ---------------------------------------------------------------------------

/// Handle to a running metrics HTTP responder; dropping it stops the
/// accept loop and joins the thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// The bound address (resolves the actual port when started with 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Start a metrics HTTP responder on `127.0.0.1:port` (`0` picks an
/// ephemeral port — read it back from [`MetricsServer::addr`]). Routes:
/// `GET /metrics` (and `/`) → Prometheus text, `GET /metrics.json` →
/// JSON snapshot, `GET /healthz` → health JSON (503 while draining);
/// anything else → 404. One blocking accept loop handles scrapes
/// serially — scrape traffic is a request every few seconds, not a data
/// path.
pub fn serve_http(port: u16) -> std::io::Result<MetricsServer> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("mt-metrics-http".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                if let Ok(mut stream) = conn {
                    let _ = handle_conn(&mut stream);
                }
            }
        })?;
    Ok(MetricsServer {
        addr,
        stop,
        handle: Some(handle),
    })
}

fn handle_conn(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read the request head (we ignore everything past the request line).
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 16 * 1024 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let path = path.split('?').next().unwrap_or(path);
    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" | "/" => (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                prometheus_text(),
            ),
            "/metrics.json" => ("200 OK", "application/json", snapshot().to_json()),
            "/healthz" => {
                // Liveness + readiness in one: live and degraded states
                // still serve (200); draining means stop routing traffic
                // here (503).
                let status = if health() == "draining" {
                    "503 Service Unavailable"
                } else {
                    "200 OK"
                };
                (status, "application/json", healthz_json())
            }
            _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
        }
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    // --- histogram behavior (promoted with the type from coordinator) ---

    #[test]
    fn histogram_memory_is_constant_and_extremes_clamp() {
        let mut h = Histogram::new();
        for _ in 0..1_000_000 {
            h.observe(0.001);
        }
        h.observe(0.0); // below range → edge bucket, exact min tracked
        h.observe(1e9); // above range → edge bucket, exact max tracked
        assert_eq!(h.count(), 1_000_002);
        assert_eq!(h.counts.len(), BUCKETS);
        assert_eq!(h.percentile(0.0), Some(0.0));
        assert_eq!(h.percentile(1.0), Some(1e9));
        let p50 = h.percentile(0.5).unwrap();
        assert!((p50 - 0.001).abs() < 0.001 * 0.05, "{p50}");
    }

    #[test]
    fn histograms_merge_like_one_series() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 1..=50 {
            a.observe(i as f64 / 1000.0);
            whole.observe(i as f64 / 1000.0);
        }
        for i in 51..=100 {
            b.observe(i as f64 / 1000.0);
            whole.observe(i as f64 / 1000.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.mean(), whole.mean());
        assert_eq!(a.sum(), whole.sum());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.percentile(q), whole.percentile(q), "q={q}");
        }
    }

    #[test]
    fn merging_an_empty_histogram_changes_nothing() {
        let mut a = Histogram::new();
        a.observe(0.002);
        a.observe(0.004);
        let before_mean = a.mean();
        a.merge(&Histogram::new());
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean(), before_mean);
        // The empty side's sentinel min/max (+inf/-inf) must not leak
        // into the merged extremes.
        assert_eq!(a.percentile(0.0), Some(0.002));
        assert_eq!(a.percentile(1.0), Some(0.004));

        // And merging *into* an empty histogram reproduces the source.
        let mut e = Histogram::new();
        e.merge(&a);
        assert_eq!(e.count(), a.count());
        assert_eq!(e.mean(), a.mean());
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(e.percentile(q), a.percentile(q), "q={q}");
        }
    }

    #[test]
    fn empty_histogram_reports_none() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), None);
        assert_eq!(h.sum(), 0.0);
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(h.percentile(q), None, "q={q}");
        }
        assert!(SummarySnapshot::from_histogram(&h).is_none());
    }

    #[test]
    fn out_of_range_values_clamp_to_edge_buckets() {
        assert_eq!(Histogram::bucket(1e-9), 0);
        assert_eq!(Histogram::bucket(0.0), 0);
        assert_eq!(Histogram::bucket(-5.0), 0);
        assert_eq!(Histogram::bucket(f64::NAN), 0);
        assert_eq!(Histogram::bucket(1e5), BUCKETS - 1);
        assert_eq!(Histogram::bucket(f64::INFINITY), BUCKETS - 1);

        let mut h = Histogram::new();
        for _ in 0..10 {
            h.observe(1e-9);
        }
        for _ in 0..10 {
            h.observe(1e5);
        }
        assert_eq!(h.percentile(0.0), Some(1e-9));
        assert_eq!(h.percentile(1.0), Some(1e5));
        let p40 = h.percentile(0.4).unwrap();
        assert!((1e-9..=1e5).contains(&p40), "{p40}");
    }

    #[test]
    fn single_sample_percentile_is_that_value() {
        let mut h = Histogram::new();
        h.observe(0.0123);
        for q in [0.0, 0.25, 0.5, 0.75, 0.99, 1.0] {
            assert_eq!(h.percentile(q), Some(0.0123), "q={q}");
        }
        assert_eq!(h.mean(), Some(0.0123));
    }

    // --- registry behavior ---
    //
    // The registry is process-global and the unit-test binary runs tests
    // concurrently, so these assert monotone deltas (≥), never global
    // equality; exact lose-nothing accounting is pinned by the
    // serialized hammer test in tests/metrics.rs.

    #[test]
    fn sharded_counter_merges_across_threads() {
        let before = snapshot();
        let get = |s: &MetricsSnapshot| {
            s.counters
                .iter()
                .find(|(k, _)| k == "minitensor_parallel_tasks_total")
                .map(|&(_, v)| v)
                .unwrap_or(0)
        };
        add(Id::ParallelTasks, 5);
        std::thread::spawn(|| add(Id::ParallelTasks, 7))
            .join()
            .unwrap();
        let after = snapshot();
        assert!(
            get(&after) >= get(&before) + 12,
            "both threads' increments must merge: {} -> {}",
            get(&before),
            get(&after)
        );
    }

    #[test]
    fn gauge_deltas_balance_across_threads() {
        // +N on this thread, -N on another: the merged sum must return
        // to (at least) its starting point despite the second shard
        // holding a wrapped "negative" value.
        let get = |s: &MetricsSnapshot| {
            s.gauges
                .iter()
                .find(|(k, _)| k == "minitensor_pool_bytes_live")
                .map(|&(_, v)| v)
                .unwrap_or(0.0)
        };
        gauge_add(Id::PoolBytesLive, 1 << 30);
        let mid = snapshot();
        std::thread::spawn(|| gauge_add(Id::PoolBytesLive, -(1 << 30)))
            .join()
            .unwrap();
        let after = snapshot();
        assert!(
            get(&mid) - get(&after) >= (1 << 30) as f64 * 0.99,
            "cross-thread decrement must subtract from the merged view: mid={} after={}",
            get(&mid),
            get(&after)
        );
    }

    #[test]
    fn gauge_peak_takes_max_across_threads() {
        gauge_peak(Id::PoolBytesHighwater, 1000);
        std::thread::spawn(|| gauge_peak(Id::PoolBytesHighwater, 999_999_999))
            .join()
            .unwrap();
        let s = snapshot();
        let hw = s
            .gauges
            .iter()
            .find(|(k, _)| k == "minitensor_pool_bytes_highwater")
            .map(|&(_, v)| v)
            .unwrap();
        assert!(hw >= 999_999_999.0, "{hw}");
    }

    #[test]
    fn named_metrics_round_trip() {
        counter_add("minitensor_test_named_total", 3);
        counter_add("minitensor_test_named_total", 4);
        gauge_set("minitensor_test_named_gauge", 2.5);
        observe("minitensor_test_named_series", 0.002);
        observe("minitensor_test_named_series", 0.004);
        let s = snapshot();
        let c = s
            .counters
            .iter()
            .find(|(k, _)| k == "minitensor_test_named_total")
            .map(|&(_, v)| v)
            .unwrap();
        assert!(c >= 7);
        assert!(s
            .gauges
            .iter()
            .any(|(k, &v)| k == "minitensor_test_named_gauge" && v == 2.5));
        let (_, sum) = s
            .summaries
            .iter()
            .find(|(k, _)| k == "minitensor_test_named_series")
            .unwrap();
        assert!(sum.count >= 2);
        assert!(sum.min <= 0.002 && sum.max >= 0.004);
    }

    // The set_enabled(false) path is pinned in tests/metrics.rs — the
    // switch is process-global, so flipping it here would race the other
    // unit tests' delta assertions; that binary serializes on a guard.

    // --- exposition formats (synthetic snapshot → deterministic text) ---

    fn sample_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            counters: vec![
                ("minitensor_exec_dispatches_total".into(), 42),
                ("minitensor_serve_requests_total".into(), 7),
            ],
            gauges: vec![("minitensor_pool_bytes_live".into(), 4096.0)],
            summaries: vec![(
                "minitensor_serve_latency".into(),
                SummarySnapshot {
                    count: 3,
                    sum: 0.006,
                    mean: 0.002,
                    min: 0.001,
                    max: 0.003,
                    p50: 0.002,
                    p95: 0.003,
                    p99: 0.003,
                },
            )],
        }
    }

    #[test]
    fn prometheus_text_renders_all_families() {
        let text = sample_snapshot().prometheus_text();
        assert!(text.contains("# TYPE minitensor_exec_dispatches_total counter"));
        assert!(text.contains("# HELP minitensor_exec_dispatches_total"));
        assert!(text.contains("minitensor_exec_dispatches_total 42"));
        assert!(text.contains("# TYPE minitensor_pool_bytes_live gauge"));
        assert!(text.contains("minitensor_pool_bytes_live 4096"));
        assert!(text.contains("# TYPE minitensor_serve_latency summary"));
        assert!(text.contains("minitensor_serve_latency{quantile=\"0.5\"} 0.002"));
        assert!(text.contains("minitensor_serve_latency_sum 0.006"));
        assert!(text.contains("minitensor_serve_latency_count 3"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad sample line: {line}");
        }
    }

    #[test]
    fn json_snapshot_is_well_formed() {
        let j = sample_snapshot().to_json();
        assert!(j.starts_with("{\"counters\":{"));
        assert!(j.contains("\"minitensor_exec_dispatches_total\":42"));
        assert!(j.contains("\"gauges\":{\"minitensor_pool_bytes_live\":4096"));
        assert!(j.contains("\"minitensor_serve_latency\":{\"count\":3"));
        assert!(j.ends_with("}}"));
    }

    #[test]
    fn live_snapshot_always_exposes_builtin_families() {
        // Even an idle process exports the full built-in schema, so a
        // scraper sees stable families from the first scrape.
        let s = snapshot();
        for def in DEFS.iter() {
            let present = s.counters.iter().any(|(k, _)| k == def.name)
                || s.gauges.iter().any(|(k, _)| k == def.name);
            assert!(present, "missing builtin {}", def.name);
        }
    }

    // --- HTTP responder ---

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn http_serves_metrics_and_404s_unknown_paths() {
        let server = serve_http(0).expect("bind ephemeral port");
        let addr = server.addr();
        let resp = http_get(addr, "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("minitensor_exec_dispatches_total"));
        let json = http_get(addr, "/metrics.json");
        assert!(json.starts_with("HTTP/1.1 200 OK"));
        assert!(json.contains("\"counters\":{"));
        let missing = http_get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"));
        drop(server); // must join cleanly without hanging the test
    }

    #[test]
    fn healthz_reports_state_and_counters() {
        // The only test in this binary that writes the global health
        // state (the serve unit tests keep theirs server-local), so the
        // transitions below cannot race another assertion.
        let server = serve_http(0).expect("bind ephemeral port");
        let addr = server.addr();

        health_set("live");
        let resp = http_get(addr, "/healthz");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"status\":\"live\""), "{resp}");
        assert!(resp.contains("\"worker_restarts\":"), "{resp}");
        assert!(resp.contains("\"faults_injected\":"), "{resp}");

        health_set("degraded");
        let resp = http_get(addr, "/healthz");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "degraded still serves: {resp}");
        assert!(resp.contains("\"status\":\"degraded\""), "{resp}");

        health_set("draining");
        let resp = http_get(addr, "/healthz");
        assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
        assert!(resp.contains("\"status\":\"draining\""), "{resp}");

        health_set("not-a-state"); // ignored
        assert_eq!(health(), "draining");
        health_set("live");
        assert_eq!(health(), "live");
        drop(server);
    }
}
