//! PJRT runtime: load AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and execute them from Rust.
//!
//! Python never runs on this path — the artifacts are HLO *text* (the
//! interchange format that survives the jax≥0.5 / xla_extension 0.5.1
//! proto-id mismatch; see DESIGN.md), parsed and compiled once per process
//! by the PJRT CPU client, then executed with `Tensor` inputs.

mod artifact;
mod engine;

pub use artifact::{Artifact, Manifest};
pub use engine::{Engine, LoadedModel};
