//! Runtime services: the parallel execution pool that powers the native
//! kernels, the observability pillars ([`stats`], [`trace`], and the
//! process-wide [`metrics`] registry), the [`faults`] fault-injection
//! layer that chaos-tests them, and (behind the `xla` feature)
//! the PJRT engine that loads AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py`.
//!
//! The PJRT path: artifacts are HLO *text* (the interchange format that
//! survives the jax≥0.5 / xla_extension 0.5.1 proto-id mismatch; see
//! DESIGN.md), parsed and compiled once per process by the PJRT CPU
//! client, then executed with `Tensor` inputs. Python never runs on that
//! path. The `xla` crate is not in the offline vendor set, so the engine
//! is compiled only with `--features xla`.

mod artifact;
#[cfg(feature = "xla")]
mod engine;
pub(crate) mod envvar;
pub mod faults;
pub mod metrics;
pub mod parallel;
pub mod simd;
pub mod stats;
pub mod trace;

pub use artifact::{Artifact, Manifest};
#[cfg(feature = "xla")]
pub use engine::{Engine, LoadedModel};
