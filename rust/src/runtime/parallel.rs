//! Persistent worker pool and the chunked `parallel_for` beneath the
//! unified kernel-execution layer (`ops::exec`).
//!
//! Design: one process-wide pool of `N-1` workers (lazily spawned on the
//! first parallel dispatch) plus the calling thread, fed from a single
//! shared queue. Kernels never talk to the pool directly — they go through
//! [`parallel_for`], which splits an index range into at most
//! [`num_threads`] contiguous chunks and blocks until every chunk has run.
//!
//! The worker count is configurable: [`set_num_threads`] wins, then the
//! `MINITENSOR_NUM_THREADS` environment variable, then the machine's
//! available cores. A count of **1 reproduces the serial kernels exactly**
//! (`parallel_for` degenerates to a direct call, so results are
//! bit-identical to the pre-pool engine) — that invariant is what the
//! `exec_parallel` integration tests pin down.
//!
//! Nested dispatch is safe: a `parallel_for` issued from inside another
//! `parallel_for`'s chunk — on a worker *or* on the calling thread's own
//! inline chunk (e.g. the batched conv loop calling the panel-parallel
//! SGEMM) — runs serially instead of re-entering the finite pool, which
//! avoids deadlock, keeps the outer-loop parallelism as the one that
//! owns the cores, and never leaves the caller stalled behind queued
//! outer tasks.
//!
//! Panic containment: a panicking chunk never poisons the pool. Every
//! chunk body runs under `catch_unwind`; the submitting thread always
//! waits for *all* sibling chunks (the latch counts down on panic too,
//! so the Condvar protocol cannot deadlock), then re-raises the first
//! captured panic **payload** via `resume_unwind` — callers see the
//! original panic message, not a generic wrapper — and the pool remains
//! reusable for the next dispatch. The `parallel.chunk` failpoint
//! (`runtime::faults`) injects panics/delays at the top of each chunk to
//! pin exactly this contract in `tests/fault_injection.rs`.

use std::any::Any;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A unit of work shipped to a worker.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Configured thread count; 0 means "not resolved yet" (resolve from the
/// environment on first read).
static NUM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Hard ceiling on the configured thread count. It bounds the number of
/// chunks `parallel_for` cuts (physical concurrency is already capped by
/// the core-sized pool), so absurd `MINITENSOR_NUM_THREADS` values can't
/// flood the queue with micro-chunks.
const MAX_THREADS: usize = 256;

thread_local! {
    /// True on pool worker threads, so nested `parallel_for` calls run
    /// serially instead of blocking the (finite) pool on itself.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// The effective worker-thread count: the last [`set_num_threads`] value,
/// else `MINITENSOR_NUM_THREADS`, else the number of available cores
/// (clamped to `1..=256` either way).
pub fn num_threads() -> usize {
    let n = NUM_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    // A parseable env value is clamped like set_num_threads (so `0`
    // means serial, not "ignore me"); unset falls back to the core
    // count, and an unparseable value does too — after a once-per-process
    // stderr warning (it used to fail silently, which read exactly like
    // the override had worked).
    let raw = std::env::var("MINITENSOR_NUM_THREADS").ok();
    let resolved = env_threads(raw.as_deref())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1)
        })
        .min(MAX_THREADS);
    // compare_exchange, not store: a concurrent set_num_threads() must
    // not be clobbered by this lazy default resolution.
    match NUM_THREADS.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => resolved,
        Err(current) => current,
    }
}

/// Parse a raw `MINITENSOR_NUM_THREADS` value: any unsigned integer is
/// accepted and clamped to `1..=`[`MAX_THREADS`]; anything else warns
/// once on stderr and returns `None` (caller falls back to core count).
fn env_threads(raw: Option<&str>) -> Option<usize> {
    super::envvar::parse::<usize>(
        "MINITENSOR_NUM_THREADS",
        raw,
        |_| true,
        "an unsigned integer thread count",
    )
    .map(|v| v.clamp(1, MAX_THREADS))
}

/// Override the worker count for the whole process (clamped to
/// `1..=256`). `1` forces exact serial execution (bit-identical to the
/// pre-pool kernels). Counts above the machine's cores only change how
/// finely work is chunked — physical concurrency is capped by the pool,
/// which is sized to the available cores on first parallel dispatch.
pub fn set_num_threads(n: usize) {
    NUM_THREADS.store(n.clamp(1, MAX_THREADS), Ordering::Relaxed);
}

/// The count [`set_num_threads`]`(n)` would take effect as (`0` means
/// "inherit the current setting") — for banners and reports that print a
/// configured value before applying it, so they can't misreport the
/// clamp.
pub fn effective_threads(n: usize) -> usize {
    if n == 0 {
        num_threads()
    } else {
        n.clamp(1, MAX_THREADS)
    }
}

/// Default minimum total element-ops before a kernel engages the pool.
pub const DEFAULT_PAR_THRESHOLD: usize = 1 << 15;

/// Default target element-ops per parallel chunk (grain).
pub const DEFAULT_PAR_GRAIN: usize = 1 << 13;

/// Dispatch cutoffs; 0 means "not resolved yet" (resolve from the
/// environment on first read, like [`NUM_THREADS`]).
static PAR_THRESHOLD_V: AtomicUsize = AtomicUsize::new(0);
static PAR_GRAIN_V: AtomicUsize = AtomicUsize::new(0);

/// Shared lazy-resolution for the dispatch cutoffs: programmatic setter
/// wins, then the environment variable, then the built-in default
/// (clamped to ≥ 1 so the chunk arithmetic never divides by zero).
fn resolve_tunable(cell: &AtomicUsize, env: &str, default: usize) -> usize {
    let v = cell.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let resolved = std::env::var(env)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|v| v.max(1))
        .unwrap_or(default);
    match cell.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => resolved,
        Err(current) => current,
    }
}

/// Minimum total element-ops of work before a kernel engages the worker
/// pool; below it the fork/join overhead exceeds the loop itself.
/// Override order: [`set_par_threshold`], then `MINITENSOR_PAR_THRESHOLD`,
/// then [`DEFAULT_PAR_THRESHOLD`]. First step toward auto-tuning these
/// from a startup microbenchmark (ROADMAP).
pub fn par_threshold() -> usize {
    resolve_tunable(
        &PAR_THRESHOLD_V,
        "MINITENSOR_PAR_THRESHOLD",
        DEFAULT_PAR_THRESHOLD,
    )
}

/// Target element-ops per parallel chunk. Override order:
/// [`set_par_grain`], then `MINITENSOR_PAR_GRAIN`, then
/// [`DEFAULT_PAR_GRAIN`].
pub fn par_grain() -> usize {
    resolve_tunable(&PAR_GRAIN_V, "MINITENSOR_PAR_GRAIN", DEFAULT_PAR_GRAIN)
}

/// Override the parallelism threshold for the whole process (clamped ≥ 1).
pub fn set_par_threshold(n: usize) {
    PAR_THRESHOLD_V.store(n.max(1), Ordering::Relaxed);
}

/// Override the parallel grain for the whole process (clamped ≥ 1).
pub fn set_par_grain(n: usize) {
    PAR_GRAIN_V.store(n.max(1), Ordering::Relaxed);
}

/// Countdown latch: `parallel_for` blocks on it until every shipped chunk
/// has finished, which is what makes the borrowed-closure hand-off sound.
///
/// A panicking chunk stores its payload here (first writer wins) and
/// still counts down, so the submitting thread can re-raise the original
/// panic after every sibling has finished — structured propagation with
/// no Condvar deadlock and no poisoned pool.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    payload: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            payload: Mutex::new(None),
        }
    }

    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = self.payload.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(|e| e.into_inner());
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(|e| e.into_inner());
        while *r > 0 {
            r = self
                .done
                .wait(r)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// The first worker panic payload, if any chunk panicked.
    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.payload.lock().unwrap_or_else(|e| e.into_inner()).take()
    }
}

/// The process-wide pool: a shared injector queue and detached workers
/// that live for the rest of the process.
struct Pool {
    queue: Mutex<Sender<Task>>,
}

impl Pool {
    fn submit(&self, task: Task) {
        // The receiver lives in the detached workers and the sender in a
        // static, so the channel can never be closed: send cannot fail.
        let _ = self
            .queue
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .send(task);
    }
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        // Sized to the machine, independent of the configured thread
        // count: the calling thread is always worker zero, and counts
        // beyond the cores would only oversubscribe. Excess chunks queue
        // and drain, so a later set_num_threads() never needs new threads.
        let cores = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1);
        let workers = cores.saturating_sub(1).max(1);
        super::metrics::gauge_set("minitensor_parallel_pool_workers", workers as f64);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("minitensor-worker-{i}"))
                .spawn(move || {
                    IN_WORKER.with(|w| w.set(true));
                    loop {
                        // One idle worker at a time holds the queue mutex
                        // while blocked in recv() (a lock hand-off); the
                        // guard drops before task() runs, so slow kernels
                        // never hold up dispatch to the other workers.
                        let task = {
                            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                            guard.recv()
                        };
                        match task {
                            Ok(task) => task(),
                            Err(_) => break,
                        }
                    }
                })
                .expect("minitensor: failed to spawn pool worker");
        }
        Pool {
            queue: Mutex::new(tx),
        }
    })
}

/// Run `body(start, end)` over a partition of `0..len` into contiguous
/// chunks of at least `grain` elements, using at most [`num_threads`]
/// chunks. Blocks until every chunk completes. With one effective thread
/// (or when already on a pool worker) this is exactly `body(0, len)`.
///
/// Chunk boundaries depend only on `(len, grain, num_threads)`, so results
/// are deterministic for a fixed thread count.
pub fn parallel_for(len: usize, grain: usize, body: &(dyn Fn(usize, usize) + Sync)) {
    if len == 0 {
        return;
    }
    let grain = grain.max(1);
    let chunks = num_threads().min(len.div_ceil(grain));
    if chunks <= 1 || IN_WORKER.with(|w| w.get()) {
        super::faults::fire_infallible("parallel.chunk");
        body(0, len);
        return;
    }

    let pool = pool();
    // Pool-utilization telemetry: chunks fanned out (including the
    // caller's inline chunk) per engaged dispatch.
    super::metrics::add(super::metrics::Id::ParallelChunks, chunks as u64);
    let latch = Arc::new(Latch::new(chunks - 1));
    // SAFETY: every task signals `latch` when done and this function does
    // not return before `latch.wait()` observes all of them, so the
    // borrows captured by `body` strictly outlive every worker access.
    // The calling thread's own chunk runs under `catch_unwind` so an
    // unwinding kernel still waits for the workers before propagating.
    let body_static: &'static (dyn Fn(usize, usize) + Sync) =
        unsafe { std::mem::transmute(body) };

    let base = len / chunks;
    let extra = len % chunks;
    let first_end = base + usize::from(extra > 0);
    let mut start = first_end;
    for i in 1..chunks {
        let size = base + usize::from(i < extra);
        let (s, e) = (start, start + size);
        start = e;
        let latch = Arc::clone(&latch);
        pool.submit(Box::new(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // Per-chunk span on the worker's own timeline track, so
                // idle gaps between chunks (imbalance, queueing) are
                // visible in the trace viewer.
                let mut sp = super::trace::span("parallel", "chunk");
                sp.arg_u("start", s as u64);
                sp.arg_u("len", (e - s) as u64);
                super::faults::fire_infallible("parallel.chunk");
                body_static(s, e);
            }));
            if let Err(payload) = result {
                latch.record_panic(payload);
            }
            latch.count_down();
        }));
    }
    debug_assert_eq!(start, len);

    // Run the caller's own chunk with the worker flag set: a nested
    // parallel_for inside it must degrade to serial (like on the
    // workers) rather than queue subtasks behind the outer tasks and
    // stall this thread on a nested latch. The flag was necessarily
    // false to get here, so resetting to false is correct; catch_unwind
    // ensures the reset happens even when the chunk panics.
    IN_WORKER.with(|w| w.set(true));
    let main_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sp = super::trace::span("parallel", "chunk");
        sp.arg_u("start", 0);
        sp.arg_u("len", first_end as u64);
        sp.arg_u("inline", 1);
        super::faults::fire_infallible("parallel.chunk");
        body(0, first_end)
    }));
    IN_WORKER.with(|w| w.set(false));
    latch.wait();
    // Inline-chunk panic wins (it is the submitting thread's own frame);
    // otherwise re-raise the first worker payload so callers see the
    // original panic message rather than a generic wrapper.
    if let Err(payload) = main_result {
        std::panic::resume_unwind(payload);
    }
    if let Some(payload) = latch.take_panic() {
        std::panic::resume_unwind(payload);
    }
}

/// Run `body(i)` once for every index in `0..tasks`, handing indices to
/// the pool through a shared atomic cursor. Unlike [`parallel_for`], the
/// *work decomposition* is fixed by the caller — exactly one call per
/// index, regardless of `num_threads()` — so per-index outputs cannot
/// depend on the thread count; only the index→thread assignment varies.
/// Use it when each index owns a private output slot (e.g. per-chunk
/// gradient partials) that a fixed-order combine pass folds afterwards.
///
/// Blocks until every index has run. With one effective thread (or when
/// already on a pool worker) the indices run serially in ascending order
/// on the calling thread.
pub fn parallel_for_indexed(tasks: usize, body: &(dyn Fn(usize) + Sync)) {
    if tasks == 0 {
        return;
    }
    let helpers = num_threads().min(tasks).saturating_sub(1);
    if helpers == 0 || IN_WORKER.with(|w| w.get()) {
        super::faults::fire_infallible("parallel.chunk");
        for i in 0..tasks {
            body(i);
        }
        return;
    }

    let pool = pool();
    super::metrics::add(super::metrics::Id::ParallelTasks, tasks as u64);
    let latch = Arc::new(Latch::new(helpers));
    let cursor = Arc::new(AtomicUsize::new(0));
    // SAFETY: the same borrowed-closure hand-off as `parallel_for` —
    // every helper signals `latch` when done and this function blocks on
    // `latch.wait()` before returning, so the borrows captured by `body`
    // strictly outlive every worker access.
    let body_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(body) };

    for _ in 0..helpers {
        let latch = Arc::clone(&latch);
        let cursor = Arc::clone(&cursor);
        pool.submit(Box::new(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= tasks {
                    break;
                }
                let mut sp = super::trace::span("parallel", "task");
                sp.arg_u("i", i as u64);
                super::faults::fire_infallible("parallel.chunk");
                body_static(i);
            }));
            if let Err(payload) = result {
                latch.record_panic(payload);
            }
            latch.count_down();
        }));
    }

    // The calling thread drains the same cursor, with the worker flag set
    // so nested dispatch degrades to serial (see `parallel_for`).
    IN_WORKER.with(|w| w.set(true));
    let main_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= tasks {
            break;
        }
        let mut sp = super::trace::span("parallel", "task");
        sp.arg_u("i", i as u64);
        super::faults::fire_infallible("parallel.chunk");
        body(i);
    }));
    IN_WORKER.with(|w| w.set(false));
    latch.wait();
    if let Err(payload) = main_result {
        std::panic::resume_unwind(payload);
    }
    if let Some(payload) = latch.take_panic() {
        std::panic::resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::MutexGuard;

    /// Tests that mutate the global thread count take this lock so they
    /// cannot race each other inside the multi-threaded test harness.
    fn nt_lock() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn covers_every_index_exactly_once() {
        // Correct partition at any thread count, including odd sizes.
        for &len in &[1usize, 2, 7, 1000, 4097] {
            let hits: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
            parallel_for(len, 8, &|s, e| {
                for h in &hits[s..e] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "len={len}"
            );
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(1);
        let tid = std::thread::current().id();
        let ran = AtomicUsize::new(0);
        parallel_for(100, 1, &|s, e| {
            assert_eq!((s, e), (0, 100));
            assert_eq!(std::thread::current().id(), tid);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        set_num_threads(before);
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_parallel_for_does_not_deadlock() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(4);
        let total = AtomicU64::new(0);
        parallel_for(64, 1, &|s, e| {
            // Nested dispatch: must run serially on workers, never hang.
            parallel_for(10, 1, &|s2, e2| {
                total.fetch_add(((e - s) * (e2 - s2)) as u64, Ordering::Relaxed);
            });
        });
        set_num_threads(before);
        assert_eq!(total.load(Ordering::Relaxed), 64 * 10);
    }

    #[test]
    fn grain_caps_chunk_count() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(8);
        let calls = AtomicUsize::new(0);
        parallel_for(100, 60, &|_, _| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        set_num_threads(before);
        // 100 elements at grain 60 → at most ceil(100/60) = 2 chunks.
        assert!(calls.load(Ordering::Relaxed) <= 2);
    }

    #[test]
    fn indexed_covers_every_index_exactly_once() {
        for &tasks in &[1usize, 2, 5, 63, 200] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            parallel_for_indexed(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "tasks={tasks}"
            );
        }
    }

    #[test]
    fn indexed_runs_serially_at_one_thread() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(1);
        let tid = std::thread::current().id();
        let order = Mutex::new(Vec::new());
        parallel_for_indexed(8, &|i| {
            assert_eq!(std::thread::current().id(), tid);
            order.lock().unwrap().push(i);
        });
        set_num_threads(before);
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn indexed_nested_inside_parallel_for_stays_serial() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(4);
        let total = AtomicU64::new(0);
        parallel_for(16, 1, &|s, e| {
            parallel_for_indexed(5, &|_| {
                total.fetch_add((e - s) as u64, Ordering::Relaxed);
            });
        });
        set_num_threads(before);
        assert_eq!(total.load(Ordering::Relaxed), 16 * 5);
    }

    #[test]
    fn par_tunables_setters_clamp_and_stick() {
        // No std::env mutation here (the test harness is multi-threaded);
        // the env-var path shares resolve_tunable with the setter path,
        // which this exercises end to end.
        let _guard = nt_lock();
        let t0 = par_threshold();
        let g0 = par_grain();
        set_par_threshold(12345);
        set_par_grain(77);
        assert_eq!(par_threshold(), 12345);
        assert_eq!(par_grain(), 77);
        set_par_threshold(0); // clamps to 1
        set_par_grain(0);
        assert_eq!(par_threshold(), 1);
        assert_eq!(par_grain(), 1);
        set_par_threshold(t0);
        set_par_grain(g0);
    }

    #[test]
    fn worker_panic_payload_reaches_the_caller_and_pool_stays_usable() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(4);
        // Panic in whichever chunk covers index 900 (a worker chunk or the
        // inline chunk, depending on partitioning) with a distinctive
        // message; the caller must observe that exact payload.
        let result = std::panic::catch_unwind(|| {
            parallel_for(1000, 1, &|s, e| {
                if (s..e).contains(&900) {
                    panic!("chunk exploded at 900");
                }
            });
        });
        let payload = result.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<&'static str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("chunk exploded at 900"), "{msg}");

        // The pool must be fully reusable after the panic: every latch
        // counted down, no worker died, no Condvar is stuck.
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 1, &|s, e| {
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        set_num_threads(before);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn indexed_task_panic_payload_reaches_the_caller() {
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(4);
        let result = std::panic::catch_unwind(|| {
            parallel_for_indexed(64, &|i| {
                if i == 17 {
                    panic!("task 17 exploded");
                }
            });
        });
        let payload = result.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<&'static str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("task 17 exploded"), "{msg}");
        // Reusable afterwards.
        let total = AtomicU64::new(0);
        parallel_for_indexed(64, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        set_num_threads(before);
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn thread_count_never_zero() {
        assert!(num_threads() >= 1);
        let _guard = nt_lock();
        let before = num_threads();
        set_num_threads(0); // clamps to 1
        assert_eq!(num_threads(), 1);
        set_num_threads(before);
    }

    #[test]
    fn env_threads_accepts_integers_and_rejects_garbage() {
        // Pure resolution over raw values — no std::env mutation (the
        // test harness is multi-threaded).
        assert_eq!(env_threads(None), None);
        assert_eq!(env_threads(Some("4")), Some(4));
        assert_eq!(env_threads(Some(" 2 ")), Some(2));
        assert_eq!(env_threads(Some("0")), Some(1), "0 clamps to serial");
        assert_eq!(env_threads(Some("100000")), Some(MAX_THREADS));
        // Invalid values fall back (with a once-per-process warning).
        assert_eq!(env_threads(Some("banana")), None);
        assert_eq!(env_threads(Some("-2")), None);
        assert_eq!(env_threads(Some("3.5")), None);
        // The warn path carries the variable name and the raw value.
        let err = crate::runtime::envvar::parse_checked::<usize>(
            "MINITENSOR_NUM_THREADS",
            Some("banana"),
            |_| true,
            "an unsigned integer thread count",
        )
        .unwrap_err();
        assert!(err.contains("MINITENSOR_NUM_THREADS"), "{err}");
        assert!(err.contains("banana"), "{err}");
    }
}
